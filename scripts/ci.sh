#!/usr/bin/env bash
# CI entry point: tier-1 suite + sweep/bench/quickstart smokes + docs check
# + backend-parity smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== lint (ruff, correctness tier — skipped when unavailable) =="
if command -v ruff >/dev/null 2>&1; then
    # Config lives in pyproject.toml ([tool.ruff]): pyflakes + E9 only.
    ruff check src tests benchmarks scripts examples
else
    echo "ruff not installed in this image; lint config still applies in editors"
fi

echo "== tier-1: full test suite =="
python -m pytest -x -q

echo "== sweep smoke (<=16 grid points, interpret) + resume check =="
SWEEP_CI_ROOT=$(mktemp -d)
PYTHONPATH=src python -m repro.sweep.run --smoke --root "$SWEEP_CI_ROOT" --quiet
# identical spec, second invocation: every chunk must come from the store
PYTHONPATH=src python -m repro.sweep.run --smoke --root "$SWEEP_CI_ROOT" --quiet --expect-cached
rm -rf "$SWEEP_CI_ROOT"

echo "== adaptive smoke: boundary search economy + grid parity + resume =="
ADAPT_CI_ROOT=$(mktemp -d)
PYTHONPATH=src python - "$ADAPT_CI_ROOT" <<'PY'
import filecmp, os, sys

from repro.sweep import presets, run_adaptive, run_sweep

root = sys.argv[1]
aspec = presets.adaptive_smoke_spec()

dense = run_sweep(aspec.base, os.path.join(root, "dense"))
adaptive = run_adaptive(aspec, os.path.join(root, "adaptive"))

# Economy gate: the boundary search must consult <= 40% of the ladder.
assert adaptive.points_covered <= 0.4 * adaptive.n_grid_points, \
    (adaptive.points_covered, adaptive.n_grid_points)

# Cliff parity: each located bracket must match a dense first-below scan.
by_idx = {r["index"]: r["success"] for r in dense.records}
ladder = sorted(by_idx)
for c in adaptive.crossings:
    assert c.crossed and c.direction == "falling", c
    first_below = next(i for i in ladder if by_idx[i] < c.threshold)
    assert (c.lo_index, c.hi_index) == (first_below - 1, first_below), \
        (c, first_below)

# Store parity: every chunk file both modes produced is byte-identical.
d_dir = os.path.join(dense.store_path, "chunks")
a_dir = os.path.join(adaptive.store_path, "chunks")
chunk_files = sorted(set(os.listdir(d_dir)) & set(os.listdir(a_dir)))
assert chunk_files, (os.listdir(d_dir), os.listdir(a_dir))
for f in chunk_files:
    assert filecmp.cmp(os.path.join(d_dir, f), os.path.join(a_dir, f),
                       shallow=False), f

print(f"adaptive gate OK: {adaptive.points_covered}/"
      f"{adaptive.n_grid_points} points probed, "
      f"{len(adaptive.crossings)} crossings match dense scan, "
      f"{len(chunk_files)} shared chunk files byte-identical")
PY
# identical campaign, second invocation: the search must replay entirely
# from the store (zero chunks executed).
PYTHONPATH=src python -m repro.sweep.run --adaptive \
    --root "$ADAPT_CI_ROOT/adaptive" --quiet --expect-cached
rm -rf "$ADAPT_CI_ROOT"

echo "== fault-tolerant sweep smoke (elastic workers) =="
FT_CI_ROOT=$(mktemp -d)
PYTHONPATH=src python -m repro.sweep.run --smoke --workers 3 \
    --root "$FT_CI_ROOT" --quiet
PYTHONPATH=src python -m repro.sweep.run --smoke --workers 3 \
    --root "$FT_CI_ROOT" --quiet --expect-cached
rm -rf "$FT_CI_ROOT"

echo "== program-fusion differential + golden + megakernel suites =="
PYTHONPATH=src python -m pytest -x -q tests/test_compile_differential.py \
    tests/test_compile_golden.py tests/test_megakernel_differential.py

echo "== bench smoke: per-op vs fused (structural dispatch gate) =="
BENCH_CI_ROOT=$(mktemp -d)
PYTHONPATH=src python -m benchmarks.bench --smoke \
    --out "$BENCH_CI_ROOT/BENCH_fused.json"
PYTHONPATH=src python - "$BENCH_CI_ROOT/BENCH_fused.json" <<'PY'
import json, sys

doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "repro-bench/fused-v4", doc["schema"]
rows = {(r["name"], r["backend"]): r for r in doc["workloads"]}
assert len({n for n, _ in rows}) >= 3, sorted(rows)
add = rows[("add32", "pallas")]
# Structural perf gate (no timing stability needed): the fused 32-bit
# adder must launch fewer kernels than per-op, within its level budget.
assert add["fused"]["dispatches"] < add["per_op"]["dispatches"], add
assert add["fused"]["dispatches"] <= add["n_levels"], add
assert all(r["per_op"]["parity"] and r["fused"]["parity"]
           and r["megakernel"]["parity"] for r in doc["workloads"])
# Energy gate: every row carries CostModel-priced energy; on the pallas
# executor it is positive and ordered megakernel <= fused <= per-op for
# add32 (fewer launches -> fewer joules, the PULSAR amortization story).
for r in doc["workloads"]:
    for m in ("per_op", "fused", "megakernel"):
        assert "energy_nj" in r[m] and r[m]["energy_nj"] >= 0, (r["name"], m)
    assert r["offload"]["pud_energy_nj"] > 0, r["name"]
    assert r["offload"]["winner_energy"] in ("pud", "tpu"), r["name"]
    if r["backend"] == "pallas":
        for m in ("per_op", "fused", "megakernel"):
            assert r[m]["energy_nj"] > 0, (r["name"], m)
add_e = {m: add[m]["energy_nj"] for m in ("per_op", "fused", "megakernel")}
assert 0 < add_e["megakernel"] <= add_e["fused"] <= add_e["per_op"], add_e
print(f"energy gate OK: add32 per-op {add_e['per_op']/1e3:.0f} uJ >= "
      f"fused {add_e['fused']/1e3:.0f} uJ >= megakernel "
      f"{add_e['megakernel']/1e3:.0f} uJ; offload winner_energy "
      f"{add['offload']['winner_energy']}")
# Session compile cache: repeated programs must re-use their schedule.
cc = doc["compile_cache"]
assert cc["hits"] >= 1, cc
print(f"bench gate OK: add32 fused {add['fused']['dispatches']} vs "
      f"per-op {add['per_op']['dispatches']} dispatches "
      f"({add['n_levels']} levels); compile cache {cc['hits']} hits / "
      f"{cc['misses']} misses")

# Megakernel gate: whole-schedule execution must collapse the deep
# workloads (add32: ~34 levels, mul8: ~36) to at most 2 launches, never
# launch more than the level-fused path, and cost no more wall time on
# the smoke sizes; lowered tables must be cache-reused across reps.
for wl in ("add32", "mul8"):
    r = rows[(wl, "pallas")]
    mega, fused = r["megakernel"], r["fused"]
    assert mega["dispatches"] <= 2, (wl, mega)
    assert mega["dispatches"] <= fused["dispatches"], (wl, r)
    assert mega["launch_overhead_ns"] <= fused["launch_overhead_ns"], (wl, r)
    assert mega["parity"], (wl, mega)
    assert mega["vmem"] is not None and mega["vmem"]["block_c"] % 128 == 0
add_mega = rows[("add32", "pallas")]["megakernel"]
add_fused = rows[("add32", "pallas")]["fused"]
assert add_mega["wall_s"] <= add_fused["wall_s"], (add_mega, add_fused)
lc = doc["lowering_cache"]
assert lc["hits"] >= 1, lc
print(f"megakernel gate OK: add32 {add_mega['dispatches']} dispatch "
      f"({add_mega['wall_s']*1e3:.1f} ms vs fused "
      f"{add_fused['wall_s']*1e3:.1f} ms); lowering cache {lc['hits']} "
      f"hits / {lc['misses']} misses")
PY
rm -rf "$BENCH_CI_ROOT"

echo "== serve bench smoke: coalesced batching vs sequential (SLO gate) =="
SERVE_CI_ROOT=$(mktemp -d)
PYTHONPATH=src python -m benchmarks.serve_bench --smoke \
    --out "$SERVE_CI_ROOT/BENCH_serve.json"
PYTHONPATH=src python - "$SERVE_CI_ROOT/BENCH_serve.json" <<'PY'
import json, sys

doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "repro-bench/serve-v2", doc["schema"]
points = {(p["offered"], p["mode"]): p for p in doc["points"]}
loads = sorted({o for o, _ in points})
assert loads, points
# The smoke run exercises the sync-path coalescing window (bugfix:
# tick_window_s used to be honored only on the asyncio path).
assert doc["tick_window_s"] > 0, doc["tick_window_s"]
for o in loads:
    seq, bat = points[(o, "sequential")], points[(o, "batched")]
    # Structural gate (no timing stability needed): coalescing must cut
    # the kernel-dispatch count and actually fill batches.
    assert bat["dispatches"] < seq["dispatches"], \
        (o, bat["dispatches"], seq["dispatches"])
    assert bat["batch_occupancy"] > 1.0, (o, bat["batch_occupancy"])
    # p99 latency must be recorded (non-null) at every point.
    assert seq["p99_ms"] is not None and bat["p99_ms"] is not None, o
    assert seq["shed"] == 0 and bat["shed"] == 0, o
    # Energy gate: present and positive at every point, and coalescing
    # must save joules, not just dispatches.
    assert seq["energy_nj"] > 0 and bat["energy_nj"] > 0, o
    assert seq["energy_per_req_nj"] > 0 and bat["energy_per_req_nj"] > 0, o
    assert bat["energy_nj"] < seq["energy_nj"], \
        (o, bat["energy_nj"], seq["energy_nj"])
    assert all(p["tick_window_s"] == doc["tick_window_s"]
               for p in (seq, bat)), o
# Throughput gate at the smoke load point (largest load; widest margin).
o = loads[-1]
seq, bat = points[(o, "sequential")], points[(o, "batched")]
assert bat["throughput_rps"] >= seq["throughput_rps"], \
    (bat["throughput_rps"], seq["throughput_rps"])
# The batched service must be hitting the shared schedule cache.
assert bat["cache"]["hit_rate"] > 0, bat["cache"]
print(f"serve gate OK: load {o} batched {bat['throughput_rps']:.0f} req/s"
      f" / {bat['dispatches']} dispatches / "
      f"{bat['energy_per_req_nj']/1e3:.0f} uJ/req vs sequential "
      f"{seq['throughput_rps']:.0f} req/s / {seq['dispatches']} / "
      f"{seq['energy_per_req_nj']/1e3:.0f} uJ/req; "
      f"occupancy {bat['batch_occupancy']:.1f}, cache hit rate "
      f"{bat['cache']['hit_rate']*100:.0f}%, tick window "
      f"{doc['tick_window_s']*1e3:.0f} ms")
PY
rm -rf "$SERVE_CI_ROOT"

echo "== analyzer gate: certify goldens/serve/sweep + seeded mutations =="
# --all = positive certification of every golden fixture, serve tick
# program, and sweep chunk program; the negative gate (every applicable
# seeded table corruption must be REJECTED); and the certificate-cache
# check (repeat certification of a cached program is a pure hit — zero
# re-analysis).  Nonzero exit on any hole.
PYTHONPATH=src python -m repro.analyze --all

echo "== docs check (module paths in docs/*.md resolve) =="
python scripts/check_docs.py

echo "== quickstart smoke (session API end-to-end) =="
PYTHONPATH=src python examples/quickstart.py

# This smoke deliberately exercises the raw registry (get_backend), the
# compat layer under repro.session — it is the one place outside tests
# that should keep doing so.
echo "== backend-parity smoke (oracle / sim / pallas) =="
PYTHONPATH=src python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.backends import ExecutionContext, available_backends, get_backend
from repro.pud.isa import Program

rng = np.random.default_rng(0)
ideal = ExecutionContext(ideal=True)
backends = {n: get_backend(n, ideal) for n in ("oracle", "sim", "pallas")}
ref = backends["oracle"]

for x in (3, 5, 7, 9):
    planes = jnp.asarray(rng.integers(0, 2**32, (x, 2, 24), dtype=np.uint32))
    want = np.asarray(ref.majx(planes))
    for n, be in backends.items():
        assert (np.asarray(be.majx(planes, n_act=32)) == want).all(), (n, x)

src = jnp.asarray(rng.integers(0, 2**32, (24,), dtype=np.uint32))
for n_dst in (1, 7, 31):
    want = np.asarray(ref.rowcopy(src, n_dst))
    for n, be in backends.items():
        assert (np.asarray(be.rowcopy(src, n_dst)) == want).all(), (n, n_dst)

prog = Program()
prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
prog.emit("MRC", n_act=4, srcs=(3,), dsts=(4, 5, 6))
state = jnp.asarray(rng.integers(0, 2**32, (7, 8), dtype=np.uint32))
want = np.asarray(ref.run(prog, state))
for n, be in backends.items():
    assert (np.asarray(be.run(prog, state)) == want).all(), n

a = rng.integers(0, 2**32, 8, dtype=np.uint32)
b = rng.integers(0, 2**32, 8, dtype=np.uint32)
for n, be in backends.items():
    out, _ = be.elementwise("add", a, b, tier=5, n_act=32)
    assert (np.asarray(out) == (a + b).astype(np.uint32)).all(), n

print(f"backend parity OK across {sorted(backends)} "
      f"(registry: {available_backends()})")
PY

echo "CI OK"
