#!/usr/bin/env python
"""Docs consistency check: code references in docs/*.md must resolve.

Scans every fenced code block and inline code span in ``docs/*.md`` (and
README.md), plus the *module docstrings* of ``examples/*.py`` and
``benchmarks/*.py`` (they are user-facing documentation too), for

* module paths (``repro.sweep.runner``, ``repro.dist.sharding.foo`` —
  attribute tails are stripped by retrying shorter prefixes), and
* repo file paths (``src/repro/sweep/spec.py``, ``scripts/ci.sh``, ...)

and fails listing every reference that does not resolve to a real file
under the repo.  It also cross-checks the ``repro-bench/*`` result
schema ids three ways: every id mentioned in the docs or gated by
``scripts/ci.sh`` must be one a benchmark script actually writes (a
``SCHEMA = "repro-bench/..."`` assignment), and every written id must
appear in both — so a schema bump that forgets ``docs/BENCH.md`` or
the CI gate fails here instead of surprising a downstream consumer.
Keeps the docs layer honest as modules move: CI runs this after the
test suite (see ``scripts/ci.sh``).
"""

from __future__ import annotations

import ast
import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

FENCE_RE = re.compile(r"```.*?```", re.S)
INLINE_RE = re.compile(r"`([^`\n]+)`")
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+")
PATH_RE = re.compile(
    r"\b(?:src|docs|scripts|tests|benchmarks|results|examples)"
    r"/[\w./-]+\.(?:py|md|sh|json|toml)\b")
SCHEMA_RE = re.compile(r"\brepro-bench/[a-z0-9-]+\b")
SCHEMA_DEF_RE = re.compile(r"^SCHEMA\s*=\s*[\"'](repro-bench/[a-z0-9-]+)",
                           re.M)


def code_regions(text: str):
    for m in FENCE_RE.finditer(text):
        yield m.group(0)
    without_fences = FENCE_RE.sub("", text)
    for m in INLINE_RE.finditer(without_fences):
        yield m.group(1)


def module_resolves(dotted: str) -> bool:
    """True if some prefix of ``dotted`` maps to a file under src/."""
    parts = dotted.split(".")
    while parts:
        rel = os.path.join(SRC, *parts)
        if os.path.isfile(rel + ".py") or \
                os.path.isfile(os.path.join(rel, "__init__.py")):
            return True
        parts = parts[:-1]
    return False


def module_docstring(path: str) -> str:
    """A script's module docstring, or "" when absent/unparseable."""
    with open(path) as f:
        try:
            tree = ast.parse(f.read())
        except SyntaxError:
            return ""
    return ast.get_docstring(tree) or ""


def check_schema_ids() -> tuple[list[str], int]:
    """Cross-check repro-bench/* ids: docs and CI vs bench-script writers.

    Three-way consistency: every id the docs (or the ``scripts/ci.sh``
    bench gates) reference must be one a benchmark actually writes, and
    every written id must appear in both — so a schema bump that
    forgets ``docs/BENCH.md`` or the CI gate's ``assert doc["schema"]``
    fails here instead of surprising a downstream consumer.
    """
    written: set[str] = set()
    for path in sorted(glob.glob(os.path.join(REPO, "benchmarks", "*.py"))):
        with open(path) as f:
            written.update(SCHEMA_DEF_RE.findall(f.read()))
    documented: set[str] = set()
    for path in sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))) + \
            [os.path.join(REPO, "README.md")]:
        with open(path) as f:
            documented.update(SCHEMA_RE.findall(f.read()))
    with open(os.path.join(REPO, "scripts", "ci.sh")) as f:
        gated = set(SCHEMA_RE.findall(f.read()))
    problems = [f"docs mention schema {s!r} that no benchmark writes"
                for s in sorted(documented - written)]
    problems += [f"benchmarks write schema {s!r} never documented in "
                 f"docs/*.md" for s in sorted(written - documented)]
    problems += [f"scripts/ci.sh gates on schema {s!r} that no benchmark "
                 f"writes" for s in sorted(gated - written)]
    problems += [f"benchmarks write schema {s!r} that scripts/ci.sh never "
                 f"gates on" for s in sorted(written - gated)]
    return problems, len(written | documented | gated)


def main() -> int:
    docs = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    docs.append(os.path.join(REPO, "README.md"))
    scripts = sorted(glob.glob(os.path.join(REPO, "examples", "*.py"))
                     + glob.glob(os.path.join(REPO, "benchmarks", "*.py")))
    bad: list[tuple[str, str]] = []
    n_refs = 0

    def scan(rel: str, region: str) -> None:
        nonlocal n_refs
        for mod in MODULE_RE.findall(region):
            n_refs += 1
            if not module_resolves(mod):
                bad.append((rel, mod))
        for p in PATH_RE.findall(region):
            if "*" in p:
                continue  # glob examples
            n_refs += 1
            if not os.path.isfile(os.path.join(REPO, p)):
                bad.append((rel, p))

    for path in docs:
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, REPO)
        for region in code_regions(text):
            scan(rel, region)
    for path in scripts:
        # Module docstrings are documentation: references must resolve
        # the same way doc-file references do.
        scan(os.path.relpath(path, REPO), module_docstring(path))

    schema_problems, n_schemas = check_schema_ids()
    if bad or schema_problems:
        print("unresolved doc references:")
        for doc, ref in sorted(set(bad)):
            print(f"  {doc}: {ref}")
        for msg in schema_problems:
            print(f"  {msg}")
        return 1
    print(f"docs check OK ({n_refs} code references across "
          f"{len(docs)} doc files + {len(scripts)} script docstrings "
          f"resolve; {n_schemas} bench schema id(s) consistent)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
