#!/usr/bin/env python
"""Docs consistency check: code references in docs/*.md must resolve.

Scans every fenced code block and inline code span in ``docs/*.md`` (and
README.md) for

* module paths (``repro.sweep.runner``, ``repro.dist.sharding.foo`` —
  attribute tails are stripped by retrying shorter prefixes), and
* repo file paths (``src/repro/sweep/spec.py``, ``scripts/ci.sh``, ...)

and fails listing every reference that does not resolve to a real file
under the repo.  Keeps the docs layer honest as modules move: CI runs
this after the test suite (see ``scripts/ci.sh``).
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

FENCE_RE = re.compile(r"```.*?```", re.S)
INLINE_RE = re.compile(r"`([^`\n]+)`")
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+")
PATH_RE = re.compile(
    r"\b(?:src|docs|scripts|tests|benchmarks|results|examples)"
    r"/[\w./-]+\.(?:py|md|sh|json|toml)\b")


def code_regions(text: str):
    for m in FENCE_RE.finditer(text):
        yield m.group(0)
    without_fences = FENCE_RE.sub("", text)
    for m in INLINE_RE.finditer(without_fences):
        yield m.group(1)


def module_resolves(dotted: str) -> bool:
    """True if some prefix of ``dotted`` maps to a file under src/."""
    parts = dotted.split(".")
    while parts:
        rel = os.path.join(SRC, *parts)
        if os.path.isfile(rel + ".py") or \
                os.path.isfile(os.path.join(rel, "__init__.py")):
            return True
        parts = parts[:-1]
    return False


def main() -> int:
    docs = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    docs.append(os.path.join(REPO, "README.md"))
    bad: list[tuple[str, str]] = []
    n_refs = 0
    for path in docs:
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, REPO)
        for region in code_regions(text):
            for mod in MODULE_RE.findall(region):
                n_refs += 1
                if not module_resolves(mod):
                    bad.append((rel, mod))
            for p in PATH_RE.findall(region):
                if "*" in p:
                    continue  # glob examples
                n_refs += 1
                if not os.path.isfile(os.path.join(REPO, p)):
                    bad.append((rel, p))
    if bad:
        print("unresolved doc references:")
        for doc, ref in sorted(set(bad)):
            print(f"  {doc}: {ref}")
        return 1
    print(f"docs check OK ({n_refs} code references across "
          f"{len(docs)} files resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
