"""Perf-iteration harness: lower one cell with config overrides and print
the roofline terms + top collectives (the §Perf hypothesis loop tool).

  PYTHONPATH=src python results/hillclimb.py --arch chatglm3-6b \
      --shape train_4k --microbatches 4 --set remat=dots
  PYTHONPATH=src python results/hillclimb.py --arch mixtral-8x22b \
      --shape decode_32k --serve-rules
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import re
import time

from repro.configs.registry import SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh


def top_collectives(txt, trips, n=8):
    rows = []
    for line in txt.splitlines():
        m = re.search(r'= (\(?[a-z0-9]+\[[0-9,]*\])[^ ]* '
                      r'(all-reduce|all-gather|all-to-all|reduce-scatter|'
                      r'collective-permute)\(', line)
        if not m or "-done(" in line:
            continue
        shp, kind = m.group(1).lstrip("("), m.group(2)
        dt = shp.split("[")[0]
        dims = shp.split("[")[1].rstrip("]")
        nelem = 1
        for d in dims.split(","):
            if d:
                nelem *= int(d)
        b = nelem * {"bf16": 2, "f32": 4, "u32": 4, "s32": 4}.get(dt, 4)
        opn = re.search(r'op_name="([^"]*)"', line)
        depth = opn.group(1).count("while/") if opn else 0
        mult = 1
        for t in trips[:depth]:
            mult *= t
        rows.append((b * mult, kind, shp,
                     (opn.group(1)[-70:] if opn else "")))
    rows.sort(reverse=True)
    return rows[:n]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--serve-rules", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value")
    ap.add_argument("--show-collectives", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    for kv in args.set:
        k, v = kv.split("=")
        field = {f.name: f for f in dataclasses.fields(cfg)}[k]
        typ = field.type if callable(field.type) else type(getattr(cfg, k))
        cast = type(getattr(cfg, k))
        val = cast(v) if cast is not bool else v.lower() in ("1", "true")
        cfg = dataclasses.replace(cfg, **{k: val})
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multipod)
    mb = args.microbatches if shape.kind == "train" else 1
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape, mesh, microbatches=mb,
                                   serve_rules=args.serve_rules)
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    trips = [max(cfg.n_layers, 1)] if mb == 1 else [mb, max(cfg.n_layers, 1)]
    coll = rl.collective_bytes(txt, loop_trips=trips)
    ca = compiled.cost_analysis()
    mem_total = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30
    print(f"cell={args.arch}/{args.shape} mb={mb} "
          f"serve_rules={args.serve_rules} overrides={args.set}")
    print(f"compile={dt:.0f}s mem={mem_total:.2f}GB "
          f"(arg {mem.argument_size_in_bytes/2**30:.2f} temp "
          f"{mem.temp_size_in_bytes/2**30:.2f})")
    print(f"flops/chip={ca.get('flops', 0):.3e} "
          f"bytes/chip={ca.get('bytes accessed', 0):.3e}")
    print(f"collectives: total={coll.total_bytes/2**30:.2f}GB "
          f"t_coll={coll.total_bytes/rl.ICI_BW:.3f}s "
          f"by kind={ {k: round(v/2**30, 2) for k, v in coll.bytes_by_kind.items()} }")
    if args.show_collectives:
        for b, kind, shp, opn in top_collectives(txt, trips):
            print(f"  {b/2**30:8.2f}GB {kind:14s} {shp:26s} ...{opn}")


if __name__ == "__main__":
    main()
