"""Generate the EXPERIMENTS.md tables from results artifacts.

Sources: the dry-run/roofline JSONs under ``results/`` and the
characterization record stores under ``results/sweeps/`` (written by
``python -m repro.sweep.run``; see docs/SWEEPS.md).  The sweep section
is reduced entirely through :mod:`repro.sweep.aggregate` — no per-point
loops live here.
"""
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(HERE, "..", "src"))

SCAFFOLD = """# EXPERIMENTS

## Characterization sweeps

<!-- SWEEP_TABLE -->

## Dry-run

<!-- DRYRUN_TABLE -->

## Roofline

<!-- ROOFLINE_TABLE -->

## Serve rules

<!-- SERVE_TABLE -->
"""


def load(name):
    p = os.path.join(HERE, name)
    return json.load(open(p)) if os.path.exists(p) else []


def sweep_table():
    """One row per stored campaign: grid size + headline aggregates."""
    from repro.sweep import aggregate, default_root, discover

    root = default_root()
    lines = ["| sweep | op | backends | points | mean success | headline |",
             "|---|---|---|---|---|---|"]
    n = 0
    for spec, store in discover(root):
        recs = store.records()
        if not recs:
            continue
        n += 1
        mean = aggregate.mean_success(recs)
        head = "; ".join(f"{k}={v:+.4f}"
                         for k, v in aggregate.headline(recs).items())
        lines.append(
            f"| {spec.name} | {spec.op} | {','.join(spec.backends)} | "
            f"{len(recs)}/{spec.n_points()} | {mean:.4f} | {head or '—'} |")
    return "\n".join(lines) if n else "(no sweep records under " + root + ")"


def fmt(x, nd=3):
    if x == 0:
        return "0"
    return f"{x:.2e}" if (abs(x) < 1e-3 or abs(x) >= 1e4) else f"{x:.{nd}f}"


def main():
    single = load("dryrun_single.json")
    multi = load("dryrun_multi.json")
    serve = load("dryrun_serve.json")

    multi_status = {(r["arch"], r["shape"]): r for r in multi}
    serve_by = {(r["arch"], r["shape"]): r for r in serve
                if r.get("status") == "ok"}

    # ---- dry-run table: per cell, both meshes
    lines = ["| arch | shape | 16x16 mem/chip | 2x16x16 mem/chip | status |",
             "|---|---|---|---|---|"]
    order = sorted({(r["arch"], r["shape"]) for r in single},
                   key=lambda t: (t[0], t[1]))
    for arch, shape in order:
        r1 = next(r for r in single if (r["arch"], r["shape"]) == (arch, shape))
        r2 = multi_status.get((arch, shape), {})
        if r1.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | skipped: "
                         f"{r1['reason'][:60]} |")
            continue
        m1 = f"{r1['memory']['total_gb']:.1f} GB" if r1.get("status") == "ok" else "ERR"
        m2 = (f"{r2['memory']['total_gb']:.1f} GB"
              if r2.get("status") == "ok" else r2.get("status", "—"))
        lines.append(f"| {arch} | {shape} | {m1} | {m2} | compiled |")
    dryrun_table = "\n".join(lines)

    # ---- roofline table (single-pod)
    lines = ["| arch | shape | t_compute | t_memory | t_collective | bound |"
             " frac | MODEL_FLOPS | MODEL/HLO | moved-by |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    hints = {
        "collective": "less wire traffic: bf16 collectives (2x on TPU), "
                      "fewer regathers",
        "memory": "smaller dtypes / fewer remat passes",
        "compute": "higher MXU utilization (already near bound)",
    }
    for arch, shape in order:
        r = next(r for r in single if (r["arch"], r["shape"]) == (arch, shape))
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {fmt(rl['t_compute_s'])} | "
            f"{fmt(rl['t_memory_s'])} | {fmt(rl['t_collective_s'])} | "
            f"{rl['bottleneck']} | {rl['roofline_fraction']:.3f} | "
            f"{fmt(rl['model_flops'])} | {rl['hlo_efficiency']:.2f} | "
            f"{hints[rl['bottleneck']]} |")
    roofline_table = "\n".join(lines)

    # ---- serve-rules comparison
    lines = ["| arch | shape | baseline t_coll | SERVE_RULES t_coll | gain |",
             "|---|---|---|---|---|"]
    for arch, shape in order:
        if (arch, shape) not in serve_by:
            continue
        r0 = next(r for r in single if (r["arch"], r["shape"]) == (arch, shape))
        if r0.get("status") != "ok":
            continue
        t0 = r0["roofline"]["t_collective_s"]
        t1 = serve_by[(arch, shape)]["roofline"]["t_collective_s"]
        if t1 > 0:
            lines.append(f"| {arch} | {shape} | {fmt(t0)} s | {fmt(t1)} s | "
                         f"{t0/t1:.1f}x |")
    serve_table = "\n".join(lines)

    p = os.path.join(HERE, "..", "EXPERIMENTS.md")
    text = open(p).read() if os.path.exists(p) else SCAFFOLD
    if "<!-- SWEEP_TABLE -->" not in text:
        # A previous run consumed the markers; regenerate from the
        # scaffold so re-runs refresh tables instead of silently no-oping.
        text = SCAFFOLD
    text = text.replace("<!-- SWEEP_TABLE -->", sweep_table())
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table)
    text = text.replace("<!-- SERVE_TABLE -->", serve_table)
    open(p, "w").write(text)
    print("tables injected")


if __name__ == "__main__":
    main()
