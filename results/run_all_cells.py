"""Driver: run every (arch x shape) dry-run cell sequentially as
subprocesses (fresh device state each), with per-arch microbatches,
merging results into one JSON.

Before launching cells it runs a PUD-backend preflight: a tiny
`repro.sweep` campaign (MAJX + Multi-RowCopy grids, ideal contexts) of
the configured execution backend (PUD_BACKEND env or --pud-backend,
default "pallas"), whose per-point records must all show success 1.0
against the oracle reference — so a bad backend choice fails in seconds
rather than after hours of compiles."""
import json, os, subprocess, sys, tempfile, time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def pud_preflight(backend_name: str) -> None:
    sys.path.insert(0, os.path.join(REPO, "src"))
    import shutil
    from repro.sweep import presets, run_sweep

    # A fresh store each invocation: a cached preflight checks nothing.
    root = tempfile.mkdtemp(prefix="pud_preflight_")
    try:
        for spec in presets.preflight_specs(backend_name):
            result = run_sweep(spec, root)
            bad = [r for r in result.records if r["success"] < 1.0]
            assert not bad, (
                f"backend '{backend_name}' lost parity vs oracle on "
                f"{spec.op} points: "
                f"{[(r['x'], r['n_act'], r['success']) for r in bad]}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    print(f"[preflight] backend '{backend_name}' sweep parity vs oracle OK",
          flush=True)
ARCHS = ["mixtral-8x22b", "qwen3-moe-235b-a22b", "chatglm3-6b", "gemma-7b",
         "deepseek-coder-33b", "glm4-9b", "zamba2-1.2b", "musicgen-medium",
         "xlstm-125m", "phi-3-vision-4.2b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MB = {"mixtral-8x22b": 8, "qwen3-moe-235b-a22b": 8}

def main():
    multipod = "--multipod" in sys.argv
    skip_cost = "--skip-cost" in sys.argv
    backend = os.environ.get("PUD_BACKEND", "pallas")
    args = sys.argv[1:]
    if "--pud-backend" in args:
        i = args.index("--pud-backend")
        if i + 1 >= len(args):
            sys.exit("--pud-backend requires a backend name")
        backend = args[i + 1]
        del args[i:i + 2]
    # out_path: first non-flag argument, wherever the flags sit —
    # validated *before* the preflight so usage errors fail instantly.
    out_path = next((a for a in args if not a.startswith("--")), None)
    if out_path is None:
        sys.exit("usage: run_all_cells.py OUT_JSON [--pud-backend NAME] "
                 "[--multipod] [--skip-cost] [--serve-rules]")
    pud_preflight(backend)
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"]) for r in results if r.get("status") in ("ok", "skipped")}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for arch in ARCHS:
        for shape in SHAPES:
            if (arch, shape) in done:
                continue
            cell_out = f"/tmp/cell_{arch}_{shape}.json"
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape,
                   "--microbatches", str(MB.get(arch, 4)),
                   "--out", cell_out]
            if multipod:
                cmd.append("--multipod")
            if skip_cost:
                cmd.append("--skip-cost")
            if "--serve-rules" in sys.argv:
                cmd.append("--serve-rules")
            if os.environ.get("ONLY_KINDS"):
                from_kind = {"train_4k": "train", "prefill_32k": "prefill",
                             "decode_32k": "decode", "long_500k": "decode"}
                if from_kind[shape] not in os.environ["ONLY_KINDS"]:
                    continue
            t0 = time.time()
            p = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                               text=True, timeout=2400)
            try:
                res = json.load(open(cell_out))
                results.extend(res)
                r = res[0]
                status = r.get("status")
                extra = ""
                if status == "ok":
                    extra = (f"mem={r['memory']['total_gb']:.1f}GB "
                             f"bound={r['roofline']['bottleneck']}")
                print(f"[{arch} {shape}] {status} {extra} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                print(f"[{arch} {shape}] FAILED rc={p.returncode}: "
                      f"{p.stderr[-400:]}", flush=True)
                results.append({"arch": arch, "shape": shape,
                                "status": "error", "error": p.stderr[-500:]})
            json.dump(results, open(out_path, "w"), indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    print(f"TOTAL: {n_ok} ok, {n_skip} skipped, "
          f"{len(results)-n_ok-n_skip} failed")

if __name__ == "__main__":
    main()
