"""Batched serving with continuous batching on a smoke-size Gemma.

Usage:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("gemma-7b", smoke=True)
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, max_seq=96)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        plen = 12 if i % 2 else 16  # mixed prompt lengths
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32),
            max_new_tokens=12))

    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} new tokens in {dt:.1f}s")
    for r in done[:3]:
        print(f"  req {r.rid} ({len(r.prompt)}-token prompt): "
              f"{[int(t) for t in r.out_tokens[:6]]}...")


if __name__ == "__main__":
    main()
