"""Batched serving with continuous batching on a smoke-size Gemma,
with the engine's PUD integrity hook healing a corrupted parameter
replica (majority vote through the configured execution backend)
before any traffic is served.

Usage:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.pud.tmr import corrupt
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("gemma-7b", smoke=True)
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    # backend is a one-string config choice: "pallas" | "oracle" | "sim"
    engine = Engine(params, cfg, max_seq=96, pud_backend="pallas")

    # PUD hook: one replica suffers silent data corruption; the engine
    # majority-votes the three replicas back to health in-place.
    key = jax.random.PRNGKey(7)
    bad = jax.tree.map(
        lambda x: corrupt(x, jax.random.fold_in(key, x.size), 1e-5), params)
    fixed = engine.heal_params([bad, params, params])
    ok = engine.verify_params(params)
    d = engine.pud_decisions[-1]
    print(f"[pud] healed {fixed} corrupted bits; param integrity "
          f"{ok*100:.4f}%; planner says bulk votes run on '{d.winner}' "
          f"({d.speedup:.1f}x)")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        plen = 12 if i % 2 else 16  # mixed prompt lengths
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32),
            max_new_tokens=12))

    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} new tokens in {dt:.1f}s")
    for r in done[:3]:
        print(f"  req {r.rid} ({len(r.prompt)}-token prompt): "
              f"{[int(t) for t in r.out_tokens[:6]]}...")


if __name__ == "__main__":
    main()
