"""End-to-end training driver: a ~100M-parameter xLSTM for a few hundred
steps on the synthetic pipeline, with checkpoint-restart, an injected
node failure, and TMR-protected checkpoints.

This is the CPU-scale twin of `python -m repro.launch.train`; on a real
cluster the same Trainer drives the full configs.

Usage:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
(~100M params is slow on CPU; --small trains the smoke config instead.)
"""

import argparse
import tempfile

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.failures import FailurePlan
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true",
                    help="train the reduced config (fast CPU demo)")
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()

    if args.small:
        cfg = get_config("xlstm-125m", smoke=True)
        batch, seq = 8, 64
    else:
        # ~100M-param xLSTM (the assigned xlstm-125m config itself)
        cfg = get_config("xlstm-125m")
        batch, seq = 4, 128

    n = cfg.n_params()
    print(f"[example] training {cfg.name}: ~{n/1e6:.0f}M params, "
          f"{args.steps} steps, batch {batch} x seq {seq}")

    tc = TrainConfig(lr=1e-3, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1))
    loader = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    global_batch=batch))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg, tc, loader,
            TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, tmr_replicas=3,
                          log_every=20),
            failure_plan=FailurePlan(at_steps=(args.fail_at,)),
        )
        hist = trainer.run(args.steps)
    losses = [h["loss"] for h in hist]
    print(f"[example] loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(survived 1 injected node failure via checkpoint-restart)")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
