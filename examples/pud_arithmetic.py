"""The §8.1 case study as a runnable example: the seven majority-based
microbenchmarks across MAJX tiers, with the calibrated latency model —
reproducing the structure of the paper's Fig. 16.

Usage:  PYTHONPATH=src python examples/pud_arithmetic.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_figures import _microbench_time_ns
from repro.core import calibration as cal
from repro.session import DramSession

#: one-string backend choice ("oracle" compiles/computes the programs;
#: swap for "pallas" or "sim" to execute the same gates elsewhere).
BACKEND = "oracle"


def main():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**32, 32, dtype=np.uint32)
    b = np.maximum(rng.integers(0, 2**32, 32, dtype=np.uint32), 1)
    backend = DramSession(BACKEND)

    print("op   tier  DRAM-ops   exact   modeled-us")
    for op in cal.MICROBENCHMARKS:
        for tier in (3, 5, 7):
            out, prog = backend.elementwise(op, a, b, tier=tier,
                                            n_act=32 if tier > 3 else 4)
            ref = {"and": a & b, "or": a | b, "xor": a ^ b,
                   "add": (a + b).astype(np.uint32),
                   "sub": (a - b).astype(np.uint32),
                   "mul": (a * b).astype(np.uint32),
                   "div": a // b}[op]
            exact = bool((np.asarray(out) == ref).all())
            t_us = _microbench_time_ns(op, "H", tier) / 1e3
            print(f"{op:5s} MAJ{tier}  {len(prog.ops):7d}   {exact}   "
                  f"{t_us:10.1f}")
    print("\nFig.16-style speedups over the MAJ3@4-row baseline:")
    for mfr in ("M", "H"):
        tiers = (5, 7) if mfr == "M" else (5, 7, 9)
        for t in tiers:
            sp = [(_microbench_time_ns(op, mfr, 3)
                   / _microbench_time_ns(op, mfr, t))
                  for op in cal.MICROBENCHMARKS]
            print(f"  Mfr {mfr} MAJ{t}: avg {np.mean(sp):.2f}x "
                  f"(paper: M +121.6%/H +46.5% avg for the new MAJX ops)")


if __name__ == "__main__":
    main()
