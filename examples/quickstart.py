"""Quickstart: the paper's PUD operations through `repro.session`.

One typed :class:`DramSession` per executor — the session owns the
backend + `ExecutionContext`, hands out validated row handles, lowers
programs through `repro.compile` automatically, and caches fused
schedules by program content:

  * ``oracle``  pure bitwise reference (ground truth),
  * ``sim``     behavioural DRAM model with the calibrated error surfaces,
  * ``pallas``  bulk TPU kernels (interpret mode on CPU).

Runs in ~30s on CPU:
  1. simultaneous many-row activation success (calibrated model),
  2. MAJ5 with input replication on every backend — identical results
     when ideal, paper-calibrated success rates when not (Obs 10),
  3. Multi-RowCopy 1 -> 31 parity across backends,
  4. a typed session program (row handles, build-time validation)
     executed by all three backends + its latency/energy under the
     calibrated model — and what a bad row address looks like,
  5. majority-based 32-bit addition per session, showing the compile
     cache turning a repeated program into a schedule-cache hit.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionContext, available_backends
from repro.core import calibration as cal
from repro.core.errormodel import ErrorModel
from repro.session import DramSession, SessionError

BACKENDS = ("oracle", "sim", "pallas")


def main():
    rng = np.random.default_rng(0)
    ideal = ExecutionContext(ideal=True)
    sessions = {n: DramSession(n, ideal) for n in BACKENDS}

    # 1) simultaneous many-row activation -------------------------------
    em = ErrorModel("H")
    print("== SiMRA: N-row activation success (calibrated to Obs 1) ==")
    for n in cal.N_ACT_LEVELS:
        print(f"  {n:2d}-row activation: {em.simra_success(n)*100:.2f}%")

    # 2) MAJ5 with input replication across backends ---------------------
    planes = jnp.asarray(rng.integers(0, 2**32, (5, 32), dtype=np.uint32))
    want = sessions["oracle"].majx(planes)
    print(f"\n== MAJ5 on every backend (registry: {available_backends()}) ==")
    for name, sess in sessions.items():
        got = sess.majx(planes, n_act=32)
        print(f"  {name:7s} (ideal): bit-exact={bool((got == want).all())}")
    for n_act in (8, 32):
        sim = DramSession("sim", ExecutionContext(seed=1))
        acc = sim.success_rate(sim.majx(planes, n_act=n_act), want)
        print(f"  sim MAJ5 @ {n_act:2d}-row activation: measured "
              f"{acc*100:.1f}% (model {em.majx_success(5, n_act)*100:.1f}%, "
              f"Obs 10 replication gain)")

    # 3) Multi-RowCopy ----------------------------------------------------
    src = jnp.asarray(rng.integers(0, 2**32, (32,), dtype=np.uint32))
    copies = {n: s.rowcopy(src, 31) for n, s in sessions.items()}
    ok = all(bool((c == src).all()) for c in copies.values())
    print(f"\n== Multi-RowCopy 1 -> 31 on all backends, bit-exact={ok} ==")

    # 4) one typed session program, three executors -----------------------
    b = sessions["oracle"].program(rows=12, name="quickstart-demo")
    ops = b.input(rng.integers(0, 2**32, (3, 8), dtype=np.uint32))
    vote = b.maj(ops[0], ops[1], ops[2], n_act=4, tag="demo/vote")
    flip = b.not_(vote, tag="demo/flip")
    b.mrc(flip, 7, tag="demo/fanout")
    prog, state = b.build(), b.initial_state()
    finals = [np.asarray(s.run_fused(prog, state))
              for s in sessions.values()]
    agree = all((f == finals[0]).all() for f in finals)
    print(f"\n== typed Program({len(prog.ops)} ops) via "
          f"{'/'.join(BACKENDS)}: states agree={agree}; "
          f"{prog.latency_ns(em):.0f} ns / {prog.energy_nj(em):.0f} nJ "
          f"modeled ==")
    try:  # the allocator catches bad programs before any kernel runs
        b.mrc(flip, b.alloc_rows(7))
    except SessionError as e:
        print(f"  build-time validation: {e}")

    # 5) majority-based arithmetic (§8.1), compile-cached per session ----
    a = rng.integers(0, 2**32, 64, dtype=np.uint32)
    c = rng.integers(0, 2**32, 64, dtype=np.uint32)
    print()
    for name, sess in sessions.items():
        out, prog = sess.elementwise("add", a, c, tier=5, n_act=32)
        if sess.capabilities().native_batch:
            # repeat the fused path: the schedule comes from the cache
            out, prog = sess.elementwise("add", a, c, tier=5, n_act=32)
        assert (np.asarray(out) == (a + c).astype(np.uint32)).all(), name
        lat_us = prog.latency_ns(em, pipelined=True, best_group=True) / 1e3
        stats = sess.cache.stats
        print(f"  32-bit ADD via {name:7s}: {len(prog.ops)} DRAM ops, "
              f"{lat_us:.1f} us modeled, bit-exact vs numpy; compile "
              f"cache {stats.hits} hits / {stats.misses} misses")

    print("\nquickstart OK")


if __name__ == "__main__":
    main()
