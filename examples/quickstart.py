"""Quickstart: the paper's PUD operations through the public API.

Runs in ~30s on CPU:
  1. simultaneous many-row activation on the behavioural DRAM model,
  2. MAJ5 with input replication (the paper's headline capability),
  3. Multi-RowCopy 1 -> 31,
  4. majority-based 32-bit addition compiled to a PUD program + its
     latency/energy under the calibrated model,
  5. the same majority logic as a TPU Pallas kernel (interpret mode).

Usage:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as cal
from repro.core import majx, rowcopy
from repro.core.errormodel import ErrorModel
from repro.core.subarray import Subarray
from repro.kernels.majx.ops import majx as majx_kernel
from repro.pud.arith import run_elementwise


def main():
    rng = np.random.default_rng(0)

    # 1) simultaneous many-row activation -------------------------------
    sa = Subarray(cols=1024, seed=0)
    em = ErrorModel("H")
    print("== SiMRA: N-row activation success (calibrated to Obs 1) ==")
    for n in cal.N_ACT_LEVELS:
        print(f"  {n:2d}-row activation: {em.simra_success(n)*100:.2f}%")

    # 2) MAJ5 with input replication -------------------------------------
    ops = [jnp.asarray(rng.integers(0, 2**32, 32, dtype=np.uint32))
           for _ in range(5)]
    print("\n== MAJ5: success with/without input replication (Obs 10) ==")
    for n_act in (8, 32):
        sa = Subarray(cols=1024, seed=1)
        acc = majx.majx_success_measured(sa, ops, n_act)
        print(f"  MAJ5 @ {n_act:2d}-row activation: measured {acc*100:.1f}% "
              f"(model {em.majx_success(5, n_act)*100:.1f}%)")

    # 3) Multi-RowCopy ----------------------------------------------------
    sa = Subarray(cols=1024, seed=2, ideal=True)
    src = jnp.asarray(rng.integers(0, 2**32, sa.n_words, dtype=np.uint32))
    _, dests = rowcopy.multi_rowcopy(sa, src, 32)
    ok = all(bool((sa.read_row(d) == src).all()) for d in dests)
    print(f"\n== Multi-RowCopy: 1 source -> {len(dests)} destinations, "
          f"bit-exact={ok} ==")

    # 4) majority-based arithmetic (§8.1) --------------------------------
    a = rng.integers(0, 2**32, 64, dtype=np.uint32)
    b = rng.integers(0, 2**32, 64, dtype=np.uint32)
    out, prog = run_elementwise("add", a, b, tier=5, n_act=32)
    assert (np.asarray(out) == (a + b).astype(np.uint32)).all()
    lat_us = prog.latency_ns(em, pipelined=True, best_group=True) / 1e3
    print(f"\n== PUD 32-bit ADD (MAJ5 construction): {len(prog.ops)} DRAM "
          f"ops, {lat_us:.1f} us modeled, bit-exact vs numpy ==")

    # 5) the TPU-side MAJX kernel -----------------------------------------
    planes = jnp.asarray(rng.integers(0, 2**32, (9, 8, 512), dtype=np.uint32))
    voted = majx_kernel(planes)
    print(f"\n== Pallas MAJ9 kernel over {planes.shape} packed planes: "
          f"out {voted.shape} (interpret mode, CSA bit-sliced counter) ==")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
