"""Quickstart: the paper's PUD operations through the backend registry.

One :class:`Program` / op set, three interchangeable executors behind
``get_backend(name)`` — the paper's central point, as an API:

  * ``oracle``  pure bitwise reference (ground truth),
  * ``sim``     behavioural DRAM model with the calibrated error surfaces,
  * ``pallas``  bulk TPU kernels (interpret mode on CPU).

Runs in ~30s on CPU:
  1. simultaneous many-row activation success (calibrated model),
  2. MAJ5 with input replication on every backend — identical results
     when ideal, paper-calibrated success rates when not (Obs 10),
  3. Multi-RowCopy 1 -> 31 parity across backends,
  4. an addressed PUD Program executed by all three backends + its
     latency/energy under the calibrated model,
  5. majority-based 32-bit addition compiled once, executed per backend.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionContext, available_backends, get_backend
from repro.core import calibration as cal
from repro.core.errormodel import ErrorModel
from repro.pud.isa import Program

BACKENDS = ("oracle", "sim", "pallas")


def main():
    rng = np.random.default_rng(0)
    ideal = ExecutionContext(ideal=True)

    # 1) simultaneous many-row activation -------------------------------
    em = ErrorModel("H")
    print("== SiMRA: N-row activation success (calibrated to Obs 1) ==")
    for n in cal.N_ACT_LEVELS:
        print(f"  {n:2d}-row activation: {em.simra_success(n)*100:.2f}%")

    # 2) MAJ5 with input replication across backends ---------------------
    planes = jnp.asarray(rng.integers(0, 2**32, (5, 32), dtype=np.uint32))
    want = get_backend("oracle").majx(planes)
    print(f"\n== MAJ5 on every backend (registry: {available_backends()}) ==")
    for name in BACKENDS:
        got = get_backend(name, ideal).majx(planes, n_act=32)
        print(f"  {name:7s} (ideal): bit-exact={bool((got == want).all())}")
    for n_act in (8, 32):
        sim = get_backend("sim", ExecutionContext(seed=1))
        acc = sim.success_rate(sim.majx(planes, n_act=n_act), want)
        print(f"  sim MAJ5 @ {n_act:2d}-row activation: measured "
              f"{acc*100:.1f}% (model {em.majx_success(5, n_act)*100:.1f}%, "
              f"Obs 10 replication gain)")

    # 3) Multi-RowCopy ----------------------------------------------------
    src = jnp.asarray(rng.integers(0, 2**32, (32,), dtype=np.uint32))
    copies = {n: get_backend(n, ideal).rowcopy(src, 31) for n in BACKENDS}
    ok = all(bool((c == src).all()) for c in copies.values())
    print(f"\n== Multi-RowCopy 1 -> 31 on all backends, bit-exact={ok} ==")

    # 4) one addressed Program, three executors ---------------------------
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(3,), dsts=(4,))
    prog.emit("MRC", n_act=8, srcs=(4,), dsts=tuple(range(5, 12)))
    state = jnp.asarray(rng.integers(0, 2**32, (12, 8), dtype=np.uint32))
    finals = [np.asarray(get_backend(n, ideal).run(prog, state))
              for n in BACKENDS]
    agree = all((f == finals[0]).all() for f in finals)
    print(f"\n== Program({len(prog.ops)} ops) via "
          f"{'/'.join(BACKENDS)}: states agree={agree}; "
          f"{prog.latency_ns(em):.0f} ns / {prog.energy_nj(em):.0f} nJ "
          f"modeled ==")

    # 5) majority-based arithmetic (§8.1), compiled per backend ----------
    a = rng.integers(0, 2**32, 64, dtype=np.uint32)
    b = rng.integers(0, 2**32, 64, dtype=np.uint32)
    for name in BACKENDS:
        out, prog = get_backend(name, ideal).elementwise(
            "add", a, b, tier=5, n_act=32)
        assert (np.asarray(out) == (a + b).astype(np.uint32)).all(), name
        lat_us = prog.latency_ns(em, pipelined=True, best_group=True) / 1e3
        print(f"  32-bit ADD via {name:7s}: {len(prog.ops)} DRAM ops, "
              f"{lat_us:.1f} us modeled, bit-exact vs numpy")

    print("\nquickstart OK")


if __name__ == "__main__":
    main()
