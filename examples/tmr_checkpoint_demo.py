"""TMR-protected checkpointing: the paper's majority-vote error correction
(§8.1) applied to training state, healing silent data corruption.

Usage:  PYTHONPATH=src python examples/tmr_checkpoint_demo.py
"""

import os
import tempfile

import jax

from repro.configs.registry import get_config
from repro.ckpt import tmr_store
from repro.train.step import init_train_state


def main():
    cfg = get_config("chatglm3-6b", smoke=True)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)

    with tempfile.TemporaryDirectory() as d:
        paths = tmr_store.save(state, d, step=100, replicas=3)
        print(f"[tmr] wrote {len(paths)} replicas")

        # simulate silent data corruption in one replica's payload
        shard = os.path.join(d, "replica_1", "step_00000100", "shard_p0.npz")
        blob = bytearray(open(shard, "rb").read())
        for off in range(len(blob) // 2, len(blob) // 2 + 64):
            blob[off] ^= 0xA5
        open(shard, "wb").write(bytes(blob))
        print("[tmr] corrupted 64 bytes of replica_1 (SDC injection)")

        restored, step, healed = tmr_store.restore(state, d)
        exact = all(
            (jax.numpy.asarray(a) == jax.numpy.asarray(b)).all()
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)))
        print(f"[tmr] restored step {step}: healed {healed} replica(s), "
              f"bit-exact={bool(exact)}")

        n_healed = tmr_store.scrub(state, d)
        print(f"[tmr] scrubber re-replicated {n_healed} corrupted replica(s)")
        _, _, healed2 = tmr_store.restore(state, d)
        print(f"[tmr] post-scrub restore: {healed2} unhealthy replicas")


if __name__ == "__main__":
    main()
