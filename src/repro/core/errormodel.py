"""Calibrated empirical success-rate model for PUD operations.

The paper characterizes the *success rate* — the fraction of DRAM cells that
produce the correct result across all trials — of simultaneous many-row
activation (SiMRA), MAJX, and Multi-RowCopy under timing (t1, t2), data
pattern, temperature, and wordline voltage.  This module is a parametric
surface anchored **exactly** at every operating point the paper reports
(constants from :mod:`repro.core.calibration`) and interpolated elsewhere
with documented model assumptions:

* SiMRA (Fig 3): plateau at >=3 ns; cliff when t2 < 3 ns (Obs 2), scaled by
  log2(N)/log2(8) around the paper's 8-row anchor.
* MAJX (Fig 6): optimum at (t1, t2) = (1.5, 3) ns; success decays as t1+t2
  grows (R_F over-shares, Obs 7 hypothesis 1) with the (3,3) point pinned
  45.50 % below optimum; t2 = 1.5 ns collapses the op (Obs 7 hypothesis 2).
* Replication (Obs 6/10): success interpolates log-linearly in N between the
  unreplicated minimum-N anchor and the 32-row anchor.
* Patterns (Obs 9/16), temperature (Obs 3/11/12/17), VPP (Obs 4/13/18):
  multiplicative adjustments pinned to the reported deltas.

The model also converts success rates into deterministic per-cell *stable
masks* (the paper's metric counts a cell as unusable if it errs once), via a
hash-derived latent threshold per (cell, row-group) pair.
"""

from __future__ import annotations

import dataclasses
import math

import jax

from repro.core import calibration as cal

# ---------------------------------------------------------------------------
# timing surfaces
# ---------------------------------------------------------------------------


def _simra_timing_mult(n_act: int, t1: float, t2: float) -> float:
    """Multiplier vs the (3,3) ns optimum for N-row activation (Fig 3)."""
    if t2 >= 6.0:
        # fn 6: waiting >=6 ns between PRE and ACT degenerates to the
        # consecutive activation of two rows — many-row activation fails.
        return 0.0 if n_act > 2 else 1.0
    scale = math.log2(max(n_act, 2)) / math.log2(cal.SIMRA_OBS2_N)
    mult = 1.0
    if t1 < 3.0 and t2 < 3.0:
        # Obs 2 anchor: (1.5, 1.5) is 21.74 % below best for 8-row.
        mult *= 1.0 - cal.SIMRA_OBS2_DROP_REL * scale
    elif t2 < 3.0:
        # t2=1.5 with relaxed t1: intermediate-signal assertion marginal.
        mult *= 1.0 - 0.5 * cal.SIMRA_OBS2_DROP_REL * scale
    elif t1 < 3.0:
        # t1=1.5, t2=3: near-best (this is the MAJX optimum region).
        mult *= 0.999
    return max(mult, 0.0)


def _majx_timing_mult(t1: float, t2: float) -> float:
    """Multiplier vs the (1.5, 3) ns optimum (Fig 6, Obs 7)."""
    if t2 < 3.0:
        # Hypothesis 2: PRE->ACT too fast to assert intermediate decoder
        # signals; many-row activation mostly fails to engage.
        return 0.30
    if t2 >= 6.0:
        return 0.0  # degenerates to consecutive two-row activation
    # Hypothesis 1: larger t1+t2 lets R_F share disproportionate charge.
    # Pinned: (3,3) => 1/(1+0.4550).
    steps = ((t1 + t2) - (cal.MAJX_BEST_T1_NS + cal.MAJX_BEST_T2_NS)) / 1.5
    return 1.0 / (1.0 + cal.MAJ3_32_BEST_OVER_SECOND_REL * max(steps, 0.0))


def _mrc_timing_mult(n_dest: int, t1: float, t2: float) -> float:
    """Multiplier vs the (36, 3) ns optimum (Fig 10, Obs 14/15)."""
    if t2 >= 6.0 and n_dest > 1:
        # fn 6: consecutive 2-row activation — a plain RowClone; only one
        # destination receives data.
        return 1.0 / n_dest
    # Sense amps need ~tRAS to fully drive bitlines with the source charge.
    t1_curve = {36.0: 1.0, 9.0: 0.97, 6.0: 0.93, 3.0: 0.85}
    if t1 >= 36.0:
        base = 1.0
    elif t1 <= 1.5:
        # Obs 15: 49.79 % below the second-worst configuration (t1=3).
        base = t1_curve[3.0] * (1.0 - cal.MRC_T1_1P5_BELOW_SECOND_WORST_REL)
    else:
        keys = sorted(t1_curve)
        lo = max(k for k in keys if k <= t1)
        hi = min(k for k in keys if k >= t1)
        if lo == hi:
            base = t1_curve[lo]
        else:
            w = (t1 - lo) / (hi - lo)
            base = t1_curve[lo] * (1 - w) + t1_curve[hi] * w
    if t2 < 3.0:
        base *= 0.95
    return base


# ---------------------------------------------------------------------------
# replication interpolation
# ---------------------------------------------------------------------------


def _majx_replication_base(x: int, n_act: int) -> float:
    """Success at best timings / random pattern / 50C / 2.5V (Obs 6/8/10)."""
    n_min = cal.min_activation_for(x)
    if n_act < n_min:
        raise ValueError(f"MAJ{x} needs >= {n_min}-row activation")
    s_min = cal.majx_success_min_activation(x)
    s_max = cal.MAJX_SUCCESS_32ROW[x]
    if n_act >= 32:
        return s_max
    lo, hi = math.log2(n_min), math.log2(32)
    w = (math.log2(n_act) - lo) / (hi - lo)
    return s_min + (s_max - s_min) * w


# ---------------------------------------------------------------------------
# environment adjustments
# ---------------------------------------------------------------------------


def _temp_mult_majx(x: int, n_act: int, temp_c: float) -> float:
    """Obs 11/12: success *rises* with temperature; replication damps it."""
    n_min = cal.min_activation_for(x)
    r = n_act / n_min  # replication factor (1 .. 8)
    # Pinned: MAJ3@4 (r=1) max variation 15.20 %; MAJ3@32 (r=8) 1.65 %.
    lo_amp = cal.MAJ3_TEMP_VARIATION_4ROW_MAX_REL
    hi_amp = cal.MAJ3_TEMP_VARIATION_32ROW_MAX_REL
    expo = math.log(lo_amp / hi_amp) / math.log(8.0)
    amp = lo_amp / (r ** expo)
    return 1.0 + amp * (temp_c - 50.0) / 40.0


def _vpp_mult(kind: str, vpp_v: float) -> float:
    drop = {
        "simra": cal.SIMRA_VPP_DROP_REL_MAX,
        "majx": cal.MAJX_VPP_VARIATION_AVG_REL,
        "mrc": cal.MRC_VPP_DROP_REL_MAX,
    }[kind]
    return 1.0 - drop * (2.5 - vpp_v) / 0.4


def _pattern_mult_majx(x: int, pattern: str) -> float:
    """Obs 9: anchors are the *random* pattern (worst case)."""
    if pattern == "random":
        return 1.0
    if pattern not in cal.DATA_PATTERNS:
        raise ValueError(f"unknown pattern {pattern!r}")
    # Fixed patterns have "a small and similar effect"; 0x00/0xFF pinned.
    fixed_gain = 1.0 / (1.0 - cal.MAJX_RANDOM_BELOW_FIXED_REL[x])
    jitter = {"0x00/0xFF": 1.0, "0xAA/0x55": 0.999, "0xCC/0x33": 0.998,
              "0x66/0x99": 0.9985}[pattern]
    return fixed_gain * jitter


def _pattern_mult_mrc(n_dest: int, pattern: str) -> float:
    """Obs 16: all-1s to 31 rows is 0.79 % lower; otherwise <= 0.11 %."""
    if pattern in ("random", "0x00"):
        return 1.0
    if pattern in ("0xFF", "all1"):
        if n_dest >= 31:
            return 1.0 - cal.MRC_ALL1_31_DROP_REL
        return 1.0 - cal.MRC_PATTERN_MAX_REL_LE15
    return 1.0 - 0.0005


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    """Success-rate surfaces for one manufacturer profile."""

    mfr: str = "H"

    @property
    def anchor(self) -> cal.DeviceAnchor:
        return cal.DEVICE_ANCHORS[self.mfr]

    # -- SiMRA -------------------------------------------------------------
    def simra_success(
        self, n_act: int, t1: float = cal.SIMRA_BEST_T1_NS,
        t2: float = cal.SIMRA_BEST_T2_NS, temp_c: float = 50.0,
        vpp_v: float = 2.5,
    ) -> float:
        if not self.anchor.supports_simra:
            return 0.0  # §9 Limitation 1 (Samsung)
        if n_act not in cal.SIMRA_SUCCESS_BEST:
            raise ValueError(f"N={n_act} not reachable (Limitation 2)")
        s = cal.SIMRA_SUCCESS_BEST[n_act]
        s *= _simra_timing_mult(n_act, t1, t2)
        # Obs 3: -0.07 % from 50C to 90C.
        s *= 1.0 - cal.SIMRA_TEMP_DROP_REL_50_TO_90 * (temp_c - 50.0) / 40.0
        s *= _vpp_mult("simra", vpp_v)
        return float(min(max(s, 0.0), 1.0))

    # -- MAJX --------------------------------------------------------------
    def majx_success(
        self, x: int, n_act: int, t1: float = cal.MAJX_BEST_T1_NS,
        t2: float = cal.MAJX_BEST_T2_NS, pattern: str = "random",
        temp_c: float = 50.0, vpp_v: float = 2.5,
    ) -> float:
        if not self.anchor.supports_simra:
            return 0.0
        if x % 2 == 0 or x < 3:
            raise ValueError("MAJX requires odd X >= 3")
        if x > self.anchor.max_majx:
            return 0.005  # fn 11: <1 % success; omitted by the paper
        s = _majx_replication_base(x, n_act)
        s *= _majx_timing_mult(t1, t2)
        s *= _pattern_mult_majx(x, pattern)
        s *= _temp_mult_majx(x, n_act, temp_c)
        s *= _vpp_mult("majx", vpp_v)
        return float(min(max(s, 0.0), 1.0))

    # -- Multi-RowCopy -------------------------------------------------------
    def mrc_success(
        self, n_dest: int, t1: float = cal.MRC_BEST_T1_NS,
        t2: float = cal.MRC_BEST_T2_NS, pattern: str = "random",
        temp_c: float = 50.0, vpp_v: float = 2.5,
    ) -> float:
        if not self.anchor.supports_simra:
            if n_dest == 1 and t2 >= 6.0:
                return 0.99996  # plain RowClone still works everywhere
            return 0.0
        levels = sorted(cal.MRC_SUCCESS_BEST)
        if n_dest not in cal.MRC_SUCCESS_BEST:
            n_key = min((k for k in levels if k >= n_dest), default=31)
        else:
            n_key = n_dest
        s = cal.MRC_SUCCESS_BEST[n_key]
        s *= _mrc_timing_mult(n_dest, t1, t2)
        s *= _pattern_mult_mrc(n_dest, pattern)
        # Obs 17: tiny, direction as SiMRA (peripheral circuitry).
        s *= 1.0 - cal.MRC_TEMP_VARIATION_AVG_REL * (temp_c - 50.0) / 40.0
        s *= _vpp_mult("mrc", vpp_v)
        return float(min(max(s, 0.0), 1.0))

    # -- stochastic realization --------------------------------------------
    def stable_mask(
        self, key: jax.Array, shape: tuple[int, ...], success: float
    ) -> jax.Array:
        """Deterministic per-cell stability mask (paper §3.1 metric).

        A cell's latent threshold is fixed by ``key`` (derived from the
        row-group identity), so repeated trials agree: unstable cells are
        unstable in every trial, matching the "correct in all trials"
        definition of success rate.
        """
        u = jax.random.uniform(key, shape)
        return u < success


def expected_retries(success: float, floor: float = 1e-3) -> float:
    """Expected repetitions until a row-group op fully succeeds (§8.1).

    The case studies pick the best row groups and re-execute failed ops;
    1/success is the geometric-retry estimate used by the throughput model.
    """
    return 1.0 / max(success, floor)
