"""Behavioural DRAM-subarray simulator executing command sequences.

State per subarray: packed bit-planes (one row of the plane matrix per DRAM
row), a per-row Frac flag (row charged to VDD/2 — contributes capacitance
but no differential charge, §2.2/§3.3), the sense-amp row buffer, and the
set of currently-open (asserted) wordlines.

The simulator implements the paper's three operating regimes for the APA
sequence, selected by the issued timings exactly as on real chips:

* ``t1 < tRAS`` and ``t2 < 6 ns`` → **charge-share regime** (§3.3): all
  simultaneously activated, non-neutral cells majority-vote per bitline.
* ``t1 >= tRAS`` and ``t2 < 6 ns`` → **Multi-RowCopy regime** (§3.4): the
  sense amps latch R_F then overwrite every activated row.
* ``t2 >= 6 ns`` → **consecutive activation** (fn 6): a plain RowClone
  from R_F to R_S.

Per-cell correctness is drawn from the calibrated
:class:`~repro.core.errormodel.ErrorModel` via deterministic stable-cell
masks, so repeated trials reproduce the same unstable cells (the paper's
success-rate metric).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes as bp
from repro.core import calibration as cal
from repro.core import commands as cmd
from repro.core.decoder import RowDecoder
from repro.core.errormodel import ErrorModel


def _odd_at_most(n: int) -> int:
    """Largest odd integer <= n (raw-APA operand-count estimate)."""
    return n if n % 2 == 1 else n - 1


@dataclasses.dataclass
class DeviceProfile:
    """Per-manufacturer behaviour (§3.1 Table 1, §9 Limitation 1)."""

    mfr: str = "H"
    subarray_rows: int = 512
    #: sense-amp tie polarity (§3.3 fn 5: Mfr M amps bias to a fixed value)
    tie_bias: int = 0

    @property
    def anchor(self) -> cal.DeviceAnchor:
        return cal.DEVICE_ANCHORS[self.mfr]

    @classmethod
    def mfr_h(cls) -> "DeviceProfile":
        return cls(mfr="H", subarray_rows=512, tie_bias=0)

    @classmethod
    def mfr_m(cls) -> "DeviceProfile":
        return cls(mfr="M", subarray_rows=1024, tie_bias=0)

    @classmethod
    def mfr_s(cls) -> "DeviceProfile":
        return cls(mfr="S", subarray_rows=512, tie_bias=0)


class Subarray:
    """One DRAM subarray with ``rows`` rows of ``cols`` cells."""

    def __init__(
        self,
        profile: DeviceProfile = None,
        cols: int = 1024,
        *,
        temp_c: float = 50.0,
        vpp_v: float = 2.5,
        seed: int = 0,
        ideal: bool = False,
    ):
        self.profile = profile or DeviceProfile.mfr_h()
        self.rows = self.profile.subarray_rows
        self.cols = cols
        self.n_words = bp.n_words(cols)
        self.temp_c = temp_c
        self.vpp_v = vpp_v
        #: ``ideal=True`` disables the stochastic error model (unit tests of
        #: pure PUD semantics; equivalent to success rate 1.0 everywhere).
        self.ideal = ideal
        self.decoder = RowDecoder.for_subarray(self.rows)
        self.errors = ErrorModel(self.profile.mfr)
        self._key = jax.random.PRNGKey(seed)
        self.planes = jnp.zeros((self.rows, self.n_words), jnp.uint32)
        self.frac_rows = np.zeros((self.rows,), bool)
        self.row_buffer = jnp.zeros((self.n_words,), jnp.uint32)
        self.buffer_valid = False
        self.open_rows: tuple[int, ...] = ()
        #: cumulative issued-command time (ns), for latency accounting
        self.elapsed_ns = 0.0

    # ------------------------------------------------------------------ I/O
    def write_row(self, row: int, data) -> None:
        data = jnp.asarray(data, jnp.uint32).reshape(self.n_words)
        self.planes = self.planes.at[row].set(data)
        self.frac_rows[row] = False

    def write_row_bits(self, row: int, bits) -> None:
        self.write_row(row, bp.pack(jnp.asarray(bits)))

    def read_row(self, row: int) -> jax.Array:
        return self.planes[row]

    def read_row_bits(self, row: int) -> jax.Array:
        return bp.unpack(self.planes[row], self.cols)

    def fill(self, pattern: str, *, key: Optional[jax.Array] = None) -> None:
        """Initialize the whole subarray with a §3.1 data pattern."""
        if pattern == "random":
            key = key if key is not None else self._next_key()
            self.planes = jax.random.randint(
                key, (self.rows, self.n_words), 0, 1 << 32, dtype=jnp.uint32
            )
        else:
            byte = {"0x00": 0x00, "0xFF": 0xFF, "0xAA": 0xAA, "0x55": 0x55,
                    "0xCC": 0xCC, "0x33": 0x33, "0x66": 0x66, "0x99": 0x99}[pattern]
            word = np.uint32(byte * 0x01010101)
            self.planes = jnp.full((self.rows, self.n_words), word, jnp.uint32)
        self.frac_rows[:] = False

    # ------------------------------------------------------------ execution
    def run(self, seq: cmd.CommandSeq) -> None:
        """Execute a command sequence with timing-dependent semantics."""
        cmds = list(seq)
        self.elapsed_ns += seq.duration_ns
        i = 0
        while i < len(cmds):
            c = cmds[i]
            if c.kind == "ACT":
                # Look ahead for the APA idiom: ACT -> PRE -> ACT.
                if (
                    i + 2 < len(cmds)
                    and cmds[i + 1].kind == "PRE"
                    and cmds[i + 2].kind == "ACT"
                    and cmds[i + 1].gap_ns < 6.0
                ):
                    self._apa(c.row, cmds[i + 2].row, c.gap_ns, cmds[i + 1].gap_ns)
                    i += 3
                    continue
                if (
                    i + 2 < len(cmds)
                    and cmds[i + 1].kind == "PRE"
                    and cmds[i + 2].kind == "ACT"
                    and cmds[i + 1].gap_ns < cmd.NOMINAL.trp
                ):
                    # consecutive activation (fn 6): RowClone
                    self._rowclone(c.row, cmds[i + 2].row)
                    i += 3
                    continue
                if c.gap_ns < 12.0 and not self.frac_rows[c.row]:
                    # interrupted restore: Frac initialization (§2.2)
                    self._frac(c.row)
                    i += 1
                    continue
                self._activate(c.row)
            elif c.kind == "PRE":
                self._precharge()
            elif c.kind == "WR":
                self._write_through(c.data)
            elif c.kind == "RD":
                self._activate(c.row)
            i += 1

    # ------------------------------------------------------------ regimes
    def _activate(self, row: int) -> None:
        self.row_buffer = self.planes[row]
        self.buffer_valid = True
        self.open_rows = (row,)

    def _precharge(self) -> None:
        self.buffer_valid = False
        self.open_rows = ()

    def _frac(self, row: int) -> None:
        if not self.profile.anchor.supports_frac:
            # §3.3 fn 5: Mfr M emulates neutral rows with the sense-amp bias
            # polarity; we model that as an all-<bias> row marked neutral.
            if not self.profile.anchor.frac_via_bias:
                raise RuntimeError(f"Mfr {self.profile.mfr}: no Frac, no bias")
        self.frac_rows[row] = True
        self.open_rows = ()
        self.buffer_valid = False

    def _rowclone(self, src: int, dst: int) -> None:
        s = self.errors.mrc_success(1, t1=cmd.NOMINAL.tras, t2=6.0,
                                    temp_c=self.temp_c, vpp_v=self.vpp_v)
        self._overwrite_rows((dst,), self.planes[src], s, op="rowclone")
        self.row_buffer = self.planes[src]
        self.buffer_valid = True
        self.open_rows = (src, dst)

    def _apa(self, rf: int, rs: int, t1: float, t2: float) -> None:
        if not self.profile.anchor.supports_simra:
            # §9 Limitation 1: chip ignores the violated-timing sequence and
            # behaves like a normal activation of the second row.
            self._activate(rs)
            return
        act = self.decoder.apa_activated_rows(rf, rs)
        self.open_rows = act
        if t1 >= cmd.NOMINAL.tras:
            self._apa_mrc(rf, act, t1, t2)
        else:
            self._apa_chargeshare(rf, rs, act, t1, t2)

    def _apa_mrc(self, rf: int, act: Sequence[int], t1: float, t2: float) -> None:
        """Multi-RowCopy regime: sense amps hold R_F; destinations overwritten."""
        dests = tuple(r for r in act if r != rf)
        s = self.errors.mrc_success(len(dests), t1=t1, t2=t2,
                                    temp_c=self.temp_c, vpp_v=self.vpp_v)
        src = self.planes[rf]
        self._overwrite_rows(dests, src, s, op=f"mrc{len(dests)}")
        self.row_buffer = src
        self.buffer_valid = True

    def _apa_chargeshare(
        self, rf: int, rs: int, act: Sequence[int], t1: float, t2: float
    ) -> None:
        """Charge-share regime: per-bitline majority over non-neutral rows."""
        contributing = [r for r in act if not self.frac_rows[r]]
        n_act = len(act)
        if not contributing:
            return
        stack = self.planes[jnp.asarray(contributing)]
        if len(contributing) % 2 == 1:
            result = bp.majority(stack, axis=0)
        else:
            result = bp.majority_with_ties(stack, self.profile.tie_bias, axis=0)
        # Success rate: the op-level wrappers (repro.core.majx) pass the
        # operand multiplicity; raw APA assumes unreplicated inputs.
        x = self._x_hint if self._x_hint else _odd_at_most(len(contributing))
        self._x_hint = 0
        s = self.errors.majx_success(
            x, n_act, t1=t1, t2=t2, pattern=self._pattern_hint,
            temp_c=self.temp_c, vpp_v=self.vpp_v,
        ) if x >= 3 else self.errors.simra_success(
            n_act, t1=t1, t2=t2, temp_c=self.temp_c, vpp_v=self.vpp_v)
        # Unstable cells resolve to the complement (sense amp flips).
        if not self.ideal and s < 1.0:
            mask = self._stable_mask((self.n_words,), s, ("apa", rf, rs))
            result = (result & mask) | (~result & ~mask)
        self._overwrite_rows(tuple(act), result, 1.0, op="chargeshare",
                             skip_mask=False)
        self.row_buffer = result
        self.buffer_valid = True

    _x_hint: int = 0
    _pattern_hint: str = "random"

    def hint(self, x: int = 0, pattern: str = "random") -> None:
        """Operand-count / pattern hint for the next charge-share APA.

        The physical op doesn't know how many *distinct* operands the rows
        hold; the MAJX wrapper passes it so the calibrated surface applies.
        """
        self._x_hint = x
        self._pattern_hint = pattern

    def _write_through(self, data: np.ndarray) -> None:
        """WR while rows are open: overdrives bitlines, updating every open
        row (§3.2 SiMRA test methodology)."""
        if not self.open_rows:
            return
        data = jnp.asarray(data, jnp.uint32).reshape(self.n_words)
        n_act = len(self.open_rows)
        if n_act in cal.SIMRA_SUCCESS_BEST:
            s = self.errors.simra_success(n_act, temp_c=self.temp_c,
                                          vpp_v=self.vpp_v)
        else:
            s = 1.0
        self._overwrite_rows(self.open_rows, data, s, op="wr")
        self.row_buffer = data

    # ------------------------------------------------------------ helpers
    def _overwrite_rows(self, rows, data, success, op, skip_mask=True) -> None:
        if not rows:
            return
        rows_arr = jnp.asarray(rows)
        if self.ideal or success >= 1.0:
            new = jnp.broadcast_to(data, (len(rows), self.n_words))
        else:
            mask = self._stable_mask((len(rows), self.n_words * 32), success,
                                     (op, rows[0]))
            mask = bp.pack(mask)
            old = self.planes[rows_arr]
            new = (data[None, :] & mask) | (old & ~mask)
        self.planes = self.planes.at[rows_arr].set(new)
        for r in rows:
            self.frac_rows[r] = False

    def _stable_mask(self, shape, success, salt) -> jax.Array:
        if self.ideal:
            return jnp.ones(shape, bool)
        key = self._key
        for s in salt:
            key = jax.random.fold_in(key, hash(s) & 0x7FFFFFFF)
        if len(shape) == 1 and shape[-1] == self.n_words:
            bits = self.errors.stable_mask(key, (self.n_words * 32,), success)
            return bp.pack(bits)
        return self.errors.stable_mask(key, shape, success)

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
