"""Power model for SiMRA vs standard DRAM operations (paper Fig. 5, Obs 5).

The paper measures average module power for RD, WR, ACT+PRE, REF, and N-row
SiMRA activation, reporting one pinned relationship: 32-row activation draws
21.19 % *less* power than REF (the hungriest standard op).  Absolute watt
values are read off Fig. 5 qualitatively; we encode representative DDR4
module-level numbers (documented model assumption) and pin the Obs 5 ratio
exactly, with SiMRA power growing logarithmically in the number of
simultaneously-asserted wordlines (wordline/CSL driver energy dominates).
"""

from __future__ import annotations

from repro.core import calibration as cal

#: Standard-operation average power, watts per module (model assumption;
#: representative of a DDR4-2400 x8 UDIMM under steady issue).
STANDARD_POWER_W = {
    "RD": 1.30,
    "WR": 1.25,
    "ACT_PRE": 0.90,
    "REF": 1.80,
}


def simra_power_w(n_act: int) -> float:
    """Average power of an N-row SiMRA activation loop.

    P(2) starts just above ACT_PRE; P(32) is pinned to REF * (1 - 0.2119).
    Interpolation is linear in log2(N) (each predecoder split roughly
    doubles asserted wordlines and their driver load).
    """
    if n_act < 2:
        return STANDARD_POWER_W["ACT_PRE"]
    import math

    p2 = STANDARD_POWER_W["ACT_PRE"] * 1.05
    p32 = STANDARD_POWER_W["REF"] * (1.0 + cal.SIMRA32_POWER_VS_REF)
    w = (math.log2(n_act) - 1.0) / 4.0  # log2: 2 -> 0, 32 -> 1
    return p2 + (p32 - p2) * min(max(w, 0.0), 1.0)


#: Lazily-built Fig. 5 table (standard ops + the calibrated SIMRA_N
#: series).  Built once; ``power_table()`` hands out copies so callers
#: can't corrupt the cache.
_TABLE_CACHE: dict[str, float] | None = None


def _table() -> dict[str, float]:
    global _TABLE_CACHE
    if _TABLE_CACHE is None:
        out = dict(STANDARD_POWER_W)
        for n in cal.N_ACT_LEVELS:
            out[f"SIMRA_{n}"] = simra_power_w(n)
        _TABLE_CACHE = out
    return _TABLE_CACHE


def power_table() -> dict[str, float]:
    """All Fig. 5 series in one dict (benchmark output; a fresh copy)."""
    return dict(_table())


def energy_nj(op: str, duration_ns: float) -> float:
    """Energy (nJ) of holding ``op`` power for ``duration_ns``.

    W x ns = nJ exactly; raises :class:`ValueError` naming the valid
    series for ops outside the calibrated table (e.g. ``SIMRA_3`` —
    only the measured :data:`~repro.core.calibration.N_ACT_LEVELS`
    activation counts appear in Fig. 5).
    """
    table = _table()
    try:
        return table[op] * duration_ns
    except KeyError:
        raise ValueError(
            f"unknown power-table op {op!r}; valid ops: "
            f"{', '.join(sorted(table))}") from None
