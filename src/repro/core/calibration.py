"""Calibration anchors: every quantitative claim of the SiMRA-DRAM paper.

This module is the single source of truth for the numbers printed in
"Simultaneous Many-Row Activation in Off-the-Shelf DRAM Chips: Experimental
Characterization and Analysis" (DSN 2024).  The behavioural error model
(`repro.core.errormodel`), the charge-sharing Monte-Carlo
(`repro.core.chargeshare`), the latency/power models (`repro.pud.latency`,
`repro.core.power`) and the §8 case-study benchmarks are all calibrated
against the constants below, and `tests/test_calibration.py` pins them.

Percentages follow the paper's own relative-percentage convention
(e.g. "MAJ3 with 32-row activation has a 30.81% *higher* success rate than
MAJ3 with 4-row activation" means ``s32 == s4 * 1.3081``), which is the only
reading consistent across Obs 6-10 (absolute-point readings would exceed
100% or go negative for MAJ7/MAJ9).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

# ---------------------------------------------------------------------------
# §3 Methodology constants
# ---------------------------------------------------------------------------

#: Timing grid used throughout the paper (DRAM Bender slot granularity 1.5ns).
T_GRID_NS = (1.5, 3.0, 6.0, 9.0, 36.0)

#: Number of simultaneously activated rows observed (§4, Limitation 2).
N_ACT_LEVELS = (2, 4, 8, 16, 32)

#: Temperatures tested (deg C); experiments default to 50C.
TEMPERATURES_C = (50.0, 60.0, 70.0, 80.0, 90.0)

#: Wordline voltages tested (V); nominal VPP = 2.5V.
VPP_LEVELS_V = (2.5, 2.4, 2.3, 2.2, 2.1)

#: Data patterns tested (§3.1).  "random" is the worst-case default.
DATA_PATTERNS = ("random", "0x00/0xFF", "0xAA/0x55", "0xCC/0x33", "0x66/0x99")

#: Tested chips (Table 1): (mfr, die_rev) -> (modules, chips, density, org, subarray_size)
TABLE1 = {
    ("H", "M"): dict(modules=7, chips=56, density="4Gb", org="x8", subarray_sizes=(512, 640)),
    ("H", "A"): dict(modules=5, chips=40, density="4Gb", org="x8", subarray_sizes=(512,)),
    ("M", "E"): dict(modules=4, chips=16, density="16Gb", org="x16", subarray_sizes=(1024,)),
    ("M", "B"): dict(modules=2, chips=8, density="16Gb", org="x16", subarray_sizes=(1024,)),
}

# ---------------------------------------------------------------------------
# §4 Simultaneous many-row activation
# ---------------------------------------------------------------------------

#: Obs 1: success of N-row activation at the best timings (t1=3ns, t2=3ns).
SIMRA_SUCCESS_BEST: Mapping[int, float] = {
    2: 0.9999, 4: 0.9999, 8: 0.9999, 16: 0.9999, 32: 0.9985,
}
SIMRA_BEST_T1_NS = 3.0
SIMRA_BEST_T2_NS = 3.0

#: Obs 2: 8-row activation at t1=t2=1.5ns is 21.74% (relative) below the best
#: timing for 8-row activation (t1=1.5, t2=3.0).
SIMRA_OBS2_DROP_REL = 0.2174
SIMRA_OBS2_N = 8

#: Obs 3: 50C -> 90C decreases SiMRA success by 0.07% on average (relative).
SIMRA_TEMP_DROP_REL_50_TO_90 = 0.0007

#: Obs 4: VPP 2.5V -> 2.1V decreases SiMRA success by at most 0.41% (relative).
SIMRA_VPP_DROP_REL_MAX = 0.0041

#: Obs 5: 32-row activation power is 21.19% below REF (the most
#: power-hungry standard op).
SIMRA32_POWER_VS_REF = -0.2119

# ---------------------------------------------------------------------------
# §5 MAJX
# ---------------------------------------------------------------------------

#: Obs 8: average success at 32-row activation, random data, best timings.
MAJX_SUCCESS_32ROW: Mapping[int, float] = {
    3: 0.9900, 5: 0.7964, 7: 0.3387, 9: 0.0591,
}

#: Best timings for MAJX (Obs 7): t1=1.5ns, t2=3.0ns.
MAJX_BEST_T1_NS = 1.5
MAJX_BEST_T2_NS = 3.0

#: Obs 7: best timing is 45.50% (relative) above the second best (t1=t2=3ns)
#: for MAJ3 with 32-row activation.
MAJ3_32_BEST_OVER_SECOND_REL = 0.4550

#: Obs 6: MAJ3@32-row is 30.81% (relative) above MAJ3@4-row.
MAJ3_REPLICATION_GAIN_32_OVER_4_REL = 0.3081

#: Obs 10: input replication gain (relative), 32-row vs the minimum
#: activation count that fits X operands with no replication.
MAJX_REPLICATION_GAIN_REL: Mapping[int, float] = {
    5: 0.5627, 7: 0.3515, 9: 0.1311,
}

#: Obs 9: random pattern is x% (relative) below 0x00/0xFF at 32-row act.
MAJX_RANDOM_BELOW_FIXED_REL: Mapping[int, float] = {
    3: 0.0068, 5: 0.1385, 7: 0.3256, 9: 0.1651,
}

#: §1/§Abstract: data pattern affects MAJX success by 11.52% on average.
MAJX_PATTERN_EFFECT_AVG_REL = 0.1152

#: Obs 11: 50C->90C varies MAJX success by 4.25% on average (higher T helps).
MAJX_TEMP_VARIATION_AVG_REL = 0.0425

#: Obs 12: max temperature-induced variation, MAJ3.
MAJ3_TEMP_VARIATION_32ROW_MAX_REL = 0.0165
MAJ3_TEMP_VARIATION_4ROW_MAX_REL = 0.1520

#: Obs 13: VPP scaling varies MAJX success by 1.10% on average.
MAJX_VPP_VARIATION_AVG_REL = 0.0110

#: Footnote 11: omitted ops with <1% success: MAJ11+ (Mfr H), MAJ9+ (Mfr M).
MAJX_MAX_X = {"H": 9, "M": 7}

#: §8.1: the case studies "choose the group of rows ... which produces the
#: highest throughput" — i.e. the best-performing row groups, not the
#: average.  Fig. 7's box whiskers reach ~100 % for MAJ5/MAJ7; the values
#: below are derived so the Fig. 16 speedup *ordering and signs* reproduce
#: (MAJ9 on Mfr H stays poor enough to degrade performance, Obs: -114.12 %).
MAJX_BEST_GROUP_SUCCESS = {
    "H": {3: 0.999, 5: 0.990, 7: 0.975, 9: 0.150},
    "M": {3: 0.999, 5: 0.995, 7: 0.990},
}
#: Best-group MAJ3 success at 4-row activation (the §8.1 baseline).
#: Per manufacturer: Mfr M has no Frac support, so its 4-row baseline's
#: neutral row relies on the weaker sense-amp-bias emulation (§3.3 fn 5) —
#: which is why Fig 16's speedups from the new MAJX ops are much larger on
#: Mfr M (+121.61 %) than on Mfr H (+46.54 %).
MAJ3_4ROW_BEST_GROUP_SUCCESS = {"H": 0.950, "M": 0.720}

# ---------------------------------------------------------------------------
# §6 Multi-RowCopy
# ---------------------------------------------------------------------------

#: Obs 14: success per destination count at best timings (t1=36ns, t2=3ns).
MRC_SUCCESS_BEST: Mapping[int, float] = {
    1: 0.99996, 3: 0.99989, 7: 0.99998, 15: 0.99999, 31: 0.99982,
}
MRC_BEST_T1_NS = 36.0
MRC_BEST_T2_NS = 3.0

#: Obs 15: t1=1.5ns is 49.79% (relative) below the second-worst timing config.
MRC_T1_1P5_BELOW_SECOND_WORST_REL = 0.4979

#: Obs 16: copying all-1s to 31 rows is 0.79% (relative) below all-0s/random;
#: for <=15 destinations pattern differences are at most 0.11%.
MRC_ALL1_31_DROP_REL = 0.0079
MRC_PATTERN_MAX_REL_LE15 = 0.0011

#: §1: data pattern affects Multi-RowCopy success by 0.07% on average.
MRC_PATTERN_EFFECT_AVG_REL = 0.0007

#: Obs 17: 50C->90C varies MRC success by 0.04% on average.
MRC_TEMP_VARIATION_AVG_REL = 0.0004

#: Obs 18: VPP -0.4V decreases MRC success by at most 1.32%.
MRC_VPP_DROP_REL_MAX = 0.0132

#: Abstract: overall temp/voltage variation bound across ALL tested ops.
ALL_OPS_TEMP_VARIATION_MAX_REL = 0.0213
ALL_OPS_VPP_VARIATION_MAX_REL = 0.0132

# ---------------------------------------------------------------------------
# §7 Hypotheses (decoder + charge sharing)
# ---------------------------------------------------------------------------

#: §7.1: the examined chip has 2^16 rows/bank, 2^9 rows/subarray, 2^7
#: subarrays/bank, and (hypothesised) 5 predecoders => up to 2^5=32 rows.
DECODER_ROW_BITS = 9
DECODER_SUBARRAY_BITS = 7
DECODER_NUM_PREDECODERS = 5

#: §7.2 SPICE: MAJ3@32-row has 159.05% higher bitline deviation than @4-row.
SPICE_DEVIATION_GAIN_32_OVER_4_REL = 1.5905

#: §7.2 SPICE: success drop when process variation goes 0% -> 40%.
SPICE_MAJ3_4ROW_PV_DROP_REL = 0.4658
SPICE_MAJ3_32ROW_PV_DROP_REL = 0.0001

#: §3.5: Monte-Carlo iterations and PV levels used by the paper.
SPICE_MC_ITERS = 10_000
SPICE_PV_LEVELS = (0.0, 0.10, 0.20, 0.30, 0.40)

# ---------------------------------------------------------------------------
# §8 Case studies
# ---------------------------------------------------------------------------

#: §8.1: average speedup of new MAJX ops over the MAJ3@4-row baseline.
MICROBENCH_AVG_SPEEDUP_REL = {"M": 1.2161, "H": 0.4654}
#: §8.1: MAJ7 over MAJ5.
MICROBENCH_MAJ7_OVER_MAJ5_REL = {"M": 0.6210, "H": 0.3171}
#: §8.1: MAJ9 *degrades* performance by 114.12% on Mfr H.
MICROBENCH_MAJ9_DEGRADATION_H_REL = 1.1412

MICROBENCHMARKS = ("and", "or", "xor", "add", "sub", "mul", "div")
MICROBENCH_ELEM_BITS = 32
MICROBENCH_ELEM_BYTES = 8 * 1024  # 8KB elements

#: §8.2: Multi-RowCopy content destruction vs RowClone / Frac baselines.
COLDBOOT_MAX_SPEEDUP_VS_ROWCLONE = 20.87
COLDBOOT_MAX_SPEEDUP_VS_FRAC = 7.55

# ---------------------------------------------------------------------------
# Derived anchors (relative-percentage convention; see module docstring)
# ---------------------------------------------------------------------------

def maj3_success_4row() -> float:
    """MAJ3 at 4-row activation (no replication), random data, best timings."""
    return MAJX_SUCCESS_32ROW[3] / (1.0 + MAJ3_REPLICATION_GAIN_32_OVER_4_REL)


def majx_success_min_activation(x: int) -> float:
    """MAJX success at the smallest N that fits X operands unreplicated."""
    if x == 3:
        return maj3_success_4row()
    return MAJX_SUCCESS_32ROW[x] / (1.0 + MAJX_REPLICATION_GAIN_REL[x])


def majx_success_fixed_pattern(x: int) -> float:
    """MAJX@32-row success with the 0x00/0xFF pattern (Obs 9)."""
    return MAJX_SUCCESS_32ROW[x] / (1.0 - MAJX_RANDOM_BELOW_FIXED_REL[x])


def maj3_32_second_best_timing() -> float:
    """MAJ3@32 at the second-best timing (t1=t2=3ns), Obs 7."""
    return MAJX_SUCCESS_32ROW[3] / (1.0 + MAJ3_32_BEST_OVER_SECOND_REL)


def min_activation_for(x: int) -> int:
    """Smallest supported N-row activation holding X operands (>= X)."""
    for n in N_ACT_LEVELS:
        if n >= x:
            return n
    raise ValueError(f"MAJ{x} does not fit any activation level")


def replication_plan(x: int, n: int) -> tuple[int, int]:
    """(copies per operand, neutral rows) when running MAJX with N-row act.

    §3.3: replicate each of the X operands floor(N/X) times; the N%X
    leftover rows are neutral (Frac-initialised to VDD/2).
    """
    if n < x:
        raise ValueError(f"cannot run MAJ{x} with only {n} activated rows")
    return n // x, n % x


@dataclasses.dataclass(frozen=True)
class DeviceAnchor:
    """Per-manufacturer behaviour captured in the paper."""

    mfr: str
    supports_simra: bool
    supports_frac: bool
    #: §3.3 fn5: Mfr M sense amps are biased to one/zero; neutral rows are
    #: emulated by initialising them with all-zeros/ones.
    frac_via_bias: bool
    max_majx: int
    subarray_sizes: tuple[int, ...]


DEVICE_ANCHORS = {
    "H": DeviceAnchor("H", True, True, False, 9, (512, 640)),
    "M": DeviceAnchor("M", True, False, True, 7, (1024,)),
    # §9 Limitation 1: Samsung chips show no SiMRA at all.
    "S": DeviceAnchor("S", False, False, False, 0, (512,)),
}
