"""DRAM command-sequence IR (the DRAM Bender programs of §3).

The paper drives real chips with precisely-timed command sequences; our
behavioural simulator consumes the same IR.  A :class:`CommandSeq` is a list
of commands with explicit inter-command delays in nanoseconds — violated
timings are simply small delays (the whole point of the paper).

Standard JEDEC DDR4 timing parameters (used as the *nominal* reference and
by the latency model in :mod:`repro.pud.latency`) are bundled as
:class:`Timings`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Timings:
    """Nominal DDR4 timing parameters (ns), DDR4-2400-ish (JESD79-4)."""

    tck: float = 0.833
    tras: float = 36.0  # ACT -> PRE (also the MRC t1 optimum, Obs 14)
    trp: float = 15.0   # PRE -> ACT
    trcd: float = 15.0  # ACT -> RD/WR
    trc: float = 51.0   # ACT -> ACT (same bank)
    twr: float = 15.0   # write recovery
    tbl: float = 3.33   # burst (BL8 @ 2400)
    trfc: float = 350.0  # refresh cycle (8Gb-class)
    trefi: float = 7800.0
    #: DRAM Bender command-slot granularity (§9 Limitation 2): 1.5 ns.
    slot: float = 1.5


NOMINAL = Timings()


@dataclasses.dataclass(frozen=True)
class Cmd:
    kind: str  # ACT | PRE | WR | RD | NOP
    row: Optional[int] = None
    #: packed uint32 payload for WR; None elsewhere
    data: Optional[np.ndarray] = None
    #: delay (ns) before the *next* command may issue
    gap_ns: float = 0.0

    def __repr__(self) -> str:  # compact traces in logs/tests
        r = f" r{self.row}" if self.row is not None else ""
        return f"{self.kind}{r}@{self.gap_ns}ns"


@dataclasses.dataclass
class CommandSeq:
    """An ordered DRAM command program with explicit timing."""

    cmds: list[Cmd] = dataclasses.field(default_factory=list)

    def act(self, row: int, gap_ns: float) -> "CommandSeq":
        self.cmds.append(Cmd("ACT", row=row, gap_ns=gap_ns))
        return self

    def pre(self, gap_ns: float) -> "CommandSeq":
        self.cmds.append(Cmd("PRE", gap_ns=gap_ns))
        return self

    def wr(self, data: np.ndarray, gap_ns: float = NOMINAL.twr) -> "CommandSeq":
        self.cmds.append(Cmd("WR", data=np.asarray(data, np.uint32), gap_ns=gap_ns))
        return self

    def rd(self, row: int, gap_ns: float = NOMINAL.tbl) -> "CommandSeq":
        self.cmds.append(Cmd("RD", row=row, gap_ns=gap_ns))
        return self

    def nop(self, gap_ns: float) -> "CommandSeq":
        self.cmds.append(Cmd("NOP", gap_ns=gap_ns))
        return self

    def extend(self, other: Union["CommandSeq", Iterable[Cmd]]) -> "CommandSeq":
        self.cmds.extend(other.cmds if isinstance(other, CommandSeq) else other)
        return self

    @property
    def duration_ns(self) -> float:
        return sum(c.gap_ns for c in self.cmds)

    def __len__(self) -> int:
        return len(self.cmds)

    def __iter__(self):
        return iter(self.cmds)


# ---------------------------------------------------------------------------
# canonical sequences from the paper
# ---------------------------------------------------------------------------


def apa(row_first: int, row_second: int, t1_ns: float, t2_ns: float) -> CommandSeq:
    """ACT R_F --t1--> PRE --t2--> ACT R_S  (§2.2, §3.2).

    t1 violates tRAS and t2 violates tRP; the trailing gap closes the row
    cycle at nominal timing so subsequent commands are safe.
    """
    seq = CommandSeq()
    seq.act(row_first, gap_ns=t1_ns)
    seq.pre(gap_ns=t2_ns)
    seq.act(row_second, gap_ns=NOMINAL.tras)
    return seq


def apa_with_wr(
    row_first: int, row_second: int, t1_ns: float, t2_ns: float,
    data: np.ndarray,
) -> CommandSeq:
    """§3.2 SiMRA test: APA then WR overdrives all simultaneously open rows."""
    seq = apa(row_first, row_second, t1_ns, t2_ns)
    seq.wr(data)
    seq.pre(gap_ns=NOMINAL.trp)
    return seq


def rowclone(src: int, dst: int) -> CommandSeq:
    """Consecutive two-row activation (fn 6): ACT src -> PRE(6ns) -> ACT dst."""
    seq = CommandSeq()
    seq.act(src, gap_ns=NOMINAL.tras)
    seq.pre(gap_ns=6.0)
    seq.act(dst, gap_ns=NOMINAL.tras)
    seq.pre(gap_ns=NOMINAL.trp)
    return seq


def multi_rowcopy(src: int, row_second: int, t2_ns: float = 3.0) -> CommandSeq:
    """§3.4: ACT src --tRAS--> PRE --t2<=3ns--> ACT r_s: 1 -> N-1 copy."""
    seq = CommandSeq()
    seq.act(src, gap_ns=NOMINAL.tras)
    seq.pre(gap_ns=t2_ns)
    seq.act(row_second, gap_ns=NOMINAL.tras)
    seq.pre(gap_ns=NOMINAL.trp)
    return seq


def frac(row: int) -> CommandSeq:
    """FracDRAM-style neutral-row initialization (§2.2, fn 4).

    Charges the row to ~VDD/2 by interrupting restoration: ACT followed by
    an early PRE mid-restore.  We model the outcome (a neutral row), not the
    analog trajectory.
    """
    seq = CommandSeq()
    seq.act(row, gap_ns=9.0)   # interrupted restore
    seq.pre(gap_ns=NOMINAL.trp)
    return seq
