"""Core PUD substrate: the paper's contribution as composable JAX modules.

- :mod:`repro.core.calibration` — every number the paper reports (anchors).
- :mod:`repro.core.bitplanes` — packed bit-plane tensors and majority ops.
- :mod:`repro.core.decoder` — hierarchical row-decoder hypothesis (§7.1).
- :mod:`repro.core.commands` — DRAM command-sequence IR (APA et al.).
- :mod:`repro.core.subarray` — behavioural subarray simulator.
- :mod:`repro.core.errormodel` — calibrated success-rate surfaces (§4-§6).
- :mod:`repro.core.chargeshare` — Monte-Carlo bitline model (§7.2).
- :mod:`repro.core.majx` / :mod:`repro.core.rowcopy` — op-level wrappers.
- :mod:`repro.core.power` — Fig. 5 power model.
"""

from repro.core.calibration import DEVICE_ANCHORS  # noqa: F401
from repro.core.decoder import RowDecoder  # noqa: F401
from repro.core.errormodel import ErrorModel  # noqa: F401
from repro.core.subarray import DeviceProfile, Subarray  # noqa: F401
