"""Packed bit-plane tensors: the digital substrate of the PUD model.

A DRAM row in the paper is a 65,536-bit vector (8KB x8 chip row).  We model
rows (and bit-serial operands) as ``uint32``-packed planes: a plane of
``n`` logical bits is a ``uint32[ceil(n/32)]`` array, LSB-first within each
word.  All bulk-bitwise PUD ops (MAJX, Multi-RowCopy, the bit-serial
arithmetic of §8.1) operate on these planes; the Pallas kernels in
``repro.kernels`` consume the same layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
UMAX = np.uint32(0xFFFFFFFF)


def n_words(n_bits: int) -> int:
    """Number of uint32 words needed for ``n_bits`` logical bits."""
    return -(-n_bits // WORD_BITS)


def pack(bits: jax.Array) -> jax.Array:
    """Pack a boolean/0-1 array of shape (..., n_bits) into uint32 planes.

    Returns shape (..., ceil(n_bits/32)), LSB-first.  n_bits is padded with
    zeros to a multiple of 32.
    """
    bits = jnp.asarray(bits)
    n_bits = bits.shape[-1]
    pad = n_words(n_bits) * WORD_BITS - n_bits
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = bits.reshape(*bits.shape[:-1], -1, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of :func:`pack`; returns bool array of shape (..., n_bits)."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], -1)
    return bits[..., :n_bits].astype(jnp.bool_)


def popcount(words: jax.Array) -> jax.Array:
    """Per-word population count (uint32 in, int32 out)."""
    w = jnp.asarray(words, dtype=jnp.uint32)
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((w * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def majority(planes: jax.Array, axis: int = 0) -> jax.Array:
    """Bitwise majority across ``planes`` (odd count) along ``axis``.

    Implements the charge-sharing semantics of an N-row activation for
    odd N: each output bit is 1 iff more than half the stacked bits are 1.
    Works on packed uint32 planes by per-bit counting; for N=3 the closed
    form ``(a&b)|(b&c)|(a&c)`` in :func:`maj3_words` is faster.
    """
    planes = jnp.asarray(planes, dtype=jnp.uint32)
    n = planes.shape[axis]
    planes = jnp.moveaxis(planes, axis, 0)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)  # (n, ..., words, 32)
    count = jnp.sum(bits.astype(jnp.int32), axis=0)
    out_bits = (2 * count > n).astype(jnp.uint32)
    return jnp.sum(out_bits << shifts, axis=-1, dtype=jnp.uint32)


def majority_with_ties(planes: jax.Array, tie_value: int, axis: int = 0) -> jax.Array:
    """Majority that resolves exact ties (even N) to ``tie_value`` (0/1).

    Models the sense-amp bias of §3.3 fn.5: Mfr M amplifiers are biased to
    a fixed polarity, so an even split resolves deterministically.
    """
    planes = jnp.asarray(planes, dtype=jnp.uint32)
    n = planes.shape[axis]
    planes = jnp.moveaxis(planes, axis, 0)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)
    count = jnp.sum(bits.astype(jnp.int32), axis=0)
    gt = 2 * count > n
    tie = 2 * count == n
    out_bits = jnp.where(tie, jnp.uint32(tie_value), gt.astype(jnp.uint32))
    return jnp.sum(out_bits << shifts, axis=-1, dtype=jnp.uint32)


def maj3_words(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Closed-form bitwise MAJ3 on packed words: (a&b)|(b&c)|(a&c)."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    c = jnp.asarray(c, jnp.uint32)
    return (a & b) | (b & c) | (a & c)


def weighted_majority(planes: jax.Array, weights: jax.Array, axis: int = 0) -> jax.Array:
    """Weighted bitwise majority: 1 iff sum(w_i * bit_i) > sum(w)/2.

    Used by the MAJ-composition identities of §8.1 (e.g. the two-position
    carry c2 = MAJ7(a1,a1,b1,b1,a0,b0,c0) is weighted majority with weights
    (2,2,1,1,1)).
    """
    planes = jnp.asarray(planes, dtype=jnp.uint32)
    planes = jnp.moveaxis(planes, axis, 0)
    w = jnp.asarray(weights, dtype=jnp.int32).reshape(-1, *([1] * (planes.ndim - 1)))
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = ((planes[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    score = jnp.sum(bits * w[..., None], axis=0)
    total = jnp.sum(jnp.asarray(weights, jnp.int32))
    out_bits = (2 * score > total).astype(jnp.uint32)
    return jnp.sum(out_bits << shifts, axis=-1, dtype=jnp.uint32)


def pack_uint_elements(x: jax.Array, n_bits: int = 32) -> jax.Array:
    """Transpose ``k`` unsigned integers into ``n_bits`` bit-planes.

    Input: integer array of shape (..., k).  Output: uint32 planes of shape
    (..., n_bits, ceil(k/32)) — plane ``i`` holds bit ``i`` of every element.
    This is the column-parallel (bit-serial SIMD) layout the §8.1
    microbenchmarks compute in: one DRAM row per bit position.
    """
    x = jnp.asarray(x)
    x = x.astype(jnp.uint32)
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    bits = (x[..., None, :] >> shifts[:, None]) & jnp.uint32(1)  # (..., n_bits, k)
    return pack(bits)


def unpack_uint_elements(planes: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`pack_uint_elements` -> uint32 array (..., k)."""
    planes = jnp.asarray(planes, jnp.uint32)
    n_bits = planes.shape[-2]
    bits = unpack(planes, k).astype(jnp.uint32)  # (..., n_bits, k)
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[:, None], axis=-2, dtype=jnp.uint32)


def bitcast_to_planes(x: jax.Array) -> tuple[jax.Array, tuple, jnp.dtype]:
    """View an arbitrary fixed-width array as packed uint32 words.

    Returns (words, original_shape, original_dtype) so that
    :func:`bitcast_from_planes` can reconstruct it.  Used by the TMR
    checkpoint protection: majority voting is bitwise, so any dtype can be
    protected by voting on its raw words.
    """
    x = jnp.asarray(x)
    nbytes = x.dtype.itemsize
    flat = x.reshape(-1)
    if nbytes == 4:
        words = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif nbytes == 2:
        halves = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        pad = (-halves.size) % 2
        if pad:
            halves = jnp.concatenate([halves, jnp.zeros((pad,), jnp.uint16)])
        pair = halves.reshape(-1, 2).astype(jnp.uint32)
        words = pair[:, 0] | (pair[:, 1] << 16)
    elif nbytes == 1:
        bytes_ = jax.lax.bitcast_convert_type(flat, jnp.uint8)
        pad = (-bytes_.size) % 4
        if pad:
            bytes_ = jnp.concatenate([bytes_, jnp.zeros((pad,), jnp.uint8)])
        quad = bytes_.reshape(-1, 4).astype(jnp.uint32)
        shifts = jnp.arange(4, dtype=jnp.uint32) * 8
        words = jnp.sum(quad << shifts, axis=-1, dtype=jnp.uint32)
    else:
        raise TypeError(f"unsupported itemsize {nbytes} for dtype {x.dtype}")
    return words, x.shape, x.dtype


def bitcast_from_planes(words: jax.Array, shape: tuple, dtype) -> jax.Array:
    """Inverse of :func:`bitcast_to_planes`."""
    dtype = jnp.dtype(dtype)
    n_elem = int(np.prod(shape)) if shape else 1
    nbytes = dtype.itemsize
    words = jnp.asarray(words, jnp.uint32)
    if nbytes == 4:
        flat = jax.lax.bitcast_convert_type(words, dtype)[:n_elem]
    elif nbytes == 2:
        lo = (words & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        hi = (words >> 16).astype(jnp.uint16)
        halves = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n_elem]
        flat = jax.lax.bitcast_convert_type(halves, dtype)
    elif nbytes == 1:
        shifts = jnp.arange(4, dtype=jnp.uint32) * 8
        bytes_ = ((words[:, None] >> shifts) & jnp.uint32(0xFF)).astype(jnp.uint8)
        flat = jax.lax.bitcast_convert_type(bytes_.reshape(-1)[:n_elem], dtype)
    else:
        raise TypeError(f"unsupported itemsize {nbytes} for dtype {dtype}")
    return flat.reshape(shape)
