"""Op-level RowClone / Multi-RowCopy / Frac (paper §3.4, §6).

Multi-RowCopy testing flow (§3.4): initialize destinations with one pattern,
the source with another, issue ACT(src) --tRAS--> PRE --t2<=3ns--> ACT(r_s),
then read each destination at nominal timings.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import calibration as cal
from repro.core import bitplanes as bp
from repro.core import commands as cmd
from repro.core.subarray import Subarray


def rowclone(sa: Subarray, src: int, dst: int) -> None:
    """Copy one row to one other row via consecutive activation (fn 6)."""
    sa.run(cmd.rowclone(src, dst))


def multi_rowcopy(
    sa: Subarray,
    src_data: jax.Array,
    n_act: int,
    *,
    t1_ns: float = cal.MRC_BEST_T1_NS,
    t2_ns: float = cal.MRC_BEST_T2_NS,
    base_row: int = 0,
) -> tuple[int, tuple[int, ...]]:
    """Copy ``src_data`` to the N-1 other rows of an N-row activation group.

    Returns (source_row, destination_rows).  The source row is R_F of the
    APA pair; destinations are the remaining activated rows.
    """
    rf, rs = sa.decoder.pair_for_n_rows(n_act, base_row)
    group = sa.decoder.apa_activated_rows(rf, rs)
    sa.write_row(rf, src_data)
    seq = cmd.CommandSeq()
    seq.act(rf, gap_ns=t1_ns)
    seq.pre(gap_ns=t2_ns)
    seq.act(rs, gap_ns=cmd.NOMINAL.tras)
    seq.pre(gap_ns=cmd.NOMINAL.trp)
    sa.run(seq)
    dests = tuple(r for r in group if r != rf)
    return rf, dests


def mrc_success_measured(
    sa: Subarray, src_data: jax.Array, n_act: int, **kw
) -> float:
    """Fraction of destination cells holding the source data after MRC."""
    src_data = jnp.asarray(src_data, jnp.uint32)
    _, dests = multi_rowcopy(sa, src_data, n_act, **kw)
    total = ok = 0
    for d in dests:
        same = ~(sa.read_row(d) ^ src_data)
        ok += int(jnp.sum(bp.popcount(same)))
        total += sa.n_words * 32
    return ok / total


def frac_init(sa: Subarray, rows: Sequence[int]) -> None:
    """Neutral-row (VDD/2) initialization for each row (FracDRAM, §2.2)."""
    for r in rows:
        sa.run(cmd.frac(r))
