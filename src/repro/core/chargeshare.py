"""Monte-Carlo charge-sharing model of the bitline (paper §7.2 / §3.5).

The paper backs its real-chip observations with LTspice simulations of a
multi-row activation: N cell capacitors (each storing VDD, 0, or VDD/2 for
Frac-neutral rows) share charge with a precharged bitline, and the sense
amplifier resolves the resulting perturbation if it exceeds the reliable
sensing margin.  We reproduce that study with a closed-form charge-sharing
computation plus Monte-Carlo process variation, calibrated so that:

* MAJ3 with 32-row activation shows **+159.05 %** bitline deviation over
  4-row activation (paper §7.2) — this pins ``CB_OVER_CC``;
* at 40 % process variation, MAJ3@4-row success drops ~46.58 % while
  MAJ3@32-row drops ~0.01 % — this pins ``SENSE_MARGIN_FRAC``.

Charge sharing (all capacitances in units of the nominal cell cap C_c,
voltages in units of VDD):

    dV = sum_i C_i (v_i - 1/2) / (C_b + sum_i C_i),   v_i in {0, 1/2, 1}

Process variation draws C_i ~ U(1-p, 1+p) per cell (the paper varies
capacitor/transistor parameters by 10..40 % over 10^4 Monte-Carlo runs).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import calibration as cal

# Bitline capacitance in units of C_c.  Solves
#   dev(32-row) / dev(4-row) = 1 + 1.5905
# with dev(N) = k / (C_b + N) for MAJ3(1,1,0) replicated k = floor(N/3) times
# and N % 3 Frac-neutral rows (which add capacitance but no differential
# charge):  10 (C_b + 4) = 2.5905 (C_b + 32).
CB_OVER_CC = (2.5905 * 32.0 - 10.0 * 4.0) / (10.0 - 2.5905)

# Reliable sensing margin as a fraction of VDD.  Calibrated (see
# tests/test_chargeshare.py) so the 40 %-PV MAJ3@4-row success lands at
# 1 - 0.4658 of its 0 %-PV value while MAJ3@32-row stays within 0.1 %.
SENSE_MARGIN_FRAC = 0.04936


@dataclasses.dataclass(frozen=True)
class BitlineModel:
    cb_over_cc: float = CB_OVER_CC
    sense_margin: float = SENSE_MARGIN_FRAC

    def deviation(self, charges: jax.Array, caps: jax.Array) -> jax.Array:
        """Bitline deviation dV/VDD for one charge-sharing event.

        charges: (..., n_cells) in {0.0, 0.5, 1.0}
        caps:    (..., n_cells) cell capacitances in units of C_c
        """
        num = jnp.sum(caps * (charges - 0.5), axis=-1)
        den = self.cb_over_cc + jnp.sum(caps, axis=-1)
        return num / den

    def sense(self, deviation: jax.Array) -> jax.Array:
        """Sense-amp output: +1 (VDD), -1 (0V), or 0 (unreliable)."""
        ok = jnp.abs(deviation) > self.sense_margin
        return jnp.where(ok, jnp.sign(deviation), 0.0)


def maj3_cell_charges(n_act: int) -> jnp.ndarray:
    """Cell charges for MAJ3(1,1,0) under N-row activation (§3.3 plan).

    floor(N/3) copies of each operand; N % 3 neutral rows at VDD/2.
    """
    copies, neutral = cal.replication_plan(3, n_act)
    vals = [1.0, 1.0, 0.0] * copies + [0.5] * neutral
    return jnp.asarray(vals)


@functools.partial(jax.jit, static_argnames=("n_act", "iters"))
def monte_carlo_maj3(
    key: jax.Array,
    n_act: int,
    pv: float,
    iters: int = cal.SPICE_MC_ITERS,
) -> dict[str, jax.Array]:
    """Monte-Carlo study of MAJ3(1,1,0) with N-row activation.

    Returns the deviation sample and the success indicator (sense amp
    resolves toward the correct majority, here logical 1).
    """
    model = BitlineModel()
    charges = maj3_cell_charges(n_act)
    u = jax.random.uniform(
        key, (iters, charges.shape[0]), minval=-pv, maxval=pv
    )
    caps = 1.0 + u
    dev = model.deviation(charges[None, :], caps)
    sensed = model.sense(dev)
    return {"deviation": dev, "success": sensed > 0.0}


def deviation_mean(n_act: int) -> float:
    """Analytic 0-PV deviation of MAJ3(1,1,0) under N-row activation."""
    copies, neutral = cal.replication_plan(3, n_act)
    return 0.5 * copies / (CB_OVER_CC + 3 * copies + neutral)


def spice_study(key: jax.Array, iters: int = cal.SPICE_MC_ITERS):
    """Full §7.2 reproduction: deviations + success across N x PV grid.

    Returns {(n_act, pv): {"dev_mean", "dev_std", "success_rate"}}.
    """
    out = {}
    for n_act in (1, 4, 8, 16, 32):
        for pv in cal.SPICE_PV_LEVELS:
            key, sub = jax.random.split(key)
            if n_act == 1:
                # Single-row activation baseline (one charged cell).
                model = BitlineModel()
                u = jax.random.uniform(sub, (iters, 1), minval=-pv, maxval=pv)
                dev = model.deviation(jnp.ones((iters, 1)), 1.0 + u)
                succ = model.sense(dev) > 0
            else:
                res = monte_carlo_maj3(sub, n_act, pv, iters)
                dev, succ = res["deviation"], res["success"]
            out[(n_act, pv)] = {
                "dev_mean": float(jnp.mean(dev)),
                "dev_std": float(jnp.std(dev)),
                "success_rate": float(jnp.mean(succ)),
            }
    return out
