"""The one CostModel: DRAM command latency, Fig. 5 power, TPU constants.

Before this module, three consumers each carried a private cost table —
``pud/latency.py`` (DRAM command latencies), ``pud/offload.py`` (TPU
roofline constants + kernel-launch overhead) and ``launch/roofline.py``
(a second copy of the same TPU constants) — and the Fig. 5 power model
(:mod:`repro.core.power`) was consumed by exactly one figure.  PULSAR
(arxiv 2312.02880) frames many-row activation as amortizing per-command
*energy*, and the paper's Obs 5 (32-row SiMRA draws 21.19 % less power
than REF) is central to the PUD value proposition — so costing must
price joules wherever it prices nanoseconds.

:class:`CostModel` owns all of it:

* **DRAM command side** — the :class:`OpLatency` table (per-issue ns of
  MAJX-APA, Multi-RowCopy, RowClone, Frac, row WR/RD) plus the Fig. 5
  power series, composed into retry-aware per-op
  :meth:`~CostModel.latency_ns` / :meth:`~CostModel.energy_nj` and
  whole-:class:`~repro.pud.isa.Program` totals.  These price an op under
  the same calibration point (manufacturer error surfaces, temperature,
  VPP) the execution backends run under — pass the
  :class:`~repro.backends.context.ExecutionContext`'s error model and
  ``env()`` kwargs.
* **TPU side** — ``peak_flops`` / ``hbm_bytes_per_s`` / ``ici_bytes_per_s``
  (the roofline terms), ``kernel_launch_ns`` (the per-dispatch host
  overhead program fusion amortizes), and the energy constants
  ``tpu_avg_w`` (average board power while a dispatch is in flight) and
  ``hbm_pj_per_byte`` (DRAM access energy per byte moved), composed into
  :meth:`~CostModel.dispatch_overhead` / :meth:`~CostModel.
  dispatch_energy_nj` / :meth:`~CostModel.hbm_energy_nj`.

Everything downstream — the offload planner, the roofline reports, the
backend energy counters, both bench schemas — imports *this* module's
:data:`COST` singleton (or the re-exported constants), so the two sides
of every offload decision can never drift apart.

Unit convention: power is watts, time is nanoseconds, so energy is
``W x ns = nJ`` everywhere (1 W for 1 ns is exactly 1 nJ).

This module lives in ``core`` and deliberately imports nothing above it;
``op``/``program`` arguments are duck-typed (``op.kind``/``op.x``/
``op.n_act``, ``program.ops``) so :class:`~repro.pud.isa.PUDOp` streams
cost without an upward import.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import calibration as cal
from repro.core import commands as cmd
from repro.core import power as pw
from repro.core.errormodel import ErrorModel, expected_retries

T = cmd.NOMINAL

#: Bits per DRAM row across one rank (8 KB row, §8.1 element layout).
ROW_BITS = 65536
#: Peak module bus bandwidth (DDR4-2400, 64-bit channel), bytes/ns.
BUS_BYTES_PER_NS = 19.2


@dataclasses.dataclass(frozen=True)
class OpLatency:
    """Latency (ns) of one issue of each PUD / support operation."""

    #: APA in charge-share mode + row-cycle close: t1 + t2 + tRAS + tRP.
    majx_apa: float = cal.MAJX_BEST_T1_NS + cal.MAJX_BEST_T2_NS + T.tras + T.trp
    #: APA in Multi-RowCopy mode.  Base schedule tRAS + t2 + tRAS + tRP =
    #: 90 ns plus a sense-amp drive extension for the 32-way fan-out;
    #: the total is *calibrated* to Fig. 17's 20.87x (the paper measures
    #: but does not print per-op latencies).
    mrc: float = 138.1
    #: Consecutive two-row activation (RowClone): tRAS + 6 + tRAS + tRP.
    rowclone: float = T.tras + 6.0 + T.tras + T.trp
    #: Frac neutral-row init: interrupted restore + precharge.  Calibrated
    #: to Fig. 17's RowClone/Frac = 20.87/7.55 ratio (see above).
    frac: float = 18.7 + T.trp
    #: Writing a full row over the bus: tRCD + burst stream + tWR + tRP.
    wr_row: float = T.trcd + (ROW_BITS / 8) / BUS_BYTES_PER_NS + T.twr + T.trp
    #: Reading a full row: tRCD + burst stream + tRP.
    rd_row: float = T.trcd + (ROW_BITS / 8) / BUS_BYTES_PER_NS + T.trp


LAT = OpLatency()


def majx_issue_ns(x: int, n_act: int) -> float:
    """One MAJX issue including operand staging (§8.1 methodology).

    RowClone the X operands into the group (X ops), Multi-RowCopy the
    replicas (one MRC covers the whole group), Frac the neutral rows.
    """
    copies, neutral = cal.replication_plan(x, n_act)
    setup = x * LAT.rowclone
    if copies > 1:
        setup += x * LAT.mrc  # one fan-out per operand
    setup += neutral * LAT.frac
    return setup + LAT.majx_apa


def majx_throughput_bits_per_s(
    x: int, n_act: int, errors: ErrorModel, **env
) -> float:
    """Correct result bits per second for one subarray issuing MAJX.

    throughput = ROW_BITS * success / (issue latency * expected retries)
    — the §8.1 analytical model with our calibrated surfaces.
    """
    s = errors.majx_success(x, n_act, **env)
    t_ns = majx_issue_ns(x, n_act) * expected_retries(s)
    return ROW_BITS * s / (t_ns * 1e-9)


def mrc_throughput_rows_per_s(n_act: int, errors: ErrorModel, **env) -> float:
    """Destination rows written per second by Multi-RowCopy."""
    s = errors.mrc_success(n_act - 1, **env)
    t_ns = LAT.mrc * expected_retries(s)
    return (n_act - 1) / (t_ns * 1e-9)


#: Power series behind each non-SiMRA op kind (Fig. 5 / §8 methodology):
#: RowClone-style copies and Frac inits pay ACT+PRE power; row I/O pays
#: the bus-transfer series.  MAJ/MRC pay :func:`repro.core.power.
#: simra_power_w` at their activation count and are handled inline.
_KIND_SERIES = {"NOT": "ACT_PRE", "COPY": "ACT_PRE", "FRAC": "ACT_PRE",
                "WR": "WR", "RD": "RD"}


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Shared latency / power / TPU-constant model (see module docstring).

    Frozen: the default :data:`COST` instance is the repo-wide pricing
    authority; derive a variant with :func:`dataclasses.replace` for
    what-if studies (e.g. a different interconnect generation).

    Attributes:
        lat: per-issue DRAM command latencies (ns).
        peak_flops: TPU peak bf16 FLOP/s (v5e-like: 197 TFLOP/s).
        hbm_bytes_per_s: HBM bandwidth (819 GB/s).
        ici_bytes_per_s: per-link ICI bandwidth (50 GB/s).
        kernel_launch_ns: host-side overhead per kernel dispatch — the
            quantity program fusion amortizes, exactly as PULSAR
            amortizes DRAM command overhead across simultaneously
            activated rows.
        tpu_avg_w: average board power while TPU work is in flight
            (model assumption; representative of a v5e-class chip under
            steady dispatch).  Priced per launch over
            ``kernel_launch_ns``.
        hbm_pj_per_byte: DRAM access energy per byte through HBM
            (model assumption, ~3.75 pJ/bit HBM2e-class).
    """

    lat: OpLatency = LAT
    peak_flops: float = 197e12
    hbm_bytes_per_s: float = 819e9
    ici_bytes_per_s: float = 50e9
    kernel_launch_ns: float = 2_000.0
    tpu_avg_w: float = 150.0
    hbm_pj_per_byte: float = 30.0

    # --------------------------------------------------- Fig. 5 power side
    def power_w(self, series: str) -> float:
        """Watts of one Fig. 5 series (``"REF"``, ``"SIMRA_32"``, ...).

        Raises :class:`ValueError` naming the valid series for unknown
        ops (e.g. a non-calibrated ``SIMRA_3``).
        """
        return pw.energy_nj(series, 1.0)  # W x 1 ns = the wattage in nJ

    def simra_power_w(self, n_act: int) -> float:
        """Average power of an N-row SiMRA activation loop (Obs 5)."""
        return pw.simra_power_w(n_act)

    # ------------------------------------------------- DRAM command side
    def latency_ns(self, op: str, *, x: int = 0, n_act: int = 0,
                   errors: Optional[ErrorModel] = None,
                   pipelined: bool = False, best_group: bool = False,
                   **env) -> float:
        """Expected latency of one op issue, retry-until-success.

        ``op`` is a :class:`~repro.pud.isa.PUDOp` kind (``MAJ``/``MRC``/
        ``NOT``/``COPY``/``FRAC``/``WR``/``RD``).  With ``errors=None``
        the single-issue latency is returned (no retry model — what an
        ideal context pays); otherwise the calibrated success surfaces
        under ``env`` (``temp_c``/``vpp_v``, see
        :meth:`repro.backends.context.ExecutionContext.env`) drive the
        geometric retry estimate.  ``pipelined`` drops MAJ operand
        staging; ``best_group`` uses the best-row-group success rates
        the §8 case studies select.
        """
        if op == "MAJ":
            retries = 1.0
            if errors is not None:
                if best_group:
                    s = cal.MAJX_BEST_GROUP_SUCCESS[errors.mfr].get(x, 0.005)
                else:
                    s = errors.majx_success(x, n_act, **env)
                retries = expected_retries(s)
            issue = (self.lat.majx_apa if pipelined
                     else majx_issue_ns(x, n_act))
            return issue * retries
        if op == "MRC":
            retries = 1.0
            if errors is not None:
                retries = expected_retries(
                    errors.mrc_success(n_act - 1, **env))
            return self.lat.mrc * retries
        if op in ("NOT", "COPY"):
            retries = 1.0
            if errors is not None:
                retries = expected_retries(
                    errors.mrc_success(1, t1=36.0, t2=6.0, **env))
            return self.lat.rowclone * retries
        if op == "FRAC":
            return self.lat.frac
        if op == "WR":
            return self.lat.wr_row
        if op == "RD":
            return self.lat.rd_row
        raise ValueError(f"unknown op kind {op!r}")

    def energy_nj(self, op: str, duration_ns: Optional[float] = None, *,
                  x: int = 0, n_act: int = 0,
                  errors: Optional[ErrorModel] = None, **env) -> float:
        """Energy (nJ) of one op issue — W x ns, both sides modelled here.

        Two calling styles:

        * ``energy_nj("REF", duration_ns=90.0)`` — hold a Fig. 5 power
          series for an explicit duration (the
          :func:`repro.core.power.energy_nj` path, same ValueError on
          unknown series);
        * ``energy_nj("MAJ", x=3, n_act=32, errors=em)`` — one op-kind
          issue: SiMRA power at the activation count over the (retry
          -aware when ``errors`` given) issue latency for MAJ/MRC,
          ACT_PRE / WR / RD power over the command latency otherwise.
          Matching the §8 methodology (and the historical
          ``Program.energy_nj``), support-op retries are a *latency*
          phenomenon only — NOT/COPY energy prices one clean issue.
        """
        if duration_ns is not None:
            return pw.energy_nj(op, duration_ns)
        if op in ("MAJ", "MRC"):
            t = self.latency_ns(op, x=x, n_act=n_act, errors=errors, **env)
            return pw.simra_power_w(n_act) * t
        series = _KIND_SERIES.get(op)
        if series is None:
            raise ValueError(f"unknown op kind {op!r}")
        return pw.energy_nj(series, self.latency_ns(op))

    def program_latency_ns(self, program, errors: ErrorModel, *,
                           pipelined: bool = False,
                           best_group: bool = False, **env) -> float:
        """Expected execution time of a whole op stream (see
        :meth:`repro.pud.isa.Program.latency_ns`, which delegates
        here)."""
        return sum(
            self.latency_ns(op.kind, x=op.x, n_act=op.n_act, errors=errors,
                            pipelined=pipelined, best_group=best_group,
                            **env)
            for op in program.ops)

    def program_energy_nj(self, program, errors: ErrorModel,
                          **env) -> float:
        """Energy of a whole op stream from the Fig. 5 power model (see
        :meth:`repro.pud.isa.Program.energy_nj`, which delegates
        here)."""
        return sum(
            self.energy_nj(op.kind, x=op.x, n_act=op.n_act, errors=errors,
                           **env)
            for op in program.ops)

    # ----------------------------------------------------------- TPU side
    def hbm_ns(self, n_bytes: float) -> float:
        """Time (ns) to move ``n_bytes`` through HBM at full bandwidth."""
        return n_bytes / self.hbm_bytes_per_s * 1e9

    def hbm_energy_nj(self, n_bytes: float) -> float:
        """DRAM access energy of moving ``n_bytes`` through HBM."""
        return n_bytes * self.hbm_pj_per_byte * 1e-3  # pJ -> nJ

    def dispatch_overhead(self, n_dispatches: int = 1) -> float:
        """Host-side launch overhead (ns) of ``n_dispatches`` kernels —
        the structural cost fusion and the megakernel collapse."""
        return n_dispatches * self.kernel_launch_ns

    def dispatch_energy_nj(self, n_dispatches: int = 1) -> float:
        """Energy of ``n_dispatches`` kernel launches: board power held
        for each launch round-trip."""
        return n_dispatches * self.kernel_launch_ns * self.tpu_avg_w


#: The repo-wide pricing authority.  Offload, roofline, the backend
#: energy counters, and both bench schemas all read THIS instance.
COST = CostModel()

#: Single-source TPU constants (re-exported by ``repro.pud.offload`` and
#: ``repro.launch.roofline``; tests/test_costmodel.py pins them equal).
PEAK_FLOPS = COST.peak_flops
HBM_BYTES_PER_S = COST.hbm_bytes_per_s
HBM_BW = COST.hbm_bytes_per_s
ICI_BW = COST.ici_bytes_per_s
KERNEL_LAUNCH_NS = COST.kernel_launch_ns
