"""Hierarchical row-decoder model (paper §7.1).

The paper hypothesises that simultaneous many-row activation arises from the
two-stage local wordline decoder: Stage 1 predecodes the 9-bit in-subarray
row address across five predecoder tiers (A..E) whose outputs are *latched*;
an APA sequence with violated tRP latches the second address *without
de-asserting* the first, so each predecoder may hold up to two one-hot
outputs.  Stage 2 asserts every local wordline whose predecoded address is
covered by the latched sets — the activated set is the Cartesian product of
the per-predecoder latched codes, giving 2^k rows where k is the number of
predecoders on which the two addresses differ (Limitation 2: only
2/4/8/16/32 are reachable).

Worked example from Fig. 14: APA(0, 7) with bit groups A=RA[0], B=RA[1:3]
latches {PA0,PA1} x {PB0,PB3} -> rows {0,1,6,7}.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core import calibration as cal


@dataclasses.dataclass(frozen=True)
class PredecoderSpec:
    """One predecoder tier: a contiguous slice of row-address bits."""

    name: str
    lo: int  # inclusive bit index (LSB-first)
    hi: int  # exclusive

    @property
    def width(self) -> int:
        return self.hi - self.lo

    def code(self, row: int) -> int:
        return (row >> self.lo) & ((1 << self.width) - 1)


def default_predecoders(row_bits: int) -> tuple[PredecoderSpec, ...]:
    """The paper's 5-tier split.

    For 2^9-row subarrays (SK Hynix, §7.1): A=1 bit, B..E=2 bits each.
    For 2^10-row subarrays (Micron): A..E=2 bits each.
    Both give 5 predecoders -> up to 2^5 = 32 simultaneous rows.
    """
    if row_bits == 9:
        widths = (1, 2, 2, 2, 2)
    elif row_bits == 10:
        widths = (2, 2, 2, 2, 2)
    else:
        # Generic: distribute bits over 5 tiers, wider tiers last.
        base, extra = divmod(row_bits, cal.DECODER_NUM_PREDECODERS)
        widths = tuple(
            base + (1 if i >= cal.DECODER_NUM_PREDECODERS - extra else 0)
            for i in range(cal.DECODER_NUM_PREDECODERS)
        )
    specs = []
    lo = 0
    for name, w in zip("ABCDE", widths):
        specs.append(PredecoderSpec(name, lo, lo + w))
        lo += w
    assert lo == row_bits
    return tuple(specs)


@dataclasses.dataclass
class RowDecoder:
    """Behavioural model of the latching local wordline decoder."""

    n_rows: int
    predecoders: tuple[PredecoderSpec, ...]

    @classmethod
    def for_subarray(cls, n_rows: int) -> "RowDecoder":
        row_bits = max(1, (n_rows - 1).bit_length())
        return cls(n_rows=n_rows, predecoders=default_predecoders(row_bits))

    # -- single activation ------------------------------------------------
    def decode(self, row: int) -> tuple[int, ...]:
        """Standard ACT: one wordline."""
        self._check(row)
        return (row,)

    # -- APA with violated timings ----------------------------------------
    def apa_activated_rows(self, row_first: int, row_second: int) -> tuple[int, ...]:
        """Rows asserted by ACT(rf) -> PRE -> ACT(rs) with violated tRAS/tRP.

        Each predecoder latches {code(rf), code(rs)}; the asserted wordline
        set is the Cartesian product of the latched codes.
        """
        self._check(row_first)
        self._check(row_second)
        latched: list[tuple[int, ...]] = []
        for p in self.predecoders:
            codes = {p.code(row_first), p.code(row_second)}
            latched.append(tuple(sorted(codes)))
        rows = []
        for combo in itertools.product(*latched):
            row = 0
            for p, code in zip(self.predecoders, combo):
                row |= code << p.lo
            if row < self.n_rows:
                rows.append(row)
        return tuple(sorted(rows))

    def n_activated(self, row_first: int, row_second: int) -> int:
        return len(self.apa_activated_rows(row_first, row_second))

    def split_predecoders(self, row_first: int, row_second: int) -> int:
        """Number of predecoders on which the two addresses differ."""
        return sum(
            1
            for p in self.predecoders
            if p.code(row_first) != p.code(row_second)
        )

    # -- inverse problem: find an APA pair for a target set ---------------
    def pair_for_n_rows(self, n: int, base_row: int = 0) -> tuple[int, int]:
        """An (rf, rs) pair that simultaneously activates exactly ``n`` rows.

        ``n`` must be a power of two <= 2^(#predecoders) (Limitation 2).
        The returned pair differs on the log2(n) *widest-spread* predecoders
        so that all activated rows stay within the subarray.
        """
        k = n.bit_length() - 1
        if n != 1 << k or k > len(self.predecoders):
            raise ValueError(
                f"cannot activate {n} rows: only powers of two up to "
                f"2^{len(self.predecoders)} are reachable (Limitation 2)"
            )
        self._check(base_row)
        rs = base_row
        for p in self.predecoders[:k]:
            # Flip the low bit of this predecoder's field.
            rs ^= 1 << p.lo
        if rs >= self.n_rows:
            raise ValueError(f"row {rs} out of range for base {base_row}")
        return base_row, rs

    def row_group(self, n: int, base_row: int = 0) -> tuple[int, ...]:
        rf, rs = self.pair_for_n_rows(n, base_row)
        return self.apa_activated_rows(rf, rs)

    def _check(self, row: int) -> None:
        if not 0 <= row < self.n_rows:
            raise ValueError(f"row {row} out of range [0, {self.n_rows})")


def fig14_example() -> tuple[int, ...]:
    """The paper's walk-through: APA(0, 7) on a 512-row subarray -> {0,1,6,7}."""
    return RowDecoder.for_subarray(512).apa_activated_rows(0, 7)


def fig13_32row_example() -> tuple[int, ...]:
    """§7.1: ACT 127 -> PRE -> ACT 128 splits all five predecoders -> 32 rows."""
    return RowDecoder.for_subarray(512).apa_activated_rows(127, 128)
