"""Op-level MAJX on a subarray (paper §3.3, §5).

Characterization flow (five steps, §3.3):
  1. store the X input operands in X rows of the activation group,
  2. replicate them floor(N/X) times across the group (Multi-RowCopy),
  3. Frac-initialize the N%X leftover rows to neutral,
  4. issue APA with the MAJX-optimal timings (t1=1.5ns, t2=3ns),
  5. read the result back from the row buffer.

`majx` performs all five steps against a :class:`~repro.core.subarray.Subarray`
and returns the packed result plane.  `majx_reference` is the pure boolean
oracle used by tests and by the Pallas kernel's ref.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import bitplanes as bp
from repro.core import calibration as cal
from repro.core import commands as cmd
from repro.core.subarray import Subarray


def majx_reference(operands: jax.Array) -> jax.Array:
    """Pure bitwise majority over packed operand planes, shape (X, words)."""
    return bp.majority(jnp.asarray(operands, jnp.uint32), axis=0)


def majx(
    sa: Subarray,
    operands: Sequence[jax.Array],
    n_act: int,
    *,
    t1_ns: float = cal.MAJX_BEST_T1_NS,
    t2_ns: float = cal.MAJX_BEST_T2_NS,
    base_row: int = 0,
    pattern: str = "random",
) -> jax.Array:
    """Run MAJX over ``operands`` using N-row activation; returns the result.

    ``operands`` are packed uint32 planes (each a full row image).  The
    function stages operands + replicas + neutral rows into the activation
    group rooted at ``base_row`` exactly as §3.3 prescribes.
    """
    x = len(operands)
    if x % 2 == 0 or x < 3:
        raise ValueError("MAJX requires odd X >= 3")
    copies, neutral = cal.replication_plan(x, n_act)
    rf, rs = sa.decoder.pair_for_n_rows(n_act, base_row)
    group = sa.decoder.apa_activated_rows(rf, rs)
    assert len(group) == n_act

    # Steps 1+2: operands and their replicas.
    slots = list(group)
    for c in range(copies):
        for i, op_plane in enumerate(operands):
            sa.write_row(slots[c * x + i], op_plane)
    # Step 3: neutral rows via Frac (Mfr M: bias-emulated, §3.3 fn 5).
    for j in range(copies * x, n_act):
        sa.run(cmd.frac(slots[j]))
    # Step 4: the APA, with the operand-count hint for the error surface.
    sa.hint(x=x, pattern=pattern)
    sa.run(cmd.apa(rf, rs, t1_ns, t2_ns))
    # Step 5: read back the row buffer.
    return sa.row_buffer


def majx_success_measured(
    sa: Subarray,
    operands: Sequence[jax.Array],
    n_act: int,
    **kw,
) -> float:
    """Fraction of bitlines whose MAJX result is correct (one trial).

    Mirrors the paper's §3.3 measurement on our behavioural model.
    """
    got = majx(sa, operands, n_act, **kw)
    want = majx_reference(jnp.stack([jnp.asarray(o, jnp.uint32) for o in operands]))
    same = ~(got ^ want)
    return float(jnp.sum(bp.popcount(same))) / (sa.n_words * 32)


def and_via_maj3(sa: Subarray, a, b, n_act: int = 4, **kw) -> jax.Array:
    """AND(a,b) = MAJ3(a, b, 0)  (Ambit-style, §8.1)."""
    zero = jnp.zeros_like(jnp.asarray(a, jnp.uint32))
    return majx(sa, [a, b, zero], n_act, **kw)


def or_via_maj3(sa: Subarray, a, b, n_act: int = 4, **kw) -> jax.Array:
    """OR(a,b) = MAJ3(a, b, 1)."""
    ones = jnp.full_like(jnp.asarray(a, jnp.uint32), 0xFFFFFFFF)
    return majx(sa, [a, b, ones], n_act, **kw)
