"""Elastic scaling: re-mesh surviving devices and reshard state.

Flow on node loss (or scale-up): checkpoint (or live state) -> build a new
mesh from the surviving device set -> recompute NamedShardings from the
*same logical axes* -> device_put resharding -> resume.  Because shardings
derive from logical axes, no per-tensor surgery is needed; the data
pipeline is step-keyed so the batch stream continues exactly.

`plan_remesh` chooses the largest (data x model) grid that preserves the
model axis (TP degree is an algorithmic choice; DP shrinks with capacity).
"""

from __future__ import annotations

from typing import Optional, Sequence, TypeVar

import jax
from jax.sharding import Mesh

from repro.dist.sharding import AxisRules, DEFAULT_RULES, tree_shardings

_T = TypeVar("_T")


class ElasticMembership:
    """Live-worker roster with deterministic shard (re)planning.

    The sweep engine's fault-tolerant driver
    (:func:`repro.sweep.runner.run_sweep_ft`) partitions pending chunks
    round-robin across the *live* workers — the same deterministic
    rule as :func:`repro.sweep.planner.shard` — and replans whenever
    membership changes: a dropped worker's share is automatically
    redistributed because the partition is a pure function of
    ``(items, live roster)``.  ``generation`` increments on every
    membership change, so long-lived holders of a partition can detect
    staleness without comparing rosters.
    """

    def __init__(self, n_workers: int):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self._live: list[int] = list(range(n_workers))
        self.dropped: list[int] = []
        self.generation = 0

    @property
    def live(self) -> tuple[int, ...]:
        return tuple(self._live)

    def is_live(self, worker: int) -> bool:
        return worker in self._live

    def drop(self, worker: int) -> None:
        """Remove a worker from the roster (idempotent)."""
        if worker in self._live:
            self._live.remove(worker)
            self.dropped.append(worker)
            self.generation += 1

    def join(self, worker: int) -> None:
        """(Re-)admit a worker; the partition replans around it."""
        if worker not in self._live:
            self._live.append(worker)
            self._live.sort()
            if worker in self.dropped:
                self.dropped.remove(worker)
            self.generation += 1

    def plan(self, items: Sequence[_T]) -> dict[int, list[_T]]:
        """Round-robin partition of ``items`` over the live roster."""
        out: dict[int, list[_T]] = {w: [] for w in self._live}
        for i, item in enumerate(items):
            out[self._live[i % len(self._live)]].append(item)
        return out

    def share(self, items: Sequence[_T], worker: int) -> list[_T]:
        """One live worker's slice of the current partition."""
        if worker not in self._live:
            return []
        return self.plan(items)[worker]


def plan_remesh(n_devices: int, model_parallel: int,
                pods: int = 1) -> tuple[int, ...]:
    """Largest usable (pods, data, model) grid on the surviving devices."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than the TP degree; cannot remesh")
    per_pod = n_devices // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError("not enough devices per pod for one data replica")
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


def make_mesh_from(devices, shape: tuple[int, ...]) -> Mesh:
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    import numpy as np

    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, names)


def reshard(tree, axes_tree, new_mesh: Mesh,
            rules: AxisRules = DEFAULT_RULES):
    """Reshard a live pytree onto a new mesh (device_put with new specs)."""
    shardings = tree_shardings(axes_tree, new_mesh, rules)
    return jax.tree.map(jax.device_put, tree, shardings)


def elastic_restart(tree_like, axes_tree, ckpt_dir: str, devices,
                    model_parallel: int, pods: int = 1,
                    step: Optional[int] = None):
    """Restore the latest checkpoint onto a fresh mesh over ``devices``."""
    from repro.ckpt import checkpoint as ckpt

    shape = plan_remesh(len(devices), model_parallel, pods)
    mesh = make_mesh_from(devices, shape)
    tree, found = ckpt.restore(tree_like, ckpt_dir, step)
    return reshard(tree, axes_tree, mesh), mesh, found
