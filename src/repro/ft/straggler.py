"""Straggler detection & mitigation.

At pod scale the dominant mitigation is *not* per-op work stealing (SPMD
steps are lockstep) but (a) detecting persistently slow workers and
(b) re-meshing without them (see repro.ft.elastic), plus (c) bounded-delay
step skipping for transient hiccups.  The detector keeps a per-worker EMA
of step durations and flags workers whose EMA exceeds the fleet median by
``threshold`` x; the trainer consults it every ``check_every`` steps.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    n_workers: int
    alpha: float = 0.2
    threshold: float = 1.5
    ema: np.ndarray = None

    def __post_init__(self):
        if self.ema is None:
            self.ema = np.zeros(self.n_workers)

    def record(self, worker: int, step_time_s: float) -> None:
        cur = self.ema[worker]
        self.ema[worker] = (step_time_s if cur == 0
                            else (1 - self.alpha) * cur + self.alpha * step_time_s)

    def stragglers(self) -> list[int]:
        active = self.ema[self.ema > 0]
        if active.size < max(2, self.n_workers // 2):
            return []
        median = float(np.median(active))
        return [int(i) for i in range(self.n_workers)
                if self.ema[i] > self.threshold * median]

    def fleet_slowdown(self) -> float:
        """Step-time inflation caused by the slowest worker (lockstep SPMD)."""
        active = self.ema[self.ema > 0]
        if active.size == 0:
            return 1.0
        return float(active.max() / np.median(active))
