"""Straggler detection & mitigation.

At pod scale the dominant mitigation is *not* per-op work stealing (SPMD
steps are lockstep) but (a) detecting persistently slow workers and
(b) re-meshing without them (see repro.ft.elastic), plus (c) bounded-delay
step skipping for transient hiccups.  The detector keeps a per-worker EMA
of step durations and flags workers whose EMA exceeds the fleet median by
``threshold`` x; the trainer consults it every ``check_every`` steps, and
the serve layer's SLO monitor (:mod:`repro.serve.slo`) reuses it with one
"worker" per pooled ``DramSession`` to flag persistently slow sessions.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    """Per-worker EMA step-time tracker (see module docstring).

    ``ema`` may be seeded with a prior ``(n_workers,)`` vector (resuming
    a detector across re-meshes); by default every worker starts cold at
    0.0, meaning "no sample yet".  The field is normalized and
    shape-checked in ``__post_init__`` — after construction it is always
    a float ``(n_workers,)`` array, never ``None``.
    """

    n_workers: int
    alpha: float = 0.2
    threshold: float = 1.5
    ema: Optional[np.ndarray] = dataclasses.field(default=None)

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        if self.ema is None:
            self.ema = np.zeros(self.n_workers)
        else:
            self.ema = np.asarray(self.ema, dtype=float)
            if self.ema.shape != (self.n_workers,):
                raise ValueError(
                    f"seeded ema shape {self.ema.shape} != "
                    f"({self.n_workers},)")

    def record(self, worker: int, step_time_s: float) -> None:
        cur = self.ema[worker]
        self.ema[worker] = (step_time_s if cur == 0
                            else (1 - self.alpha) * cur + self.alpha * step_time_s)

    def stragglers(self) -> list[int]:
        active = self.ema[self.ema > 0]
        if active.size < max(2, self.n_workers // 2):
            return []
        median = float(np.median(active))
        return [int(i) for i in range(self.n_workers)
                if self.ema[i] > self.threshold * median]

    def fleet_slowdown(self) -> float:
        """Step-time inflation caused by the slowest worker (lockstep SPMD)."""
        active = self.ema[self.ema > 0]
        if active.size == 0:
            return 1.0
        return float(active.max() / np.median(active))
