"""Straggler detection & mitigation.

At pod scale the dominant mitigation is *not* per-op work stealing (SPMD
steps are lockstep) but (a) detecting persistently slow workers and
(b) re-meshing without them (see repro.ft.elastic), plus (c) bounded-delay
step skipping for transient hiccups.  The detector keeps a per-worker EMA
of step durations and flags workers whose EMA exceeds the fleet median by
``threshold`` x; the trainer consults it every ``check_every`` steps, the
serve layer's SLO monitor (:mod:`repro.serve.slo`) reuses it with one
"worker" per pooled ``DramSession`` to flag persistently slow sessions,
and the sweep engine's fault-tolerant runner
(:func:`repro.sweep.runner.run_sweep_ft`) feeds it per-chunk wall times
to decide which workers' in-flight chunks to re-dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    """Per-worker EMA step-time tracker (see module docstring).

    ``ema`` may be seeded with a prior ``(n_workers,)`` vector (resuming
    a detector across re-meshes); a seeded detector is treated as warm —
    every worker counts as having one prior sample unless ``n_samples``
    is seeded alongside it.  Cold workers ("no sample yet") are tracked
    by the explicit ``n_samples`` counter, *never* by an ``ema == 0``
    sentinel: a genuine 0.0-duration sample (or an EMA that decays to
    0) still marks its worker as measured, so it participates in
    :meth:`stragglers` / :meth:`fleet_slowdown` like any other.  Both
    fields are normalized and shape-checked in ``__post_init__`` — after
    construction they are always ``(n_workers,)`` arrays, never ``None``.
    """

    n_workers: int
    alpha: float = 0.2
    threshold: float = 1.5
    ema: Optional[np.ndarray] = dataclasses.field(default=None)
    n_samples: Optional[np.ndarray] = dataclasses.field(default=None)

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        seeded = self.ema is not None
        if not seeded:
            self.ema = np.zeros(self.n_workers)
        else:
            self.ema = np.asarray(self.ema, dtype=float)
            if self.ema.shape != (self.n_workers,):
                raise ValueError(
                    f"seeded ema shape {self.ema.shape} != "
                    f"({self.n_workers},)")
        if self.n_samples is None:
            self.n_samples = (np.ones(self.n_workers, dtype=np.int64)
                              if seeded else
                              np.zeros(self.n_workers, dtype=np.int64))
        else:
            self.n_samples = np.asarray(self.n_samples, dtype=np.int64)
            if self.n_samples.shape != (self.n_workers,):
                raise ValueError(
                    f"seeded n_samples shape {self.n_samples.shape} != "
                    f"({self.n_workers},)")

    def record(self, worker: int, step_time_s: float) -> None:
        if self.n_samples[worker] == 0:
            self.ema[worker] = step_time_s
        else:
            self.ema[worker] = ((1 - self.alpha) * self.ema[worker]
                                + self.alpha * step_time_s)
        self.n_samples[worker] += 1

    def _measured(self) -> np.ndarray:
        return self.n_samples > 0

    def stragglers(self) -> list[int]:
        measured = self._measured()
        active = self.ema[measured]
        if active.size < max(2, self.n_workers // 2):
            return []
        median = float(np.median(active))
        return [int(i) for i in range(self.n_workers)
                if measured[i] and self.ema[i] > self.threshold * median]

    def fleet_slowdown(self) -> float:
        """Step-time inflation caused by the slowest worker (lockstep SPMD)."""
        active = self.ema[self._measured()]
        if active.size == 0:
            return 1.0
        median = float(np.median(active))
        if median == 0.0:
            # An all-instant (or decayed-to-zero) fleet has no meaningful
            # relative slowdown; any nonzero worker above it is infinite.
            return float("inf") if float(active.max()) > 0.0 else 1.0
        return float(active.max() / median)
