"""Failure injection & detection for the checkpoint-restart trainer.

On a real cluster, failures surface as missing heartbeats / NCCL-ICI
timeouts; here they are injected deterministically so the restart path is
exercised by tests and examples.  The trainer treats any
:class:`SimulatedFailure` as a node loss: it re-initializes from the last
committed checkpoint and replays the data stream from the recorded step
(the pipeline is step-keyed, so replay is exact).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


class SimulatedFailure(RuntimeError):
    """A injected node/process failure."""


class WorkerLost(SimulatedFailure):
    """An injected sweep-worker loss.

    Raised from a worker hook to simulate a process dying mid-chunk;
    the fault-tolerant sweep driver
    (:func:`repro.sweep.runner.run_sweep_ft`) treats it as permanent
    membership loss: the worker leaves the elastic partition and its
    in-flight chunk is released for the survivors.
    """


@dataclasses.dataclass
class FailurePlan:
    """Fail at specific steps (once each)."""

    at_steps: tuple[int, ...] = ()
    kind: str = "node_loss"
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"{self.kind} at step {step}")


@dataclasses.dataclass
class HeartbeatMonitor:
    """Deadline-based failure detector (the real-cluster shape of check())."""

    deadline_s: float = 300.0
    last_beat: Optional[float] = None

    def beat(self, now: float) -> None:
        self.last_beat = now

    def healthy(self, now: float) -> bool:
        return self.last_beat is None or (now - self.last_beat) < self.deadline_s
