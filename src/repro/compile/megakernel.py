"""Megakernel lowering: a whole fused Schedule as ONE kernel's level tables.

The level-fused executor (:meth:`repro.backends.pallas.PallasBackend.
run_fused`) already collapses each dependency level into at most one MAJX
plus one Multi-RowCopy dispatch — but a 34-level adder is still 34 kernel
launches, and per-level launch overhead is the dominant cost the
``BENCH_fused.json`` trajectory shows (the command-stream overhead PULSAR
attributes to sequencing many-row activations).  This module lowers a
:class:`~repro.compile.schedule.Schedule` to *static level tables* that a
single Pallas dispatch executes end-to-end: ``lax.scan`` over the level
axis with the packed ``uint32`` bit-plane state resident in VMEM.

Lowering model — every schedulable op becomes one or more **write
slots**, and a level is a fixed-width array of slots:

* a ``MAJ_k`` op is one slot per destination row, its ``k`` operand
  indices padded to the program-wide widest arity ``x_max`` with
  constant (all-0, all-1) row *pairs* — the exact
  ``MAJ_k == MAJ_{k+2m}(.., 0*m, 1*m)`` identity the level-fused path
  already relies on;
* a Multi-RowCopy wave is one arity-1 identity slot per destination
  (``MAJ_1(src) == src``), so an MRC's fan-out becomes ``len(dsts)``
  parallel slots of one level;
* ``NOT`` / ``COPY`` are arity-1 identity slots, NOT with the slot's
  invert flag set (the kernel XORs the vote with all-ones);
* levels narrower than the widest level pad with inert slots that read
  the constant zero row and write the trash row.

The executing kernel therefore needs exactly one primitive — gather
``(W, X)`` operand rows, bit-sliced majority over ``X`` packed words,
optional complement, scatter to ``W`` destination rows — repeated
``n_levels`` times inside one ``pallas_call``.  WAW leveling guarantees
each level's scatters hit disjoint rows, and all reads sample the
level-entry state, so megakernel execution is bit-identical to per-op
interpretation by construction (verified adversarially in
``tests/test_megakernel_differential.py`` and frozen per-program in
``tests/golden``).

Row-space layout: the kernel image prepends three **constant rows** in
front of the program's rows, so a lowering depends only on program
content (never on the height of the state it later runs against) — the
property that lets :class:`repro.session.cache.CompileCache` key lowered
artifacts by the same content hash as schedules:

    row 0: all-zero   (MAJ padding, inert-slot source)
    row 1: all-one    (MAJ padding)
    row 2: trash      (inert-slot destination)
    row 3..: program rows, shifted by :data:`N_CONST_ROWS`

All ops are bitwise per packed word, so word columns are independent:
when the working set exceeds the backend's VMEM budget,
:func:`plan_vmem` splits the word axis into column blocks streamed
through the Pallas pipeline's double-buffered HBM fetches — still one
dispatch, never one per level.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.compile.schedule import Schedule

#: Augmented-image layout (see module docstring).
ZERO_ROW = 0
ONE_ROW = 1
TRASH_ROW = 2
N_CONST_ROWS = 3


@dataclasses.dataclass(frozen=True, eq=False)
class MegaLowering:
    """Static level tables for one-dispatch execution of a Schedule.

    ``src``: (n_levels, w_max, x_max) int32 operand row indices into the
    augmented image; ``dst``: (n_levels, w_max) int32 destination rows;
    ``inv``: (n_levels, w_max) uint32 complement flags (1 = XOR the vote
    with all-ones).  ``level_meta`` records, per level, the live slot
    counts by kind ``(MAJ, MRC, NOT, COPY)`` — the structural shape the
    golden fixtures freeze so a lowering change that silently reorders
    levels fails loudly.
    """

    src: np.ndarray
    dst: np.ndarray
    inv: np.ndarray
    n_rows: int
    level_meta: tuple[tuple[int, int, int, int], ...]

    @property
    def n_levels(self) -> int:
        return self.src.shape[0]

    @property
    def w_max(self) -> int:
        """Write slots per level (the padded level width)."""
        return self.src.shape[1]

    @property
    def x_max(self) -> int:
        """Operand slots per write slot (the padded vote arity; odd)."""
        return self.src.shape[2]

    @property
    def table_bytes(self) -> int:
        """Metadata bytes staged as scalar-prefetch/SMEM tables."""
        return self.src.nbytes + self.dst.nbytes + self.inv.nbytes

    def digest(self) -> str:
        """Content fingerprint of the lowered tables.

        Golden fixtures freeze this: any change to level order, slot
        packing, padding policy, or constant-row layout changes the
        digest even when the final state happens to agree.
        """
        h = hashlib.sha256()
        h.update(f"{self.src.shape}|{self.n_rows}\n".encode())
        for arr in (self.src, self.dst, self.inv):
            h.update(np.ascontiguousarray(arr).tobytes())
        return h.hexdigest()


def lower_schedule(sched: Schedule) -> MegaLowering:
    """Lower a fused Schedule to :class:`MegaLowering` level tables.

    Pure function of schedule content: two programs with identical op
    streams lower to byte-identical tables (what makes the artifact
    cacheable under the schedule's own content hash).
    """
    x_max = 1
    w_max = 0
    n_rows = 0
    for lvl in sched.levels:
        width = 0
        for g in lvl:
            if g.kind == "MAJ":
                x_max = max(x_max, g.param)
            for op in g.ops:
                width += len(op.dsts)
                for r in op.srcs + op.dsts:
                    n_rows = max(n_rows, r + 1)
        w_max = max(w_max, width)

    n_levels = len(sched.levels)
    src = np.full((n_levels, w_max, x_max), ZERO_ROW, np.int32)
    dst = np.full((n_levels, w_max), TRASH_ROW, np.int32)
    inv = np.zeros((n_levels, w_max), np.uint32)
    meta = []
    for li, lvl in enumerate(sched.levels):
        slot = 0
        counts = {"MAJ": 0, "MRC": 0, "NOT": 0, "COPY": 0}
        for g in lvl:
            for op in g.ops:
                if g.kind == "MAJ":
                    k = len(op.srcs)
                    if (x_max - k) % 2:
                        raise ValueError(
                            f"cannot pad MAJ{k} to MAJ{x_max}: parity "
                            f"differs")
                    pad = (x_max - k) // 2
                    operands = ([s + N_CONST_ROWS for s in op.srcs]
                                + [ZERO_ROW] * pad + [ONE_ROW] * pad)
                else:  # MRC / NOT / COPY: arity-1 identity vote
                    pad = (x_max - 1) // 2
                    operands = ([op.srcs[0] + N_CONST_ROWS]
                                + [ZERO_ROW] * pad + [ONE_ROW] * pad)
                for d in op.dsts:
                    src[li, slot] = operands
                    dst[li, slot] = d + N_CONST_ROWS
                    inv[li, slot] = 1 if g.kind == "NOT" else 0
                    counts[g.kind] += 1
                    slot += 1
        meta.append((counts["MAJ"], counts["MRC"], counts["NOT"],
                     counts["COPY"]))
    return MegaLowering(src=src, dst=dst, inv=inv, n_rows=n_rows,
                        level_meta=tuple(meta))


@dataclasses.dataclass(frozen=True)
class VmemPlan:
    """Column-blocking decision for one megakernel launch.

    ``resident`` means the whole augmented image fits one VMEM block
    (single grid step); otherwise the word axis splits into ``block_c``
    -wide column slabs streamed through the Pallas pipeline's
    double-buffered HBM fetches.  Either way: one dispatch.
    """

    block_c: int
    resident: bool
    working_set_bytes: int
    budget_bytes: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def plan_vmem(lowering: MegaLowering, rows: int, words: int,
              budget_bytes: int, *, block_r: int = 8,
              lane_width: int = 128) -> VmemPlan:
    """Pick the widest VPU-aligned column block the VMEM budget allows.

    Bytes per word column: the state block appears twice (pipeline in +
    out buffers) plus the per-level gather ``(w_max, x_max)`` operand
    planes and the vote's counter digits; the scalar-prefetch tables are
    charged once, column-independent.
    """
    rows_aug = -(-(rows + N_CONST_ROWS) // block_r) * block_r
    words_padded = -(-words // lane_width) * lane_width
    digits = max(lowering.x_max.bit_length(), 1)
    per_col = 4 * (2 * rows_aug
                   + lowering.w_max * (lowering.x_max + digits + 1))
    usable = max(budget_bytes - lowering.table_bytes, per_col * lane_width)
    block_c = max(usable // per_col // lane_width, 1) * lane_width
    block_c = min(block_c, words_padded)
    working = per_col * words_padded + lowering.table_bytes
    return VmemPlan(block_c=int(block_c),
                    resident=bool(block_c >= words_padded),
                    working_set_bytes=int(working),
                    budget_bytes=int(budget_bytes))
