"""Program-fusion scheduler: dependency levels -> batched dispatch groups.

The per-op interpreter (:meth:`repro.backends.base.Backend.run`) launches
one kernel per MAJ/MRC op, so a 32-bit ripple-carry adder costs ~100 tiny
dispatches.  PULSAR-style, the win comes from amortizing command overhead
across many simultaneously issued operations: this module partitions an
addressed :class:`~repro.pud.isa.Program` into *dependency levels* — maximal
sets of ops that can execute against the same entry state — and fuses each
level into at most one MAJX dispatch plus at most one Multi-RowCopy
dispatch.  The ``pallas`` backend's :meth:`run_fused` walks the schedule;
per-op and fused execution are bit-identical by construction (verified
adversarially in ``tests/test_compile_differential.py``).

Hazard model (reads sample the level-entry state, writes commit at level
exit):

* **RAW** — an op reading row ``r`` is placed strictly after the level
  that last wrote ``r``;
* **WAW** — two writers of the same row land in different levels, so no
  level scatters twice into one row;
* **WAR** — a writer may share a level with *earlier* readers of its
  destination (they read the entry state, matching program order), but a
  reader that follows the writer in program order is pushed later by RAW.

Destination-aliasing programs (an op whose ``dsts`` intersect its
``srcs``, or rows rewritten many times) therefore schedule correctly.

Mixed-arity MAJ fusion uses the exact padding identity

    ``MAJ_k(x_1..x_k) == MAJ_{k+2m}(x_1..x_k, 0 * m, 1 * m)``

(each constant 0/1 *pair* adds one to the popcount and one to the
majority threshold), so one batched kernel launch serves every arity in
a level; the constant planes are synthesized by the executor, never
materialized as state rows.
"""

from __future__ import annotations

import dataclasses

from repro.pud.isa import Program, PUDOp

#: Op kinds that change the (rows, words) image.  FRAC initializes rows
#: to the neutral charge state (value-wise a no-op on every backend), and
#: WR/RD are I/O accounting ops, so none of them schedule.
VALUE_KINDS = ("MAJ", "NOT", "COPY", "MRC")


@dataclasses.dataclass(frozen=True)
class FusedGroup:
    """Ops of one kind inside one level, executed as a single batch.

    ``param`` is the batch-shape parameter: the widest MAJ arity in the
    group (narrower ops are padded with 0/1 plane pairs) or the widest
    MRC fan-out (ops with fewer destinations scatter a prefix of the
    copies).  NOT/COPY groups are pure gather/scatter (no kernel).
    """

    kind: str
    param: int
    ops: tuple[PUDOp, ...]

    @property
    def is_dispatch(self) -> bool:
        """True when executing this group costs one kernel launch."""
        return self.kind in ("MAJ", "MRC")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A leveled, batched execution plan for one Program."""

    levels: tuple[tuple[FusedGroup, ...], ...]

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def n_dispatches(self) -> int:
        """Kernel launches the fused executor will issue."""
        return sum(1 for lvl in self.levels for g in lvl if g.is_dispatch)

    def per_op_dispatches(self) -> int:
        """Kernel launches the per-op interpreter issues for the same ops."""
        return sum(len(g.ops) for lvl in self.levels
                   for g in lvl if g.is_dispatch)

    def histogram(self) -> dict[tuple, int]:
        """(kind, param) -> group count, for structural assertions."""
        h: dict[tuple, int] = {}
        for lvl in self.levels:
            for g in lvl:
                h[(g.kind, g.param)] = h.get((g.kind, g.param), 0) + 1
        return h


def _schedulable(op: PUDOp) -> bool:
    if not op.dsts:
        return False  # cost-only record: nothing addressable to do
    if op.kind in ("FRAC", "WR", "RD"):
        return False  # value-wise no-ops (see VALUE_KINDS)
    if op.kind not in VALUE_KINDS:
        raise ValueError(f"unknown op kind {op.kind}")
    return True


def dependency_levels(program: Program) -> list[list[PUDOp]]:
    """Partition value-affecting ops into hazard-respecting levels.

    Greedy list scheduling in program order: each op lands on the
    earliest level satisfying the RAW/WAW/WAR constraints in the module
    docstring.  Dead ops (results never read) still schedule — they
    write state the differential tests compare.
    """
    write_level: dict[int, int] = {}   # row -> level of its last writer
    read_level: dict[int, int] = {}    # row -> latest level that read it
    levels: list[list[PUDOp]] = []
    for op in program.ops:
        if not _schedulable(op):
            continue
        lvl = 0
        for s in op.srcs:
            if s in write_level:               # RAW: read strictly after
                lvl = max(lvl, write_level[s] + 1)
        for d in op.dsts:
            if d in write_level:               # WAW: one writer per level
                lvl = max(lvl, write_level[d] + 1)
            if d in read_level:                # WAR: share level with
                lvl = max(lvl, read_level[d])  # earlier readers only
        while len(levels) <= lvl:
            levels.append([])
        levels[lvl].append(op)
        for s in op.srcs:
            read_level[s] = max(read_level.get(s, 0), lvl)
        for d in op.dsts:
            write_level[d] = lvl
    return levels


def build_schedule(program: Program) -> Schedule:
    """Level the program and fuse each level into dispatch groups.

    Per level: all MAJ ops form one group (padded to the widest arity),
    all MRC ops one group (padded to the widest fan-out), NOT and COPY
    one gather/scatter group each.  Group order inside a level is fixed
    (MAJ, MRC, NOT, COPY) but irrelevant to semantics: WAW leveling
    guarantees disjoint destination rows within a level, and every group
    reads the level-entry state.
    """
    out: list[tuple[FusedGroup, ...]] = []
    for ops in dependency_levels(program):
        groups: list[FusedGroup] = []
        for kind in VALUE_KINDS:
            members = tuple(op for op in ops if op.kind == kind)
            if not members:
                continue
            if kind == "MAJ":
                param = max(len(op.srcs) for op in members)
            elif kind == "MRC":
                param = max(len(op.dsts) for op in members)
            else:
                param = 0
            groups.append(FusedGroup(kind, param, members))
        out.append(tuple(groups))
    return Schedule(tuple(out))
