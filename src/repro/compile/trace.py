"""Lower BitSerial gate streams to addressed, fusable Programs.

The §8.1 bit-serial compiler (:class:`repro.pud.arith.BitSerial`) records
cost-only ops while computing on whatever planes flow through it.  The
:class:`Tracer` here is a :class:`~repro.pud.arith.GateExecutor` that
additionally assigns every gate a *row address*: operands resolve to rows
of a growing subarray image, each gate output gets a fresh (SSA) row, and
the emitted :class:`~repro.pud.isa.Program` carries full ``srcs``/``dsts``
— executable by any backend and fusable by
:mod:`repro.compile.schedule`.

Rows are keyed by plane *value*.  BitSerial freely reshapes, stacks and
re-indexes planes (``jnp.stack(sums)``, ``acc[i:]``), destroying object
identity but never values; because traced rows are written exactly once,
any row holding a value is a valid source for that value forever, so
value-keying is exact.  Planes first seen as gate operands (packed inputs,
``const`` planes) become *input rows* of the initial state image.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes as bp
from repro.pud.isa import Program


class Tracer:
    """GateExecutor assigning SSA row addresses while computing oracle
    gate values (the recorded Program is then *re*-executed by a real
    backend, so traced values never leak into backend results)."""

    def __init__(self):
        self.program = Program()
        #: initial value per row; None for gate outputs (written by ops).
        self._init: list[Optional[np.ndarray]] = []
        self._table: dict[bytes, int] = {}

    # ------------------------------------------------------------- rows
    @staticmethod
    def _key(plane) -> bytes:
        return np.asarray(plane, np.uint32).tobytes()

    @property
    def n_rows(self) -> int:
        return len(self._init)

    def row_of(self, plane) -> int:
        """Row holding ``plane``'s value (allocating an input row if the
        value was never produced by a traced gate)."""
        key = self._key(plane)
        row = self._table.get(key)
        if row is None:
            row = len(self._init)
            self._init.append(np.asarray(plane, np.uint32).copy())
            self._table[key] = row
        return row

    def _alloc_output(self, value) -> int:
        row = len(self._init)
        self._init.append(None)
        # Map the value to its newest row: both old and new rows hold it
        # once written (rows are SSA), so either is a valid source.
        self._table[self._key(value)] = row
        return row

    def initial_state(self) -> np.ndarray:
        """(rows, words) uint32 image: input rows hold their traced
        values, gate-output rows start zeroed (their ops overwrite)."""
        width = 0
        for v in self._init:
            if v is not None:
                width = int(np.asarray(v).shape[-1])
                break
        state = np.zeros((len(self._init), width), np.uint32)
        for r, v in enumerate(self._init):
            if v is not None:
                state[r] = v
        return state

    # --------------------------------------------- GateExecutor protocol
    def gate_maj(self, planes: Sequence[jax.Array], x: int,
                 n_act: int) -> jax.Array:
        srcs = tuple(self.row_of(p) for p in planes)
        stack = jnp.stack([jnp.asarray(p, jnp.uint32) for p in planes])
        out = bp.maj3_words(*stack) if len(planes) == 3 else \
            bp.majority(stack, axis=0)
        dst = self._alloc_output(out)
        self.program.emit("MAJ", x=x, n_act=n_act, srcs=srcs, dsts=(dst,))
        return out

    def gate_not(self, p: jax.Array) -> jax.Array:
        src = self.row_of(p)
        out = ~jnp.asarray(p, jnp.uint32)
        dst = self._alloc_output(out)
        self.program.emit("NOT", srcs=(src,), dsts=(dst,))
        return out


@dataclasses.dataclass
class CompiledProgram:
    """A traced computation, ready for :meth:`Backend.run_fused`.

    ``state`` is the initial (rows, words) image; ``out_rows`` index the
    rows holding the result planes after execution; ``n_lanes`` is the
    element count for unpacking elementwise results.
    """

    program: Program
    state: np.ndarray
    out_rows: tuple[int, ...]
    n_lanes: int

    def outputs(self, final_state: jax.Array) -> jax.Array:
        """Unpack the result planes of an executed image into uint32
        elements (inverse of :func:`bitplanes.pack_uint_elements`)."""
        planes = jnp.asarray(final_state, jnp.uint32)[
            np.array(self.out_rows, np.int32)]
        return bp.unpack_uint_elements(planes, self.n_lanes)


def trace_planes(build, tier: int, n_act: int) -> CompiledProgram:
    """Trace ``build(bs, tracer) -> output planes`` into a CompiledProgram.

    ``build`` receives a :class:`~repro.pud.arith.BitSerial` wired to a
    fresh Tracer and returns the stacked output planes ``(nbits, words)``;
    constructions are shared verbatim with the per-gate path, so the
    traced Program's histogram equals the cost-only recording.
    """
    from repro.pud.arith import BitSerial  # deferred: arith lazily imports us

    tracer = Tracer()
    bs = BitSerial(tier=tier, n_act=n_act, executor=tracer)
    out = build(bs)
    out_rows = tuple(tracer.row_of(p) for p in out)
    return CompiledProgram(tracer.program, tracer.initial_state(),
                           out_rows, n_lanes=0)


def compile_elementwise(op: str, a, b, tier: int = 3, n_act: int = 4
                        ) -> CompiledProgram:
    """Compile a §8.1 elementwise microbenchmark to an addressed Program.

    Mirrors :func:`repro.pud.arith.run_elementwise` (same constructions,
    same recorded op stream) but captures row addresses, so the returned
    program executes through :meth:`Backend.run_fused` in level-batched
    kernel dispatches instead of one launch per gate.
    """
    a = jnp.asarray(a, jnp.uint32).reshape(-1)
    b = jnp.asarray(b, jnp.uint32).reshape(-1)
    k = int(a.shape[0])
    A = bp.pack_uint_elements(a)
    B = bp.pack_uint_elements(b)

    def build(bs):
        if op == "and":
            return [bs.and_(A[i], B[i]) for i in range(A.shape[0])]
        if op == "or":
            return [bs.or_(A[i], B[i]) for i in range(A.shape[0])]
        if op == "xor":
            return [bs.xor(A[i], B[i]) for i in range(A.shape[0])]
        if op == "add":
            return list(bs.add(A, B)[0])
        if op == "sub":
            return list(bs.sub(A, B)[0])
        if op == "mul":
            return list(bs.mul(A, B))
        if op == "div":
            return list(bs.div(A, B)[0])
        raise ValueError(f"unknown op {op!r}")

    cp = trace_planes(build, tier=tier, n_act=n_act)
    cp.n_lanes = k
    return cp
