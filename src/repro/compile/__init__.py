"""``repro.compile``: program fusion for PUD instruction streams.

Three layers:

* :mod:`repro.compile.schedule` — partition an addressed
  :class:`~repro.pud.isa.Program` into hazard-respecting dependency
  levels and fuse each level's MAJX / Multi-RowCopy ops into single
  batched kernel dispatches (the plan behind
  :meth:`repro.backends.base.Backend.run_fused`);
* :mod:`repro.compile.megakernel` — lower a whole Schedule to static
  level tables one Pallas dispatch scans end-to-end
  (``run_fused(mode="megakernel")``), with a VMEM column planner for
  images wider than the on-chip budget;
* :mod:`repro.compile.trace` — lower §8.1 ``BitSerial`` gate streams to
  addressed, fusable Programs (SSA row allocation over a subarray
  image).

Consumers: the ``pallas`` backend executes schedules, ``pud.arith``
routes batch-native executors through :func:`compile_elementwise`, the
sweep runner fuses characterization chunks, the serve engine's integrity
vote is one fused program, and ``pud.offload`` prices dispatch-count
reductions.  :class:`repro.session.DramSession` is the layer above:
it memoizes :func:`build_schedule` by program content, so repeated
programs skip straight to fused execution.  See docs/ARCHITECTURE.md
("Program compilation & fusion" and "Session layer").
"""

from repro.compile.megakernel import (MegaLowering, VmemPlan,
                                      lower_schedule, plan_vmem)
from repro.compile.schedule import (FusedGroup, Schedule, build_schedule,
                                    dependency_levels)
from repro.compile.trace import (CompiledProgram, Tracer,
                                 compile_elementwise, trace_planes)

__all__ = [
    "CompiledProgram", "FusedGroup", "MegaLowering", "Schedule", "Tracer",
    "VmemPlan", "build_schedule", "compile_elementwise",
    "dependency_levels", "lower_schedule", "plan_vmem", "trace_planes",
]
