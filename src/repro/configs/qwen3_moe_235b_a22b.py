"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936.
Fine-grained experts: the 128-expert dim shards over the TP axis (EP, 8
experts per chip at model=16).  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    moe_shard_experts=True,
    mlp_act="swiglu",
    rope_theta=1e6,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=32,
    vocab_size=256,
    head_dim=8,
    n_experts=8,
    top_k=2,
    moe_shard_experts=True,
    mlp_act="swiglu",
    subquadratic=False,
)
