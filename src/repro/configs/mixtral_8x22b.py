"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768, sliding window 4096.
Sharding note: 8 experts < 16 TP chips, so expert FFN hidden is
tensor-parallel *within* each expert (moe_shard_experts=False); SWA gives a
sub-quadratic path, so long_500k runs with a 4096-token live window.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    moe_shard_experts=False,
    sliding_window=4096,
    mlp_act="swiglu",
    rope_theta=1e6,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    moe_shard_experts=False,
    sliding_window=16,
    mlp_act="swiglu",
    subquadratic=True,
)
