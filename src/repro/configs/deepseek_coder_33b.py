"""deepseek-coder-33b [dense] — llama arch [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    mlp_act="swiglu",
    rope_theta=100000.0,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    mlp_act="swiglu",
    subquadratic=False,
)
