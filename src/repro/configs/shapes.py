"""The four assigned input shapes (LM-family; seq_len x global_batch)."""

from __future__ import annotations

from repro.configs.base import ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256,
                            kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32,
                               kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128,
                              kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1,
                             kind="decode"),
}


def shape_applicable(arch_cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k requires a sub-quadratic attention path (DESIGN.md §4)."""
    if shape.name == "long_500k" and not arch_cfg.subquadratic:
        return False, ("skip: pure full-attention arch has no sub-quadratic "
                       "path for 500k context (noted in DESIGN.md)")
    return True, ""
