"""Model/config dataclasses for all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    # --- attention ---
    sliding_window: int = 0             # 0 = full attention
    rotary_pct: float = 1.0             # fraction of head_dim rotated
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0
    # --- mlp ---
    mlp_act: str = "swiglu"             # swiglu | geglu | gelu
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    moe_shard_experts: bool = True      # EP over tp axis (False: TP-in-expert)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 64
    attn_every: int = 0                 # zamba: shared attn period (0 = off)
    slstm_layers: Tuple[int, ...] = ()  # xlstm: which layers are sLSTM
    # --- audio ---
    n_codebooks: int = 0
    # --- vlm ---
    n_patches: int = 0                  # stub frontend patches (prefill)
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False           # gemma: embeddings * sqrt(d_model)
    dtype: str = "bfloat16"
    #: rematerialization policy for the scanned blocks.  "full" saves only
    #: the (sequence-sharded) residual carry — the memory-fit default at
    #: 4k x 256 batch; "dots" additionally saves projection outputs (fewer
    #: recompute FLOPs, ~25 GB/chip more live activations at chatglm scale).
    remat: str = "full"                 # none | dots | full
    #: sequence-parallel residual carries ("sp" on the seq dim).  Saves
    #: 16x carry memory but costs backward re-gathers; §Perf measures both.
    seq_shard: bool = True
    # long-context capability: sub-quadratic attention path exists
    # (SWA / SSM / hybrid); gates the long_500k shape (DESIGN.md §4).
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.n_codebooks:
            emb = self.n_codebooks * self.vocab_size * d * 2
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.is_moe:
            ff = self.n_experts * 3 * d * self.d_ff
        elif self.mlp_act in ("swiglu", "geglu"):
            ff = 3 * d * self.d_ff
        else:
            ff = 2 * d * self.d_ff
        per_layer = attn + ff + 2 * d
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer = 2 * d * di + di * d + di * self.ssm_state * 2 + 2 * d
        if self.family == "ssm":
            di = 2 * d
            per_layer = d * 3 * di + di * d + 4 * di + 2 * d
        return emb + self.n_layers * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        ff_all = self.n_layers * self.n_experts * 3 * d * self.d_ff
        ff_active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return total - ff_all + ff_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape x step-kind) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1
    z_loss: float = 1e-4
    seed: int = 0
    # gradient compression (optional, benchmarked in EXPERIMENTS.md)
    compression: str = "none"  # none | int8 | topk
    topk_frac: float = 0.01
