"""glm4-9b [dense] — RoPE (partial), GQA kv=2 [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rotary_pct=0.5,
    mlp_act="swiglu",
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="glm4-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    rotary_pct=0.5,
    mlp_act="swiglu",
    subquadratic=False,
)
