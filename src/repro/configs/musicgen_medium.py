"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 (per codebook, 4
codebooks, delay pattern).  The EnCodec frontend is a STUB per the
assignment: input_specs() provides token ids per codebook (training) or
precomputed frame embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    n_codebooks=4,
    mlp_act="gelu",
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    head_dim=16,
    n_codebooks=4,
    mlp_act="gelu",
    subquadratic=False,
)
