"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (kv=4) d_ff=0 (projections live inside the xLSTM
blocks) vocab=50304.  sLSTM at layers {3, 7, 11} (sparse placement as in
the paper's LM configs); the rest are mLSTM (matrix-memory) blocks.
Pure recurrent state -> long_500k runs.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_layers=(3, 7, 11),
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    slstm_layers=(1, 3),
    subquadratic=True,
)
