"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (kv=16, i.e. MHA on 7b; MQA is the 2b variant)
d_ff=24576 vocab=256000.  Embeddings scaled by sqrt(d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    mlp_act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="gemma-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=32,
    mlp_act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=False,
)
