"""Architecture registry: ``--arch <id>`` -> (full config, smoke twin)."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, shape_applicable  # noqa: F401

_MODULES = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "gemma-7b": "repro.configs.gemma_7b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "glm4-9b": "repro.configs.glm4_9b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4p2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
