"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
One *shared* full-attention block (params reused) interleaved every 6
Mamba2 layers — the Zamba trick.  Sub-quadratic: long_500k runs.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=64,
    attn_every=6,
    mlp_act="gelu",
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=8,
    attn_every=2,
    mlp_act="gelu",
    subquadratic=True,
)
