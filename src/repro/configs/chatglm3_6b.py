"""chatglm3-6b [dense] — partial RoPE ("2d"), GQA kv=2 [arXiv:2406.12793; hf].

28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024.  GLM applies rotary to
half the head dims (rotary_pct=0.5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rotary_pct=0.5,
    mlp_act="swiglu",
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="chatglm3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    rotary_pct=0.5,
    mlp_act="swiglu",
    subquadratic=False,
)
