"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.  The CLIP vision
frontend is a STUB per the assignment: input_specs() provides precomputed
patch embeddings (n_patches x d_model) prepended at prefill.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    n_patches=576,
    mlp_act="swiglu",
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    n_patches=16,
    mlp_act="swiglu",
    subquadratic=False,
)
