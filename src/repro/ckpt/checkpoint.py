"""Sharded checkpointing with manifests, async writes, and atomic commits.

Layout of a checkpoint directory:

    step_000123/
      manifest.json            # tree structure, shapes, dtypes, crc32s
      shard_p0.npz             # this process's leaves (single-host: all)
      COMMIT                   # written last: restore ignores dirs without it

Restart safety: writes go to ``step_X.tmp`` and are atomically renamed
after COMMIT; `latest_step` scans only committed directories.  The TMR
variant in :mod:`repro.ckpt.tmr_store` layers X-replica majority voting on
top (the paper's §8.1 error-correction case study applied to checkpoints).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]
    return named, treedef


def save(tree, directory: str, step: int, process: int = 0,
         blocking: bool = True) -> str:
    """Write a checkpoint; returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    named, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        key = f"leaf_{i}"
        dtype_name = str(arr.dtype)
        encoded = False
        if arr.dtype not in (np.float64, np.float32, np.float16, np.int64,
                             np.int32, np.int16, np.int8, np.uint64,
                             np.uint32, np.uint16, np.uint8, np.bool_):
            # exotic dtypes (bfloat16 etc.): store the raw words — numpy's
            # npz round-trips them as void otherwise
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
            encoded = True
        arrays[key] = arr
        manifest["leaves"].append({
            "name": name, "key": key, "shape": list(arr.shape),
            "dtype": dtype_name, "encoded": encoded,
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })

    def _write():
        np.savez(os.path.join(tmp, f"shard_p{process}.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        t.join(timeout=0)  # fire and forget; tests use blocking=True
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "COMMIT")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like, directory: str, step: Optional[int] = None,
            process: int = 0, verify: bool = True):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"shard_p{process}.npz"))
    by_name = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != leaf["crc32"]:
                raise IOError(
                    f"checkpoint corruption in {leaf['name']}: crc mismatch "
                    f"(have {crc}, want {leaf['crc32']}) — use the TMR store "
                    f"to self-heal (repro.ckpt.tmr_store)")
        if leaf.get("encoded"):
            import ml_dtypes

            dt = {"bfloat16": ml_dtypes.bfloat16}.get(leaf["dtype"])
            if dt is not None:
                arr = arr.view(dt)
        by_name[leaf["name"]] = arr

    named, treedef = _flatten(tree_like)
    leaves = []
    for name, proto in named:
        arr = by_name[name]
        leaves.append(jnp.asarray(arr).astype(np.asarray(proto).dtype)
                      if hasattr(proto, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
