"""TMR/XMR-protected checkpoint store (the paper's §8.1 case study applied).

The paper shows MAJX implements X-way modular redundancy in memory: MAJ3
corrects one faulty replica, MAJ5/7/9 up to 2/3/4.  At 1000+-node scale,
silent data corruption in checkpoint storage is a real failure mode; this
store writes X independent replicas (on real deployments: different hosts /
storage domains) and majority-votes them bitwise on restore through the
MAJX Pallas-kernel path (`repro.kernels.majx.ops.vote`), healing any
minority corruption without recomputation.

The restore path also *detects* which replicas disagreed (CRC vs manifest)
and can trigger re-replication of the healed state via the Multi-RowCopy
fan-out primitive (`repro.kernels.rowcopy`) — the same 1->N copy pattern
the paper measures at 99.98 % success for 31 destinations.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from repro.ckpt import checkpoint as ckpt
from repro.pud import tmr


def save(tree, directory: str, step: int, replicas: int = 3) -> list[str]:
    if replicas % 2 == 0:
        raise ValueError("replica count must be odd for majority voting")
    paths = []
    for r in range(replicas):
        rdir = os.path.join(directory, f"replica_{r}")
        paths.append(ckpt.save(tree, rdir, step))
    return paths


def restore(tree_like, directory: str, step: Optional[int] = None,
            use_kernel: bool = False):
    """Vote-restore; returns (tree, step, n_healed_replicas)."""
    rdirs = sorted(d for d in os.listdir(directory)
                   if d.startswith("replica_"))
    if not rdirs:
        raise FileNotFoundError(f"no replicas under {directory}")
    trees, healthy = [], []
    step_found = None
    for d in rdirs:
        try:
            t, s = ckpt.restore(tree_like, os.path.join(directory, d),
                                step, verify=True)
            trees.append(t)
            healthy.append(True)
            step_found = s
        except Exception:
            # CRC failure or unreadable replica: still try raw bytes so the
            # voter can out-vote the corruption (verify=False).
            try:
                t, s = ckpt.restore(tree_like, os.path.join(directory, d),
                                    step, verify=False)
                trees.append(t)
                healthy.append(False)
                step_found = s
            except Exception:
                healthy.append(False)
    if not trees:
        raise IOError("all replicas unreadable")
    if len(trees) == 1:
        return trees[0], step_found, sum(1 for h in healthy if not h)
    if len(trees) % 2 == 0:
        trees = trees[:-1]
    if use_kernel:
        flat = [jax.tree.leaves(t) for t in trees]
        treedef = jax.tree.structure(trees[0])
        from repro.kernels.majx.ops import vote as kvote
        voted = [kvote([f[i] for f in flat]) for i in range(len(flat[0]))]
        out = jax.tree.unflatten(treedef, voted)
    else:
        out = tmr.vote_pytree(trees)
    return out, step_found, sum(1 for h in healthy if not h)


def scrub(tree_like, directory: str, step: Optional[int] = None) -> int:
    """Background scrubber: vote, then rewrite any corrupted replica from
    the healed state (fan-out re-replication).  Returns #healed."""
    healed_tree, s, bad = restore(tree_like, directory, step)
    if bad:
        rdirs = sorted(d for d in os.listdir(directory)
                       if d.startswith("replica_"))
        for d in rdirs:
            try:
                ckpt.restore(tree_like, os.path.join(directory, d), s,
                             verify=True)
            except Exception:
                ckpt.save(healed_tree, os.path.join(directory, d), s)
    return bad
