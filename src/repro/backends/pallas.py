"""``pallas``: the bulk TPU-kernel backend.

Dispatches the Pallas kernels of :mod:`repro.kernels` (bit-sliced CSA
MAJX, fan-out Multi-RowCopy, fused XOR+popcount mismatch, fused
bit-serial adder) through the shared VPU tiling helper
(:mod:`repro.kernels.tiling`).  ``ctx.interpret=True`` is the validated
CPU path; on real TPUs construct the context with ``interpret=False``.
Batch dispatch is vmapped over the kernel wrappers — one fused launch
per batch, not a python loop.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.backends.base import Backend, Capabilities
from repro.core import calibration as cal
from repro.kernels.bitserial.ops import bitserial_add
from repro.kernels.majx.ops import majx as majx_kernel
from repro.kernels.mismatch.ops import mismatch_count
from repro.kernels.rowcopy.ops import fanout


class PallasBackend(Backend):
    name = "pallas"

    def capabilities(self) -> Capabilities:
        return Capabilities(
            name=self.name,
            description="bulk Pallas TPU kernels (CSA bit-sliced MAJX, "
                        "fan-out MRC, fused mismatch, bit-serial add)",
            stochastic=False,
            device_model=False,
            accelerated=True,
            max_majx=1_000_000,
            n_act_levels=cal.N_ACT_LEVELS,
            native_batch=True,
        )

    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        return majx_kernel(planes, interpret=self.ctx.interpret,
                           block_r=self.ctx.block_r,
                           block_c=self.ctx.block_c)

    def majx_batch(self, planes: jax.Array) -> jax.Array:
        """(B, X, R, C) -> (B, R, C) in one vmapped kernel dispatch."""
        fn = functools.partial(majx_kernel, interpret=self.ctx.interpret,
                               block_r=self.ctx.block_r,
                               block_c=self.ctx.block_c)
        return jax.vmap(fn)(jnp.asarray(planes, jnp.uint32))

    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        return fanout(src, n_dst, interpret=self.ctx.interpret,
                      block_r=self.ctx.block_r, block_c=self.ctx.block_c)

    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return mismatch_count(a, b, interpret=self.ctx.interpret)

    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return bitserial_add(a, b, interpret=self.ctx.interpret)
