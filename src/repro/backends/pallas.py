"""``pallas``: the bulk TPU-kernel backend.

Dispatches the Pallas kernels of :mod:`repro.kernels` (bit-sliced CSA
MAJX, fan-out Multi-RowCopy, fused XOR+popcount mismatch, fused
bit-serial adder) through the shared VPU tiling helper
(:mod:`repro.kernels.tiling`).  ``ctx.interpret=True`` is the validated
CPU path; on real TPUs construct the context with ``interpret=False``.
Batch dispatch is vmapped over the kernel wrappers — one fused launch
per batch, not a python loop.

Program execution: :meth:`run_fused` overrides the per-op interpreter
with the :mod:`repro.compile` schedule — every dependency level of the
program becomes at most one MAJX dispatch (mixed arities padded with
constant 0/1 plane pairs, an exact identity) plus at most one
Multi-RowCopy dispatch, while NOT/COPY levels are pure gather/scatter.
``run_fused(mode="megakernel")`` goes further: the whole schedule
lowers to static level tables (:mod:`repro.compile.megakernel`) that
ONE ``pallas_call`` scans end-to-end, VMEM-resident, column-blocked
against ``Capabilities.vmem_budget_bytes`` when the image is too wide.
``self.dispatch_count`` tracks real kernel launches, which is the
structural metric ``benchmarks/bench.py`` and the CI perf gate assert
on; each launch also accrues :data:`repro.core.costmodel.COST`-priced
energy (launch round-trip at board power + HBM traffic) into
``self.energy_nj_total``, so fusion's dispatch savings show up in
joules too.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends.base import Backend, Capabilities
from repro.core import calibration as cal
from repro.core.costmodel import COST
from repro.kernels.bitserial.ops import bitserial_add
from repro.kernels.majx.ops import majx as majx_kernel
from repro.kernels.mismatch.ops import mismatch_count
from repro.kernels.rowcopy.ops import fanout
from repro.pud.isa import Program


class PallasBackend(Backend):
    name = "pallas"

    def capabilities(self) -> Capabilities:
        return Capabilities(
            name=self.name,
            description="bulk Pallas TPU kernels (CSA bit-sliced MAJX, "
                        "fan-out MRC, fused mismatch, bit-serial add)",
            stochastic=False,
            device_model=False,
            accelerated=True,
            max_majx=1_000_000,
            n_act_levels=cal.N_ACT_LEVELS,
            native_batch=True,
            megakernel=True,
            vmem_budget_bytes=self.ctx.vmem_budget_bytes,
        )

    def _launch(self, n_bytes: float) -> None:
        """Account one kernel launch: bump the dispatch counter and
        accrue its CostModel energy — the launch round-trip at board
        power plus the HBM access energy of the kernel's ``n_bytes`` of
        operand + result traffic."""
        self.dispatch_count += 1
        self.energy_nj_total += (COST.dispatch_energy_nj(1)
                                 + COST.hbm_energy_nj(n_bytes))

    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        out_words = planes.size // planes.shape[0]
        self._launch((planes.size + out_words) * 4)
        return majx_kernel(planes, interpret=self.ctx.interpret,
                           block_r=self.ctx.block_r,
                           block_c=self.ctx.block_c)

    def majx_batch(self, planes: jax.Array) -> jax.Array:
        """(B, X, R, C) -> (B, R, C) in one vmapped kernel dispatch."""
        planes = jnp.asarray(planes, jnp.uint32)
        self._launch((planes.size + planes.size // planes.shape[1]) * 4)
        fn = functools.partial(majx_kernel, interpret=self.ctx.interpret,
                               block_r=self.ctx.block_r,
                               block_c=self.ctx.block_c)
        return jax.vmap(fn)(planes)

    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        self._launch(src.size * (1 + n_dst) * 4)
        return fanout(src, n_dst, interpret=self.ctx.interpret,
                      block_r=self.ctx.block_r, block_c=self.ctx.block_c)

    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        self._launch((jnp.asarray(a).size + jnp.asarray(b).size) * 4)
        return mismatch_count(a, b, interpret=self.ctx.interpret)

    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        self._launch(3 * jnp.asarray(a).size * 4)
        return bitserial_add(a, b, interpret=self.ctx.interpret)

    # ------------------------------------------------- fused program path
    def run_fused(self, program: Program, state: jax.Array, *,
                  sched=None, mode: str = "fused",
                  lowering=None) -> jax.Array:
        """Level-batched program execution (see module docstring).

        Reads sample the level-entry state and writes commit at level
        exit, matching the hazard model the scheduler levels against;
        WAW leveling guarantees the per-level scatters hit disjoint
        rows.  Prebuilt ``sched`` / ``lowering`` artifacts (the session
        compile cache) skip the scheduling and lowering passes entirely.

        ``mode="megakernel"`` routes to :meth:`run_megakernel` — the
        whole schedule in one dispatch.
        """
        from repro.compile.schedule import build_schedule

        if mode == "megakernel":
            return self.run_megakernel(program, state, sched=sched,
                                       lowering=lowering)
        if mode != "fused":
            raise ValueError(f"unknown run_fused mode {mode!r}")
        if sched is None:
            sched = build_schedule(program)
        state = jnp.asarray(state, jnp.uint32)
        for level in sched.levels:
            entry = state
            for group in level:
                state = self._exec_group(group, entry, state)
        return state

    def run_megakernel(self, program: Program, state: jax.Array, *,
                       sched=None, lowering=None) -> jax.Array:
        """The whole schedule in ONE Pallas dispatch.

        Lowers the program's Schedule to static level tables
        (:mod:`repro.compile.megakernel`), plans VMEM column blocking
        against ``ctx.vmem_budget_bytes``, and scans every level inside
        a single ``pallas_call``.  Value-neutral programs (no write
        slots) are the identity at zero dispatches — there is nothing
        to launch, matching what the empty fused walk does.
        """
        from repro.compile.megakernel import lower_schedule, plan_vmem
        from repro.compile.schedule import build_schedule
        from repro.kernels.megakernel.ops import run_lowering

        if lowering is None:
            if sched is None:
                sched = build_schedule(program)
            lowering = lower_schedule(sched)
        state = jnp.asarray(state, jnp.uint32)
        if lowering.n_levels == 0 or lowering.w_max == 0:
            return state
        rows, words = state.shape
        plan = plan_vmem(lowering, rows, words, self.ctx.vmem_budget_bytes,
                         block_r=self.ctx.block_r)
        self._launch(2 * rows * words * 4)  # image in + image out
        return run_lowering(lowering, state, block_c=plan.block_c,
                            interpret=self.ctx.interpret)

    def _exec_group(self, group, entry: jax.Array,
                    state: jax.Array) -> jax.Array:
        if group.kind == "MAJ":
            return self._fused_maj(group, entry, state)
        if group.kind == "MRC":
            return self._fused_mrc(group, entry, state)
        # NOT / COPY: one gather (+ complement) + scatter, no kernel.
        srcs = np.array([op.srcs[0] for op in group.ops
                         for _ in op.dsts])
        dsts = np.array([d for op in group.ops for d in op.dsts])
        vals = entry[srcs]
        if group.kind == "NOT":
            vals = self._not(vals)
        else:
            vals = self._copy(vals)
        return state.at[dsts].set(vals)

    def _fused_maj(self, group, entry: jax.Array,
                   state: jax.Array) -> jax.Array:
        """All MAJ ops of a level in ONE kernel dispatch.

        Narrower ops are padded to the level's widest arity X with
        constant (all-0, all-1) plane *pairs* — each pair adds one to
        the popcount and one to the majority threshold, so
        ``MAJ_k(x..) == MAJ_X(x.., 0*m, 1*m)`` exactly.  The batch is
        laid out (X, B, W): every op is one row-image of the tile, so a
        single non-vmapped MAJX launch covers the whole level with
        minimal VPU padding.
        """
        x_max = group.param
        width = entry.shape[-1]
        # Augment the image with one all-0 and one all-1 row, then build
        # the whole (B, X) source-index matrix on the host: padding slots
        # point at the constant rows, and a single fancy-index gather
        # assembles the batch (no per-op jnp traffic).
        zero_row, one_row = entry.shape[0], entry.shape[0] + 1
        aug = jnp.concatenate([
            entry,
            jnp.zeros((1, width), jnp.uint32),
            jnp.full((1, width), 0xFFFFFFFF, jnp.uint32)])
        idx = np.empty((len(group.ops), x_max), np.int32)
        for i, op in enumerate(group.ops):
            k = len(op.srcs)
            if (x_max - k) % 2:
                raise ValueError(
                    f"cannot pad MAJ{k} to MAJ{x_max}: parity differs")
            pad = (x_max - k) // 2
            idx[i, :k] = op.srcs
            idx[i, k:k + pad] = zero_row
            idx[i, k + pad:] = one_row
        batch = jnp.swapaxes(aug[idx], 0, 1)          # (X, B, W)
        out = self.majx(batch)                        # (B, W), 1 dispatch
        dsts = np.array([d for op in group.ops for d in op.dsts])
        sel = np.array([i for i, op in enumerate(group.ops)
                        for _ in op.dsts])
        return state.at[dsts].set(out[sel])

    def _fused_mrc(self, group, entry: jax.Array,
                   state: jax.Array) -> jax.Array:
        """All Multi-RowCopy ops of a level in ONE fan-out dispatch.

        Sources stack into a (B, W) block treated as one (R=B, C=W)
        image; a single fan-out to the widest destination count yields
        (n, B, W), and each op scatters the prefix of copies its own
        ``dsts`` ask for (copies are identical, so a prefix is exact).
        """
        n_max = group.param
        srcs = np.array([op.srcs[0] for op in group.ops])
        copies = self.rowcopy(entry[srcs], n_max)     # (n_max, B, W)
        dsts = np.array([d for op in group.ops for d in op.dsts])
        sel_copy = np.array([j for op in group.ops
                             for j in range(len(op.dsts))])
        sel_op = np.array([i for i, op in enumerate(group.ops)
                           for _ in op.dsts])
        return state.at[dsts].set(copies[sel_copy, sel_op])
