"""``oracle``: the pure bitwise reference backend.

Executes every op with its closed-form boolean semantics (the
``kernels/*/ref.py`` oracles + :mod:`repro.core.bitplanes`): no error
model, no device structure, no kernels.  This is the ground truth the
other backends are tested against, and the cheapest executor for
program compilation / costing runs where only the op stream matters.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.backends.base import Backend, Capabilities
from repro.core import calibration as cal
from repro.kernels.bitserial.ref import bitserial_add_ref
from repro.kernels.majx.ref import majx_ref
from repro.kernels.mismatch.ref import mismatch_count_ref
from repro.kernels.rowcopy.ref import fanout_ref


class OracleBackend(Backend):
    name = "oracle"

    def capabilities(self) -> Capabilities:
        return Capabilities(
            name=self.name,
            description="pure bitwise reference (kernels/*/ref.py + "
                        "core.bitplanes); exact, error-free",
            stochastic=False,
            device_model=False,
            accelerated=False,
            max_majx=1_000_000,  # any odd arity
            n_act_levels=cal.N_ACT_LEVELS,
            native_batch=False,
        )

    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        return majx_ref(jnp.asarray(planes, jnp.uint32))

    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        return fanout_ref(jnp.asarray(src, jnp.uint32), n_dst)

    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return mismatch_count_ref(jnp.asarray(a, jnp.uint32).reshape(-1),
                                  jnp.asarray(b, jnp.uint32).reshape(-1))

    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return bitserial_add_ref(a, b)
