"""Unified execution backends for PUD operations.

One :class:`~repro.pud.isa.Program`, three interchangeable executors:

>>> from repro.backends import ExecutionContext, get_backend
>>> be = get_backend("oracle")                  # or "sim" / "pallas"
>>> out = be.majx(planes, x=3, n_act=32)
>>> copies = be.rowcopy(src, 31)
>>> bad_bits = be.mismatch(out, want)

Every backend takes the same :class:`ExecutionContext` (calibration
point, timings, temperature/voltage, interpret/tiling flags), so a
backend is a one-string config choice everywhere.  New executors
(multi-device sharded sim, compiled-TPU) register with
:func:`register_backend` and inherit every consumer for free.

This registry is the **compat layer**: consumers execute through
:class:`repro.session.DramSession` (typed row allocation, build-time
validation, compile-cached fused execution), which resolves its backend
here via :func:`resolve_backend`.  Reach for :func:`get_backend`
directly only when implementing backend-layer machinery or tests.
"""

from __future__ import annotations

from typing import Optional, Type

from repro.backends.base import Backend, Capabilities  # noqa: F401
from repro.backends.context import ExecutionContext, Timings  # noqa: F401

_REGISTRY: dict[str, Type[Backend]] = {}


def register_backend(name: str):
    """Class decorator: register a Backend implementation under a name."""

    def deco(cls: Type[Backend]) -> Type[Backend]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, ctx: Optional[ExecutionContext] = None) -> Backend:
    """Instantiate a registered backend with a shared ExecutionContext."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    return cls(ctx)


def resolve_backend(backend: "str | Backend",
                    ctx: Optional[ExecutionContext] = None) -> Backend:
    """Name -> registry lookup; instance -> passed through unchanged.

    The session layer's resolution hook: ``DramSession("sim", ctx)``
    and ``DramSession(prebuilt_backend)`` both land here.  A ``ctx``
    alongside an already-constructed instance must match the instance's
    own context (a backend is constructed *under* its context; silently
    swapping would change semantics mid-flight).
    """
    if isinstance(backend, Backend):
        if ctx is not None and ctx != backend.ctx:
            raise ValueError(
                f"backend instance {backend.name!r} already carries an "
                f"ExecutionContext; pass ctx only when resolving by name")
        return backend
    return get_backend(backend, ctx)


# Register the three shipped implementations.
from repro.backends.oracle import OracleBackend  # noqa: E402
from repro.backends.pallas import PallasBackend  # noqa: E402
from repro.backends.sim import SimBackend  # noqa: E402

register_backend("oracle")(OracleBackend)
register_backend("sim")(SimBackend)
register_backend("pallas")(PallasBackend)

__all__ = [
    "Backend", "Capabilities", "ExecutionContext", "Timings",
    "available_backends", "get_backend", "register_backend",
    "resolve_backend",
]
