"""Unified execution backends for PUD operations.

One :class:`~repro.pud.isa.Program`, three interchangeable executors:

>>> from repro.backends import ExecutionContext, get_backend
>>> be = get_backend("oracle")                  # or "sim" / "pallas"
>>> out = be.majx(planes, x=3, n_act=32)
>>> copies = be.rowcopy(src, 31)
>>> bad_bits = be.mismatch(out, want)

Every backend takes the same :class:`ExecutionContext` (calibration
point, timings, temperature/voltage, interpret/tiling flags), so a
backend is a one-string config choice everywhere — examples,
benchmarks, the serving engine's PUD hooks, and the offload planner all
resolve their executor here.  New executors (multi-device sharded sim,
compiled-TPU) register with :func:`register_backend` and inherit every
consumer for free.
"""

from __future__ import annotations

from typing import Optional, Type

from repro.backends.base import Backend, Capabilities  # noqa: F401
from repro.backends.context import ExecutionContext, Timings  # noqa: F401

_REGISTRY: dict[str, Type[Backend]] = {}


def register_backend(name: str):
    """Class decorator: register a Backend implementation under a name."""

    def deco(cls: Type[Backend]) -> Type[Backend]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, ctx: Optional[ExecutionContext] = None) -> Backend:
    """Instantiate a registered backend with a shared ExecutionContext."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    return cls(ctx)


# Register the three shipped implementations.
from repro.backends.oracle import OracleBackend  # noqa: E402
from repro.backends.pallas import PallasBackend  # noqa: E402
from repro.backends.sim import SimBackend  # noqa: E402

register_backend("oracle")(OracleBackend)
register_backend("sim")(SimBackend)
register_backend("pallas")(PallasBackend)

__all__ = [
    "Backend", "Capabilities", "ExecutionContext", "Timings",
    "available_backends", "get_backend", "register_backend",
]
