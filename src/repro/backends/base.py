"""The Backend protocol: one Program, interchangeable executors.

A backend executes PUD work at three granularities through one
interface:

* **bulk entry points** — ``majx(planes, x, n_act)``,
  ``rowcopy(src, n_dst)``, ``mismatch(a, b)``, ``add_planes(a, b)`` on
  packed uint32 bit-planes (the layout of :mod:`repro.core.bitplanes`);
* **programs** — ``run(program, state)`` interprets a
  :class:`repro.pud.isa.Program` whose ops carry row addresses against a
  ``(rows, words)`` subarray image, and ``run_fused(program, state)``
  executes the same program through the :mod:`repro.compile` fusion
  scheduler (bit-identical results; batch-native backends collapse each
  dependency level into one kernel dispatch);
* **compiled arithmetic** — ``elementwise(op, a, b)`` drives the §8.1
  bit-serial compiler with this backend as the gate executor, so the
  recorded Program and the computed values come from the same run.

All knobs live in one shared :class:`~repro.backends.context.ExecutionContext`.
Implementations: ``oracle`` (pure bitwise reference), ``sim``
(behavioural subarray with calibrated error injection), ``pallas``
(bulk TPU kernels).  Consumers name one backend and execute through a
:class:`repro.session.DramSession` — a backend is a one-string config
choice, which is what makes regime comparisons (PULSAR/FCDRAM-style
reliability-vs-throughput tradeoffs) apples-to-apples; the session adds
typed row allocation, build-time validation, and schedule caching on
top of this protocol.
"""

from __future__ import annotations

import abc
import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.backends.context import ExecutionContext
from repro.pud.isa import Program


class DispatchScope:
    """A window over a backend's kernel-launch and energy counters.

    Produced by :meth:`Backend.count_dispatches`: ``.count`` is the
    launches issued since the scope opened and ``.energy_nj`` the
    modelled energy accrued (CostModel-priced: per-dispatch launch
    energy + HBM traffic on accelerated backends, per-DRAM-command
    Fig. 5 energy on the device-model backend), both frozen when the
    ``with`` block exits — so two workloads (bench rows, tests) each
    read their own window of the monotonic counters instead of sharing
    one mutable total that leaks across resets.
    """

    def __init__(self, backend: "Backend"):
        self._backend = backend
        self._start = backend.dispatch_count
        self._stop: Optional[int] = None
        self._energy_start = backend.energy_nj_total
        self._energy_stop: Optional[float] = None

    @property
    def count(self) -> int:
        end = (self._backend.dispatch_count if self._stop is None
               else self._stop)
        return end - self._start

    @property
    def energy_nj(self) -> float:
        end = (self._backend.energy_nj_total if self._energy_stop is None
               else self._energy_stop)
        return end - self._energy_start


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend models / how it executes.

    Consumers branch on this instead of on backend names: the sweep
    planner batches chunks for ``native_batch`` executors, the offload
    planner checks ``accelerated``, and characterization filters grids
    by ``max_majx`` / ``n_act_levels``.

    Attributes:
        name: registry name the backend was instantiated under.
        description: one-line human summary of the execution model.
        stochastic: True when the paper-calibrated per-cell error
            surfaces (Obs 1-18) are injected; exact digital results
            otherwise.  ``ExecutionContext(ideal=True)`` forces False.
        device_model: True when ops execute through the behavioural
            ``Subarray``/``PUDDevice`` APA/PRE/ACT command model rather
            than closed-form boolean semantics.
        accelerated: True when bulk ops dispatch Pallas TPU kernels
            (interpret mode on CPU, compiled on real TPUs).
        max_majx: widest MAJ arity this backend can execute.  For the
            calibrated ``sim`` backend this is the manufacturer limit
            (fn 11: 9 for Mfr H, 7 for Mfr M); digital backends are
            unbounded in arity (reported as a large sentinel).
        n_act_levels: reachable simultaneous-activation counts
            (§4 Limitation 2: powers of two up to 32).
        native_batch: True when ``majx_batch`` is a single vmapped
            kernel dispatch rather than a python loop — the property
            the sweep planner exploits to fuse a chunk of grid points
            into one launch.
        megakernel: True when ``run_fused(mode="megakernel")`` executes
            a whole Schedule in ONE kernel dispatch via lowered level
            tables (:mod:`repro.compile.megakernel`).  Backends without
            it still accept the mode and fall back to their exact
            per-op/level path — mode is a request, this flag is the
            contract.
        vmem_budget_bytes: on-chip working-set budget the megakernel
            VMEM planner (:func:`repro.compile.megakernel.plan_vmem`)
            blocks the word axis against.  Irrelevant when
            ``megakernel`` is False.
    """

    name: str
    description: str
    stochastic: bool
    device_model: bool
    accelerated: bool
    max_majx: int
    n_act_levels: tuple[int, ...]
    native_batch: bool
    megakernel: bool = False
    vmem_budget_bytes: int = 8 * 2**20


class Backend(abc.ABC):
    """Abstract executor for PUD operations (see module docstring)."""

    name: str = "?"

    def __init__(self, ctx: Optional[ExecutionContext] = None):
        self.ctx = ctx or ExecutionContext()
        #: Kernel launches issued so far (bulk-op or program execution).
        #: Only accelerated backends increment it; it is the structural
        #: metric the fusion layer optimizes and repro.bench records.
        self.dispatch_count = 0
        #: Modelled energy (nJ) accrued so far, priced by
        #: :data:`repro.core.costmodel.COST`: the ``pallas`` backend
        #: accrues launch + HBM-traffic energy per kernel dispatch, the
        #: ``sim`` backend Fig. 5 command energy per DRAM op.  The
        #: ``oracle`` reference accrues nothing (it models no hardware).
        self.energy_nj_total = 0.0

    def reset_dispatches(self) -> None:
        """Zero the process-lifetime counters (launches AND energy).

        Prefer :meth:`count_dispatches` for measurement — resetting a
        shared counter inside someone else's measurement window corrupts
        their count; a scope never does.
        """
        self.dispatch_count = 0
        self.energy_nj_total = 0.0

    @contextlib.contextmanager
    def count_dispatches(self):
        """Scoped kernel-launch and energy counting.

        Yields a :class:`DispatchScope` whose ``.count`` is the
        launches issued — and ``.energy_nj`` the modelled energy accrued
        — inside the ``with`` block (frozen at exit).  Scopes nest and
        sequence independently, so concurrent bench workloads and tests
        cannot leak counts into each other.

        >>> with backend.count_dispatches() as scope:
        ...     backend.run_fused(program, state)
        >>> scope.count                # launches of that run alone
        """
        scope = DispatchScope(self)
        try:
            yield scope
        finally:
            scope._stop = self.dispatch_count
            scope._energy_stop = self.energy_nj_total

    # ------------------------------------------------------------ protocol
    @abc.abstractmethod
    def capabilities(self) -> Capabilities:
        """Self-description for capability-based dispatch.

        May depend on ``self.ctx`` (e.g. ``sim`` reports the active
        manufacturer's MAJ arity limit, and ``stochastic=False`` under
        an ideal context).
        """

    @abc.abstractmethod
    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        """MAJX over X packed operand planes.

        ``planes``: (X, words) or (X, R, C) uint32, X odd.  ``x`` defaults
        to ``planes.shape[0]``; ``n_act`` (>= x, a reachable activation
        level) defaults to ``ctx.n_act`` and selects the replication
        ladder of §5 — it changes the *success rate*, never the logical
        result.  Returns the majority plane, shape ``planes.shape[1:]``.
        """

    @abc.abstractmethod
    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        """Multi-RowCopy: replicate one row image to ``n_dst`` rows.

        ``src``: (words,) or (R, C) uint32.  Returns ``(n_dst, *src.shape)``.
        """

    @abc.abstractmethod
    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Total differing bits between two packed arrays (any shape)."""

    @abc.abstractmethod
    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Bit-serial ripple add over (NBITS, ...) packed planes."""

    # ------------------------------------------------- derived bulk helpers
    def majx_batch(self, planes: jax.Array) -> jax.Array:
        """Batched MAJX: (B, X, R, C) -> (B, R, C).

        Default is a python loop; backends with native batch dispatch
        (``pallas``) override with a vmapped kernel call.
        """
        return jnp.stack([self.majx(p) for p in planes])

    def success_rate(self, got: jax.Array, want: jax.Array,
                     n_bits: Optional[int] = None) -> float:
        """Fraction of matching bits — the paper's §3.1 metric."""
        total = int(n_bits) if n_bits else jnp.asarray(got).size * 32
        return 1.0 - int(self.mismatch(got, want)) / total

    # -------------------------------------------------- program execution
    def run(self, program: Program, state: jax.Array) -> jax.Array:
        """Execute an addressed Program against a (rows, words) image.

        Ops without destination addresses (cost-only streams recorded by
        the bit-serial compiler) are skipped.  Returns the new image.
        """
        state = jnp.asarray(state, jnp.uint32)
        for op in program.ops:
            state = self._exec_op(op, state)
        return state

    def run_fused(self, program: Program, state: jax.Array, *,
                  sched=None, mode: str = "fused",
                  lowering=None) -> jax.Array:
        """Execute an addressed Program through the fusion scheduler.

        Semantically identical to :meth:`run` (verified adversarially in
        tests/test_compile_differential.py and
        tests/test_megakernel_differential.py).  The default falls back
        to per-op interpretation, so device-model and reference backends
        keep their exact command-level semantics; backends with native
        batch dispatch (``pallas``) override this with level-batched
        kernel launches (see :mod:`repro.compile.schedule`).

        ``mode`` selects the execution strategy: ``"fused"`` (level
        batching, the default) or ``"megakernel"`` (one dispatch for the
        whole schedule, see :mod:`repro.compile.megakernel`).  Every
        backend accepts every mode — backends whose
        :meth:`capabilities` don't advertise ``megakernel`` satisfy the
        request with their exact fallback, so callers can set a mode
        unconditionally and compare backends apples-to-apples.

        ``sched`` / ``lowering`` optionally supply prebuilt compile
        artifacts (the session layer's content-hash cache skips
        re-scheduling and re-lowering on repeated programs).  Backends
        that interpret per-op ignore both.
        """
        if mode not in ("fused", "megakernel"):
            raise ValueError(f"unknown run_fused mode {mode!r}")
        return self.run(program, state)

    def _exec_op(self, op, state: jax.Array) -> jax.Array:
        if not op.dsts:
            return state  # cost-only op: nothing addressable to do
        dsts = jnp.asarray(op.dsts)
        if op.kind == "MAJ":
            out = self.majx(state[jnp.asarray(op.srcs)], x=op.x,
                            n_act=op.n_act or None)
            return state.at[dsts].set(out)
        if op.kind == "NOT":
            return state.at[dsts].set(self._not(state[op.srcs[0]]))
        if op.kind == "COPY":
            return state.at[dsts].set(self._copy(state[op.srcs[0]]))
        if op.kind == "MRC":
            rows = self.rowcopy(state[op.srcs[0]], len(op.dsts))
            return state.at[dsts].set(rows)
        if op.kind == "FRAC":
            return self._frac(dsts, state)
        if op.kind in ("WR", "RD"):
            return state  # I/O accounting ops: no in-array effect
        raise ValueError(f"unknown op kind {op.kind}")

    # Per-op hooks the device-model backend overrides with command-level
    # execution (RowClone / complement copy with calibrated errors).
    def _not(self, plane: jax.Array) -> jax.Array:
        return ~jnp.asarray(plane, jnp.uint32)

    def _copy(self, plane: jax.Array) -> jax.Array:
        return jnp.asarray(plane, jnp.uint32)

    def _frac(self, dsts: jax.Array, state: jax.Array) -> jax.Array:
        return state  # neutral rows don't vote; value-wise a no-op

    # ------------------------------------------- §8.1 compiled arithmetic
    def elementwise(self, op: str, a, b, tier: Optional[int] = None,
                    n_act: Optional[int] = None):
        """Run a §8.1 microbenchmark through this backend's gates.

        Returns (uint32 results, recorded Program) — the Program prices
        latency/energy under the shared calibration regardless of which
        backend computed the values.
        """
        from repro.pud.arith import run_elementwise

        return run_elementwise(
            op, a, b, tier=tier or self.ctx.tier,
            n_act=n_act or self.ctx.n_act, executor=self)

    # GateExecutor protocol (repro.pud.arith) -----------------------------
    def gate_maj(self, planes: Sequence[jax.Array], x: int,
                 n_act: int) -> jax.Array:
        return self.majx(jnp.stack([jnp.asarray(p, jnp.uint32)
                                    for p in planes]), x=x, n_act=n_act)

    def gate_not(self, p: jax.Array) -> jax.Array:
        return self._not(p)
