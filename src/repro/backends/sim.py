"""``sim``: the behavioural device-model backend.

Executes every op through :class:`repro.core.subarray.Subarray` command
sequences — the same APA/PRE/ACT streams the paper issues — with the
calibrated :class:`~repro.core.errormodel.ErrorModel` injecting
deterministic per-cell errors (``ctx.ideal=True`` disables injection for
pure-semantics runs).  Bulk (R, C) tiles are spread round-robin over a
pool of subarrays so row-images land on independent row groups, exactly
like the paper's per-subarray characterization.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.backends.base import Backend, Capabilities
from repro.backends.context import ExecutionContext
from repro.core import calibration as cal
from repro.core import majx as mj
from repro.core import rowcopy as rc
from repro.core.costmodel import COST
from repro.core.subarray import DeviceProfile, Subarray
from repro.kernels.mismatch.ref import mismatch_count_ref

_PROFILES = {"H": DeviceProfile.mfr_h, "M": DeviceProfile.mfr_m,
             "S": DeviceProfile.mfr_s}

#: Subarrays per plane width: row-images of a bulk tile rotate over these
#: (independent stable-cell masks, like testing several random subarrays).
_POOL_SIZE = 4


class SimBackend(Backend):
    name = "sim"

    def __init__(self, ctx: Optional[ExecutionContext] = None):
        super().__init__(ctx)
        self._pools: dict[int, list[Subarray]] = {}
        self._rr = 0  # round-robin cursor over the pool
        #: Per-(kind, x, n_act) command energy, memoized — the context
        #: (and so the calibration point) is frozen for this backend's
        #: lifetime, so each command's Fig. 5 energy is a constant.
        self._energy_cache: dict[tuple[str, int, int], float] = {}

    def _accrue(self, kind: str, *, x: int = 0, n_act: int = 0) -> None:
        """Accrue one DRAM command's Fig. 5 energy (retry-aware under
        this context's calibration point; single-issue when ideal)."""
        key = (kind, x, n_act)
        e = self._energy_cache.get(key)
        if e is None:
            errors = None if self.ctx.ideal else self.ctx.error_model
            e = COST.energy_nj(kind, x=x, n_act=n_act, errors=errors,
                               **self.ctx.env())
            self._energy_cache[key] = e
        self.energy_nj_total += e

    def capabilities(self) -> Capabilities:
        anchor = cal.DEVICE_ANCHORS[self.ctx.mfr]
        return Capabilities(
            name=self.name,
            description="behavioural Subarray command model with the "
                        "calibrated per-cell error surfaces",
            stochastic=not self.ctx.ideal,
            device_model=True,
            accelerated=False,
            max_majx=anchor.max_majx if not self.ctx.ideal else 9,
            n_act_levels=cal.N_ACT_LEVELS,
            native_batch=False,
        )

    # ------------------------------------------------------------ plumbing
    def _subarray(self, n_words: int) -> Subarray:
        pool = self._pools.get(n_words)
        if pool is None:
            profile = _PROFILES[self.ctx.mfr]()
            pool = [
                Subarray(profile, cols=n_words * 32, temp_c=self.ctx.temp_c,
                         vpp_v=self.ctx.vpp_v, ideal=self.ctx.ideal,
                         seed=self.ctx.seed * 1009 + i)
                for i in range(_POOL_SIZE)
            ]
            self._pools[n_words] = pool
        sa = pool[self._rr % len(pool)]
        self._rr += 1
        return sa

    @staticmethod
    def _per_row(fn, plane: jax.Array) -> jax.Array:
        """Apply a (words,)->(...) op to a (words,) or (R, C) row set."""
        plane = jnp.asarray(plane, jnp.uint32)
        if plane.ndim == 1:
            return fn(plane)
        return jnp.stack([fn(row) for row in plane])

    # ------------------------------------------------------------- bulk ops
    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        planes = jnp.asarray(planes, jnp.uint32)
        x = x or planes.shape[0]
        n = n_act or max(self.ctx.n_act, cal.min_activation_for(x))
        if n < x:
            n = cal.min_activation_for(x)
        t = self.ctx.timings

        def one(stack: jax.Array) -> jax.Array:  # (X, words)
            self._accrue("MAJ", x=x, n_act=n)
            sa = self._subarray(stack.shape[-1])
            return mj.majx(sa, list(stack), n, t1_ns=t.majx_t1,
                           t2_ns=t.majx_t2, pattern=self.ctx.pattern)

        if planes.ndim == 2:
            return one(planes)
        # (X, R, C): each r is an independent row image.
        return jnp.stack([one(planes[:, r, :])
                          for r in range(planes.shape[1])])

    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        t = self.ctx.timings

        def one(row: jax.Array) -> jax.Array:  # (words,) -> (n_dst, words)
            sa = self._subarray(row.shape[-1])
            out, base = [], 0
            while len(out) < n_dst:
                remaining = n_dst - len(out)
                n_act = max(l for l in cal.N_ACT_LEVELS
                            if l <= remaining + 1)
                self._accrue("MRC", n_act=n_act)
                _, dests = rc.multi_rowcopy(sa, row, n_act, t1_ns=t.mrc_t1,
                                            t2_ns=t.mrc_t2, base_row=base)
                out.extend(sa.read_row(d) for d in dests[:remaining])
                base += n_act
            return jnp.stack(out)

        src = jnp.asarray(src, jnp.uint32)
        if src.ndim == 1:
            return one(src)
        # (R, C) -> (n_dst, R, C)
        per_row = [one(row) for row in src]          # R x (n_dst, C)
        return jnp.stack(per_row, axis=1)

    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        # Success-rate measurement happens off-device in the paper's
        # harness (read-back + host compare); the digital count is exact.
        return mismatch_count_ref(jnp.asarray(a, jnp.uint32).reshape(-1),
                                  jnp.asarray(b, jnp.uint32).reshape(-1))

    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        from repro.pud.arith import BitSerial

        bs = BitSerial(tier=self.ctx.tier, n_act=self.ctx.n_act,
                       executor=self)
        out, _ = bs.add(jnp.asarray(a, jnp.uint32),
                        jnp.asarray(b, jnp.uint32))
        return out

    # ------------------------------------------------- device-model hooks
    def _copy(self, plane: jax.Array) -> jax.Array:
        def one(row: jax.Array) -> jax.Array:
            self._accrue("COPY")
            sa = self._subarray(row.shape[-1])
            sa.write_row(0, row)
            rc.rowclone(sa, 0, 1)
            return sa.read_row(1)

        return self._per_row(one, plane)

    def _not(self, plane: jax.Array) -> jax.Array:
        # NOT is a complement-row copy (Ambit-style): clone the staged
        # complement so the op pays RowClone error semantics.
        def one(row: jax.Array) -> jax.Array:
            self._accrue("NOT")
            sa = self._subarray(row.shape[-1])
            sa.write_row(0, ~jnp.asarray(row, jnp.uint32))
            rc.rowclone(sa, 0, 1)
            return sa.read_row(1)

        return self._per_row(one, plane)

    def _frac(self, dsts: jax.Array, state: jax.Array) -> jax.Array:
        self._accrue("FRAC")
        return super()._frac(dsts, state)

    def _exec_op(self, op, state: jax.Array) -> jax.Array:
        # Row I/O is value-neutral in the image but not in joules: the
        # bus transfer pays WR/RD power for the full row time (Fig. 5).
        if op.kind in ("WR", "RD"):
            self._accrue(op.kind)
        return super()._exec_op(op, state)
