"""ExecutionContext: the one knob object every backend call takes.

The paper's central result is that the *same* APA command sequence yields
MAJX, Multi-RowCopy, or plain RowClone depending only on the operating
regime — timings (t1, t2), temperature, wordline voltage, data pattern.
``ExecutionContext`` captures exactly that regime (plus framework-side
execution knobs: interpret mode, tile geometry, RNG seed) so that the
regime is declared once and threaded to whichever backend executes,
instead of today's per-call keyword soup.
"""

from __future__ import annotations

import dataclasses

from repro.core import calibration as cal
from repro.core.errormodel import ErrorModel


@dataclasses.dataclass(frozen=True)
class Timings:
    """The violated-timing pairs (ns) issued per op class (§3.3/§3.4).

    Defaults are the paper's best operating points: MAJX at (1.5, 3),
    Multi-RowCopy at (36, 3), SiMRA at (3, 3).
    """

    majx_t1: float = cal.MAJX_BEST_T1_NS
    majx_t2: float = cal.MAJX_BEST_T2_NS
    mrc_t1: float = cal.MRC_BEST_T1_NS
    mrc_t2: float = cal.MRC_BEST_T2_NS
    simra_t1: float = cal.SIMRA_BEST_T1_NS
    simra_t2: float = cal.SIMRA_BEST_T2_NS


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Shared calibration point + execution knobs for all backends.

    Frozen and hashable: a context *is* an operating-regime identity,
    which is how the sweep runner caches one backend instance per
    distinct regime and how a grid point's regime becomes part of its
    stored record.  Derive variants with :meth:`replace`.

    Operating regime (device physics; consumed by ``sim`` and by latency
    / energy costing):

    * ``mfr`` — manufacturer profile ("H" / "M" / "S", Table 1),
    * ``timings`` — the issued (t1, t2) pairs per op class,
    * ``temp_c`` — DRAM temperature in Celsius (paper grid 50-90),
    * ``vpp_v`` — wordline voltage in volts (nominal 2.5, down to 2.1),
    * ``pattern`` — data pattern written to operand rows; one of
      :data:`repro.core.calibration.DATA_PATTERNS` (Obs 9/16),
    * ``ideal`` — disable stochastic error injection (pure digital
      semantics; every backend then matches the oracle bit-exactly).

    Compiler defaults (consumed by the bit-serial §8.1 programs):

    * ``tier`` — widest MAJ gate available (3/5/7/9),
    * ``n_act`` — simultaneous-activation count per MAJ issue
      (§4 Limitation 2: one of 2/4/8/16/32).

    Framework execution knobs:

    * ``certify`` — run the static analyzer over every fused artifact a
      :class:`~repro.session.DramSession` executes (cached by program
      content, see :meth:`repro.session.cache.CompileCache.
      certificate_for`); set False to opt out on hot paths that already
      certified their programs elsewhere,
    * ``interpret`` — Pallas interpret mode (CPU) vs compiled TPU,
    * ``block_r`` / ``block_c`` — VPU tile geometry for bulk kernels,
    * ``vmem_budget_bytes`` — on-chip working-set ceiling the megakernel
      executor's column planner blocks against
      (:func:`repro.compile.megakernel.plan_vmem`),
    * ``subarray_cols`` — behavioural-sim row width (bits),
    * ``seed`` — stable-mask RNG seed: the chip / row-group identity;
      sweeps treat distinct seeds as distinct tested chips.
    """

    mfr: str = "H"
    timings: Timings = dataclasses.field(default_factory=Timings)
    temp_c: float = 50.0
    vpp_v: float = 2.5
    pattern: str = "random"
    ideal: bool = False

    tier: int = 5
    n_act: int = 32

    certify: bool = True
    interpret: bool = True
    block_r: int = 8
    block_c: int = 512
    vmem_budget_bytes: int = 8 * 2**20
    subarray_cols: int = 1024
    seed: int = 0

    @property
    def error_model(self) -> ErrorModel:
        return ErrorModel(self.mfr)

    def env(self) -> dict:
        """Environment kwargs understood by the ErrorModel surfaces."""
        return {"temp_c": self.temp_c, "vpp_v": self.vpp_v}

    def replace(self, **kw) -> "ExecutionContext":
        return dataclasses.replace(self, **kw)
