"""AdamW with decoupled weight decay, grad clipping, ZeRO-friendly state.

Optimizer state mirrors parameter sharding (m/v get the same logical axes
as their parameter), which combined with the FSDP rules *is* the ZeRO
partitioning — no separate machinery needed.  fp32 master weights are kept
when params are low-precision.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 copies of low-precision params


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def state_axes(param_axes) -> AdamWState:
    """Logical axes for the optimizer state (mirrors params)."""
    return AdamWState(step=(), m=param_axes, v=param_axes, master=param_axes)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def lr_schedule(tc: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = tc.lr * (step + 1) / max(tc.warmup_steps, 1)
        prog = jnp.clip((step - tc.warmup_steps)
                        / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * tc.lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < tc.warmup_steps, warm, jnp.maximum(cos, 0.1 * tc.lr))
    return lr


def apply_updates(params, state: AdamWState, grads, tc: TrainConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc)(step)
    b1, b2, eps = tc.b1, tc.b2, tc.eps

    def upd(m, v, g, master):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + tc.weight_decay * master
        master2 = master - lr * delta
        return m2, v2, master2

    flat_m, tdef = jax.tree.flatten(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(grads)
    flat_w = jax.tree.leaves(state.master)
    outs = [upd(m, v, g, w) for m, v, g, w in
            zip(flat_m, flat_v, flat_g, flat_w)]
    new_m = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_master = jax.tree.unflatten(tdef, [o[2] for o in outs])
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_master, params)
    return new_params, AdamWState(step, new_m, new_v, new_master), {
        "grad_norm": gnorm, "lr": lr}
