"""Gradient compression with error feedback (distributed-optimization trick).

Two codecs, both with residual error feedback so compression error does not
accumulate (Karimireddy et al., 2019):

* **int8**: per-tensor symmetric quantization of the gradient before the
  (conceptual) all-reduce — 4x wire traffic reduction at bf16 training.
* **top-k**: magnitude sparsification keeping ``frac`` of entries.

In single-program XLA the all-reduce is implicit in sharding propagation;
the codec is applied around the gradient computation and its *wire-format
byte count* is reported so EXPERIMENTS.md can quote the collective-bytes
delta (the dry-run's collective term scales with it for DP-bound configs).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: Any


def init_feedback(params) -> ErrorFeedback:
    return ErrorFeedback(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quant_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk(g, frac: float):
    flat = g.reshape(-1)
    k = max(int(flat.size * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress(grads, fb: ErrorFeedback, method: str, topk_frac: float = 0.01):
    """Returns (decoded grads as seen post-allreduce, new feedback, stats)."""
    if method == "none":
        return grads, fb, {"wire_bytes_frac": 1.0}

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if method == "int8":
            dec = _quant_int8(gf)
        elif method == "topk":
            dec = _topk(gf, topk_frac)
        else:
            raise ValueError(method)
        return dec, gf - dec

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(fb.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    dec = jax.tree.unflatten(tdef, [o[0] for o in outs])
    res = jax.tree.unflatten(tdef, [o[1] for o in outs])
    frac = {"int8": 0.25, "topk": topk_frac * 2.5}[method]  # idx overhead
    return dec, ErrorFeedback(res), {"wire_bytes_frac": frac}
