"""Trainer: the production loop — checkpoint/restart, failure handling,
straggler monitoring, TMR-protected state, deterministic data replay.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.configs.base import ModelConfig, TrainConfig
from repro.ckpt import checkpoint as ckpt
from repro.ckpt import tmr_store
from repro.data.pipeline import SyntheticLM
from repro.ft.failures import FailurePlan, SimulatedFailure
from repro.ft.straggler import StragglerDetector
from repro.train.step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    tmr_replicas: int = 0          # 0 = plain store; 3/5 = voted store
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 loader: SyntheticLM, trainer_cfg: TrainerConfig = None,
                 failure_plan: Optional[FailurePlan] = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.tc = tc
        self.loader = loader
        self.tcfg = trainer_cfg or TrainerConfig()
        self.failures = failure_plan or FailurePlan()
        self.log = log_fn
        self.stragglers = StragglerDetector(n_workers=jax.device_count())
        self.step_fn = jax.jit(make_train_step(cfg, tc))
        self.history: list[dict] = []

    # ------------------------------------------------------------- state
    def _fresh_state(self) -> TrainState:
        key = jax.random.PRNGKey(self.tc.seed)
        state, _axes = init_train_state(key, self.cfg)
        return state

    def _save(self, state: TrainState, step: int) -> None:
        if not self.tcfg.ckpt_dir:
            return
        if self.tcfg.tmr_replicas:
            tmr_store.save(state, self.tcfg.ckpt_dir, step,
                           replicas=self.tcfg.tmr_replicas)
        else:
            ckpt.save(state, self.tcfg.ckpt_dir, step)

    def _restore(self, proto: TrainState) -> tuple[TrainState, int]:
        if not self.tcfg.ckpt_dir:
            return proto, 0
        try:
            if self.tcfg.tmr_replicas:
                state, step, healed = tmr_store.restore(proto, self.tcfg.ckpt_dir)
                if healed:
                    self.log(f"[trainer] TMR healed {healed} replica(s)")
            else:
                state, step = ckpt.restore(proto, self.tcfg.ckpt_dir)
            self.log(f"[trainer] restored step {step}")
            return state, step
        except FileNotFoundError:
            return proto, 0

    # --------------------------------------------------------------- run
    def run(self, steps: int) -> list[dict]:
        state = self._fresh_state()
        state, start = self._restore(state)
        step = start
        restarts = 0
        while step < steps:
            try:
                step = self._run_span(state, step, steps)
                return self.history
            except SimulatedFailure as e:
                restarts += 1
                self.log(f"[trainer] FAILURE: {e}; restart {restarts}")
                if restarts > self.tcfg.max_restarts:
                    raise
                state = self._fresh_state()
                state, step = self._restore(state)
        return self.history

    def _run_span(self, state: TrainState, step: int, steps: int) -> int:
        self._state = state
        while step < steps:
            self.failures.check(step)
            batch = self.loader.batch(step)
            t0 = time.time()
            self._state, metrics = self.step_fn(self._state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self.stragglers.record(0, dt)
            self.history.append({"step": step, "loss": loss, "time_s": dt})
            if step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms)")
            step += 1
            if step % self.tcfg.ckpt_every == 0:
                self._save(self._state, step)
        self._save(self._state, step)
        return step
