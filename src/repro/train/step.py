"""train_step: loss -> grads -> AdamW, with microbatching and compression.

The jitted step is built once per (cfg, mesh) with explicit in/out
shardings; gradient accumulation scans over microbatches so peak activation
memory is one microbatch (plus remat policy inside the model).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as M
from repro.optim import adamw
from repro.optim import compression as comp


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    feedback: comp.ErrorFeedback


def init_train_state(key, cfg: ModelConfig) -> tuple[TrainState, Any]:
    params, axes = M.init(key, cfg)
    state = TrainState(params=params, opt=adamw.init_state(params),
                       feedback=comp.init_feedback(params))
    state_axes = TrainState(params=axes, opt=adamw.state_axes(axes),
                            feedback=comp.ErrorFeedback(axes))
    return state, state_axes


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns fn(state, batch) -> (state, metrics)."""

    def loss(params, batch):
        return M.loss_fn(params, batch, cfg, z_loss=tc.z_loss)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(state: TrainState, batch):
        if tc.microbatches > 1:
            mb = tc.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            batches = jax.tree.map(split, batch)

            def acc_step(carry, mb_batch):
                gsum, lsum = carry
                (l, _aux), g = grad_fn(state.params, mb_batch)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (zeros, 0.0), batches)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss_val = lsum / mb
            metrics = {}
        else:
            (loss_val, metrics), grads = grad_fn(state.params, batch)

        grads, feedback, cstats = comp.compress(
            grads, state.feedback, tc.compression, tc.topk_frac)
        params, opt, ostats = adamw.apply_updates(
            state.params, state.opt, grads, tc)
        out = {"loss": loss_val, **ostats, **cstats}
        out.update({k: v for k, v in metrics.items()})
        return TrainState(params, opt, feedback), out

    return step


def make_eval_step(cfg: ModelConfig, tc: TrainConfig):
    def eval_step(params, batch):
        loss, metrics = M.loss_fn(params, batch, cfg, z_loss=0.0)
        return {"loss": loss, **metrics}
    return eval_step
