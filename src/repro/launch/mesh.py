"""Production mesh construction.

Single pod: (16, 16) = 256 chips as (data, model).
Multi-pod:  (2, 16, 16) = 512 chips as (pod, data, model); the ``pod``
axis carries only data parallelism + ZeRO sharding, so its collectives
(DP all-reduce, FSDP all-gather) are the only cross-DCN traffic — the
layout that scales past one ICI domain.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests run on 1 CPU device).
"""

from __future__ import annotations

import jax


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    # axis_types landed after jax 0.4.37; Auto is the default there anyway.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over available devices (tests / examples)."""
    n = n_devices or jax.device_count()
    model = min(model, n)
    return make_mesh((n // model, model), ("data", "model"))
