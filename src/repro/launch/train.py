"""Training launcher.

On this CPU container it drives the *smoke* configs end-to-end (the full
configs are exercised by the dry-run); on a real cluster the same entry
point runs the full configs — the mesh adapts to the available devices.

Examples:
  python -m repro.launch.train --arch xlstm-125m --smoke --steps 50
  python -m repro.launch.train --arch chatglm3-6b --smoke --steps 100 \
      --ckpt-dir /tmp/ck --tmr 3 --fail-at 30
"""

from __future__ import annotations

import argparse
import sys

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.failures import FailurePlan
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=("none", "int8", "topk"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--tmr", type=int, default=0,
                    help="TMR replica count for the checkpoint store (0=off)")
    ap.add_argument("--fail-at", type=int, action="append", default=[],
                    help="inject a simulated node failure at this step")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatches=args.microbatches,
                     compression=args.compression)
    loader = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, n_codebooks=cfg.n_codebooks,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
        d_model=cfg.d_model))
    trainer = Trainer(
        cfg, tc, loader,
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      tmr_replicas=args.tmr),
        failure_plan=FailurePlan(at_steps=tuple(args.fail_at)),
    )
    history = trainer.run(args.steps)
    first = history[0]["loss"] if history else float("nan")
    last = history[-1]["loss"] if history else float("nan")
    print(f"[train] {cfg.name}: loss {first:.4f} -> {last:.4f} "
          f"over {len(history)} recorded steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
