"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

Sources & methodology (CPU container, TPU v5e-like target):

* ``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed — but XLA
  counts a ``while`` body ONCE, so scanned layers and streaming-attention
  chunks would be undercounted ~L x.  We therefore lower *cost-mode*
  variants (dense attention forced; see models/attention.FORCE_DENSE) at
  composition points and combine:
      transformers:  C(L) = C0 + L * (C1 - C0)
      hybrid/zamba:  body = C(a+1) - C(a);  attn = C(a) - C0 - body
                     C = C0 + n_layers*body + n_full*attn     (a=attn_every)
      ssm/xlstm:     C(S) is linear in S (recurrent):  fit at S=64,128
* collective bytes are parsed from the *deploy* compile's optimized HLO:
  every all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute
  op contributes its wire bytes (all-reduce 2x operand for ring R-S+A-G;
  all-gather its result), multiplied by the layer trip count when the op
  lives inside the scan body (op_name metadata contains "/while/").
* ``memory_analysis()`` of the deploy compile proves per-chip fit.

Hardware constants (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI)
come from the one :data:`repro.core.costmodel.COST` model, shared with
the PUD offload planner so the two can never disagree; the names below
are re-exports, not definitions.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core.costmodel import (
    HBM_BW as HBM_BW,
    ICI_BW as ICI_BW,
    PEAK_FLOPS as PEAK_FLOPS,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"(\((?:[a-z0-9]+\[[0-9,]*\][^)]*)\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float
    n_ops: int

    @property
    def dominant(self) -> str:
        if not self.bytes_by_kind:
            return "none"
        return max(self.bytes_by_kind, key=self.bytes_by_kind.get)


def collective_bytes(hlo_text: str, loop_multiplier: int = 1,
                     loop_trips: Optional[list] = None) -> CollectiveStats:
    """Sum wire bytes of collectives in optimized HLO (per-chip program).

    Ops inside while bodies (op_name metadata contains "/while/") get
    multiplied by the enclosing trip counts: ``loop_trips`` is an
    outer-to-inner list (e.g. [microbatches, n_layers]); an op nested under
    ``n`` whiles multiplies by ``prod(loop_trips[:n])``.  The legacy
    ``loop_multiplier`` is shorthand for ``loop_trips=[loop_multiplier]``.
    """
    if loop_trips is None:
        loop_trips = [loop_multiplier]
    by_kind: dict[str, float] = {}
    n = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count the -start, not the -done
        shape_txt, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_txt)
        if kind == "all-reduce":
            nbytes *= 2  # ring reduce-scatter + all-gather
        depth = line.count("/while/")
        op_m = re.search(r'op_name="([^"]*)"', line)
        if op_m:
            depth = op_m.group(1).count("while/")
        # deeper nesting than provided trips (e.g. attention chunk loops)
        # conservatively multiplies by 1 — those loops carry no collectives
        # in our programs.
        mult = 1
        for trip in loop_trips[:depth]:
            mult *= trip
        by_kind[kind] = by_kind.get(kind, 0.0) + nbytes * mult
        n += 1
    return CollectiveStats(by_kind, sum(by_kind.values()), n)


# ---------------------------------------------------------------------------
# analytic model FLOPs (the MODEL_FLOPS row of the table)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training (2*N_active*D inference) + attention."""
    n_active = cfg.n_active_params()
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = gb * s
        base = 6 * n_active * tokens
        mult = 3  # fwd + bwd
    elif shape.kind == "prefill":
        tokens = gb * s
        base = 2 * n_active * tokens
        mult = 1
    else:  # decode: one token against an s-long context
        tokens = gb
        base = 2 * n_active * tokens
        mult = 1

    attn = 0.0
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        n_attn_layers = cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // max(cfg.attn_every, 1)
    else:
        n_attn_layers = 0
    if n_attn_layers:
        h, hd = cfg.n_heads, cfg.hd
        if shape.kind == "decode":
            ctx = min(s, cfg.sliding_window) if cfg.sliding_window else s
            attn = 4 * gb * ctx * h * hd * n_attn_layers  # QK + PV
        else:
            eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
            # causal halves the S x S_eff score work
            attn = (4 * gb * s * eff * h * hd / 2) * n_attn_layers * mult
    return float(base + attn)


# ---------------------------------------------------------------------------
# composition of cost-mode measurements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CostPoint:
    flops: float
    bytes_accessed: float


def compose(cfg, points: dict[int, CostPoint]) -> CostPoint:
    """Combine cost-mode compile points into the full-depth estimate."""
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        c0, c1 = points[0], points[1]
        return CostPoint(
            flops=c0.flops + cfg.n_layers * (c1.flops - c0.flops),
            bytes_accessed=c0.bytes_accessed
            + cfg.n_layers * (c1.bytes_accessed - c0.bytes_accessed))
    if cfg.family == "hybrid":
        a = cfg.attn_every
        c0, ca, ca1 = points[0], points[a], points[a + 1]
        body_f = ca1.flops - ca.flops
        body_b = ca1.bytes_accessed - ca.bytes_accessed
        attn_f = ca.flops - c0.flops - body_f
        attn_b = ca.bytes_accessed - c0.bytes_accessed - body_b
        n_full = cfg.n_layers // a
        return CostPoint(
            flops=c0.flops + cfg.n_layers * body_f + n_full * attn_f,
            bytes_accessed=(c0.bytes_accessed + cfg.n_layers * body_b
                            + n_full * attn_b))
    raise ValueError(f"no composition rule for family {cfg.family}")


def compose_seq(s_target: int, s_points: dict[int, CostPoint]) -> CostPoint:
    """Linear-in-S fit for recurrent (ssm) families."""
    (s1, c1), (s2, c2) = sorted(s_points.items())
    df = (c2.flops - c1.flops) / (s2 - s1)
    db = (c2.bytes_accessed - c1.bytes_accessed) / (s2 - s1)
    return CostPoint(flops=c1.flops + df * (s_target - s1),
                     bytes_accessed=c1.bytes_accessed + db * (s_target - s1))


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_dominant_kind: str
    model_flops_global: float
    mem_per_chip_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction: time the compute term would take at
        peak vs the dominant term (1.0 = perfectly compute-bound at peak
        with ideal HLO)."""
        t_ideal = self.model_flops_global / self.n_chips / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_ideal / t_bound if t_bound > 0 else 0.0

    @property
    def hlo_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundant compute."""
        total_hlo = self.flops_per_chip * self.n_chips
        return self.model_flops_global / total_hlo if total_hlo else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "model_flops": self.model_flops_global,
            "hlo_flops_global": self.flops_per_chip * self.n_chips,
            "hlo_efficiency": self.hlo_efficiency,
            "coll_dominant": self.coll_dominant_kind,
            "mem_per_chip_gb": self.mem_per_chip_bytes / 2**30,
        }
