"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

Everything here is allocation-free: specs are ShapeDtypeStructs (via
eval_shape), shardings come from the logical-axis rules.  The dry-run
lowers

    train_step(state, batch)            for train shapes
    prefill_step(params, batch)         for prefill shapes
    decode_step(params, tokens, cache)  for decode shapes (incl. long_500k)

with caches sized to the shape's context length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import sharding_for, tree_shardings
from repro.models import model as M
from repro.models.attention import KVCache
from repro.optim import adamw
from repro.optim import compression as comp
from repro.train.step import TrainState


def _dp_axes(mesh: Mesh, batch: int | None = None):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch is not None:
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        if batch % extent != 0:
            return ()  # e.g. long_500k's global_batch=1: replicate
    return axes


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                with_labels: bool):
    dp = _dp_axes(mesh, shape.global_batch)
    gb, s = shape.global_batch, shape.seq_len
    tok_shape = (gb, s, cfg.n_codebooks) if cfg.family == "audio" else (gb, s)
    specs = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    shardings = {"tokens": NamedSharding(mesh, P(dp))}
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
        shardings["labels"] = NamedSharding(mesh, P(dp))
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_patches, cfg.d_model), jnp.float32)
        shardings["patches"] = NamedSharding(mesh, P(dp, None, None))
    return specs, shardings


def params_specs(cfg: ModelConfig, mesh: Mesh):
    abstract, axes = M.init_abstract(cfg)
    return abstract, tree_shardings(axes, mesh), axes


def state_specs(cfg: ModelConfig, mesh: Mesh):
    """Abstract TrainState + shardings (ZeRO: opt state mirrors params)."""
    params_abs, param_axes = M.init_abstract(cfg)

    def mk_opt(p):
        return adamw.init_state(p)

    opt_abs = jax.eval_shape(mk_opt, params_abs)
    fb_abs = jax.eval_shape(lambda p: comp.init_feedback(p), params_abs)
    abstract = TrainState(params=params_abs, opt=opt_abs, feedback=fb_abs)
    st_axes = TrainState(params=param_axes,
                         opt=adamw.state_axes(param_axes),
                         feedback=comp.ErrorFeedback(param_axes))
    return abstract, tree_shardings(st_axes, mesh), st_axes


def cache_axes_tree(cfg: ModelConfig, cache_abstract: M.ServeCache):
    """Logical axes matching a ServeCache structure.

    KV caches: batch over dp, head_dim over tp (head_dim is divisible by
    the TP degree for every assigned arch, and the dynamic-position cache
    update touches only the *unsharded* seq dim — no resharding on decode).
    Mamba states: heads over tp.  xLSTM states: batch only (125M model).
    """
    def kv_axes(stacked: bool):
        lead = (None,) if stacked else ()
        return KVCache(k=lead + ("kv_batch", None, None, "tp"),
                       v=lead + ("kv_batch", None, None, "tp"),
                       pos=lead + ("kv_batch",))

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return M.ServeCache(kv_axes(stacked=True), None)
    if cfg.family == "hybrid":
        from repro.models.mamba2 import MambaState

        m_axes = [MambaState(h=(None, "kv_batch", "tp", None, None),
                             conv=(None, "kv_batch", None, "tp"))
                  for _ in cache_abstract.layers]
        a_axes = [kv_axes(stacked=False) for _ in (cache_abstract.extra or [])]
        return M.ServeCache(m_axes, a_axes)
    if cfg.family == "ssm":
        from repro.models.xlstm import MLSTMState, SLSTMState

        axes = []
        for st in cache_abstract.layers:
            if isinstance(st, MLSTMState):
                axes.append(MLSTMState(c=("kv_batch", None, None, None),
                                       n=("kv_batch", None, None),
                                       m=("kv_batch", None)))
            else:
                axes.append(SLSTMState(
                    c=("kv_batch", None), n=("kv_batch", None),
                    h=("kv_batch", None), m=("kv_batch", None)))
        return M.ServeCache(axes, None)
    raise ValueError(cfg.family)


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(tok_spec, cache_spec, tok_sharding, cache_sharding) for decode."""
    dp = _dp_axes(mesh, shape.global_batch)
    gb = shape.global_batch
    tshape = (gb, 1, cfg.n_codebooks) if cfg.family == "audio" else (gb, 1)
    tok_spec = jax.ShapeDtypeStruct(tshape, jnp.int32)
    tok_shard = sharding_for(tshape, ("batch",) + (None,) * (len(tshape) - 1),
                             mesh)
    cache_abs = jax.eval_shape(lambda: M.fresh_cache(cfg, gb, shape.seq_len))
    axes = cache_axes_tree(cfg, cache_abs)
    if not dp:  # tiny global batch (long_500k): replicate the batch dim
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        axes = jax.tree.map(
            lambda t: tuple(None if a in ("batch", "kv_batch") else a
                            for a in t),
            axes, is_leaf=is_axes)
    cache_shard = tree_shardings(axes, mesh)
    return tok_spec, cache_abs, tok_shard, cache_shard
