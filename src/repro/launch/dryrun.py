import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at
# first backend initialization.  The dry-run is the ONLY entry point that
# fakes 512 devices; tests and benches see the real (1-CPU) topology.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

For each cell this lowers the production step function with ShapeDtypeStruct
inputs on the requested mesh, compiles it, and records:
  * memory_analysis()  — proves the cell fits per-chip HBM,
  * cost_analysis()    — FLOPs / bytes for the roofline,
  * the optimized HLO's collective schedule (parsed wire bytes),
  * cost-mode composition points (see launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
  python -m repro.launch.dryrun --all --multipod --out results.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import SHAPES, all_configs, get_config, shape_applicable
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attention_mod
from repro.models import model as M
from repro.train.step import make_train_step


def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() returns a 1-elem list on older jax."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _train_fn(cfg, microbatches: int = 1):
    tc = TrainConfig(microbatches=microbatches, compression="none")
    return make_train_step(cfg, tc)


def _prefill_fn(cfg, shape):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, batch, cfg, shape.seq_len)
        return logits

    return prefill_step


def _decode_fn(cfg):
    def decode_step(params, tokens, cache):
        return M.decode(params, tokens, cache, cfg)

    return decode_step


def lower_cell(cfg, shape, mesh, *, donate: bool = True,
               microbatches: int = 1, serve_rules: bool = False):
    """Returns (lowered, compiled) for one cell on one mesh.

    ``serve_rules=True`` lowers decode cells under the activation-
    stationary SERVE_RULES (see repro.dist.sharding; §Perf H3).
    """
    from repro.dist import sharding as shd
    import contextlib

    rules_ctx = (shd.use_rules(shd.SERVE_RULES)
                 if serve_rules else contextlib.nullcontext())
    with mesh, rules_ctx:
        if shape.kind == "train":
            state_abs, state_shard, _ = sp.state_specs(cfg, mesh)
            batch_abs, batch_shard = sp.batch_specs(cfg, shape, mesh, True)
            fn = jax.jit(
                _train_fn(cfg, microbatches),
                in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,) if donate else (),
            )
            lowered = fn.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs, params_shard, _ = sp.params_specs(cfg, mesh)
            batch_abs, batch_shard = sp.batch_specs(cfg, shape, mesh, False)
            fn = jax.jit(_prefill_fn(cfg, shape),
                         in_shardings=(params_shard, batch_shard))
            lowered = fn.lower(params_abs, batch_abs)
        else:  # decode
            params_abs, params_shard, _ = sp.params_specs(cfg, mesh)
            tok_abs, cache_abs, tok_shard, cache_shard = sp.decode_specs(
                cfg, shape, mesh)
            fn = jax.jit(
                _decode_fn(cfg),
                in_shardings=(params_shard, tok_shard, cache_shard),
                out_shardings=(None, cache_shard),
                donate_argnums=(2,) if donate else (),
            )
            lowered = fn.lower(params_abs, tok_abs, cache_abs)
        compiled = lowered.compile()
    return lowered, compiled


def _cost_points(cfg, shape, mesh):
    """Cost-mode compile points for the roofline composition."""
    attention_mod.FORCE_DENSE = True
    try:
        points = {}
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            depths = (0, 1)
        elif cfg.family == "hybrid":
            depths = (0, cfg.attn_every, cfg.attn_every + 1)
        else:  # ssm: S-composition at full depth
            pts = {}
            for s_small in (64, 128):
                sh = dataclasses.replace(shape, seq_len=s_small)
                _, comp = lower_cell(cfg, sh, mesh, donate=False)
                ca = _cost_analysis(comp)
                pts[s_small] = rl.CostPoint(ca.get("flops", 0.0),
                                            ca.get("bytes accessed", 0.0))
            if shape.kind == "decode":
                # decode for ssm is python-unrolled: exact, no composition
                _, comp = lower_cell(cfg, shape, mesh, donate=False)
                ca = _cost_analysis(comp)
                return rl.CostPoint(ca.get("flops", 0.0),
                                    ca.get("bytes accessed", 0.0))
            return rl.compose_seq(shape.seq_len, pts)
        for d in depths:
            cfg_d = dataclasses.replace(cfg, n_layers=d, remat="none")
            _, comp = lower_cell(cfg_d, shape, mesh, donate=False)
            ca = _cost_analysis(comp)
            points[d] = rl.CostPoint(ca.get("flops", 0.0),
                                     ca.get("bytes accessed", 0.0))
        return rl.compose(cfg, points)
    finally:
        attention_mod.FORCE_DENSE = False


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             skip_cost: bool = False, verbose: bool = True,
             microbatches: int = 4, serve_rules: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    mb = microbatches if shape.kind == "train" else 1
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape, mesh, microbatches=mb,
                                   serve_rules=serve_rules)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    trips = [max(cfg.n_layers, 1)] if mb == 1 else [mb, max(cfg.n_layers, 1)]
    coll = rl.collective_bytes(hlo, loop_trips=trips)
    ca = _cost_analysis(compiled)
    deploy_cost = rl.CostPoint(ca.get("flops", 0.0),
                               ca.get("bytes accessed", 0.0))

    if skip_cost:
        cost = deploy_cost
    else:
        try:
            cost = _cost_points(cfg, shape, mesh)
        except Exception:
            traceback.print_exc()
            cost = deploy_cost

    n_chips = mesh.devices.size
    # donated inputs alias outputs: count aliased bytes once
    mem_per_chip = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes
                    + mem.generated_code_size_in_bytes)
    report = rl.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=cost.flops, bytes_per_chip=cost.bytes_accessed,
        coll_bytes_per_chip=coll.total_bytes,
        coll_dominant_kind=coll.dominant,
        model_flops_global=rl.model_flops(cfg, shape),
        mem_per_chip_bytes=mem_per_chip,
    )
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2**30,
            "output_gb": mem.output_size_in_bytes / 2**30,
            "temp_gb": mem.temp_size_in_bytes / 2**30,
            "alias_gb": mem.alias_size_in_bytes / 2**30,
            "total_gb": mem_per_chip / 2**30,
        },
        "collectives": {
            "per_kind_gb": {k: v / 2**30 for k, v in coll.bytes_by_kind.items()},
            "total_gb": coll.total_bytes / 2**30,
            "n_ops": coll.n_ops,
        },
        "deploy_cost": dataclasses.asdict(deploy_cost),
        "roofline": report.row(),
    }
    if verbose:
        r = out["roofline"]
        print(f"[dryrun] {arch:24s} {shape_name:12s} mesh={mesh_name:10s} "
              f"mem={out['memory']['total_gb']:.2f}GB "
              f"tC={r['t_compute_s']:.3e} tM={r['t_memory_s']:.3e} "
              f"tX={r['t_collective_s']:.3e} bound={r['bottleneck']:<10s} "
              f"frac={r['roofline_fraction']:.3f} compile={t_compile:.0f}s",
              flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="skip cost-mode composition compiles (faster)")
    ap.add_argument("--microbatches", type=int, default=4,
                    help="grad-accumulation microbatches for train cells")
    ap.add_argument("--serve-rules", action="store_true",
                    help="decode cells: activation-stationary SERVE_RULES")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in all_configs():
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    results, failures = [], 0
    for arch, shape_name in cells:
        try:
            results.append(run_cell(arch, shape_name, args.multipod,
                                    skip_cost=args.skip_cost,
                                    microbatches=args.microbatches,
                                    serve_rules=args.serve_rules))
        except Exception as e:
            failures += 1
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape_name,
                            "status": "error", "error": str(e)[:500]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    print(f"[dryrun] {sum(1 for r in results if r['status']=='ok')} ok, "
          f"{sum(1 for r in results if r['status']=='skipped')} skipped, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
