"""Serving launcher: batched generation with continuous batching.

  python -m repro.launch.serve --arch gemma-7b --smoke --requests 8
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params, _ = M.init(key, cfg)
    engine = Engine(params, cfg, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        if cfg.family == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (args.prompt_len, cfg.n_codebooks),
                                  dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (args.prompt_len,),
                                  dtype=np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {cfg.name}: {len(done)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    for r in done[:2]:
        toks = [int(np.asarray(t).flat[0]) for t in r.out_tokens[:8]]
        print(f"  req {r.rid}: {toks} ...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
