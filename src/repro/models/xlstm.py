"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows arXiv:2405.04517 with stabilized exponential gating:
  mLSTM:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
          y_t = (C_t q_t) / max(|n_t . q_t|, 1)
  sLSTM:  scalar cell per unit with hidden-state recurrence feeding gates.

Both use the log-space stabilizer m_t = max(log f_t + m_{t-1}, log i_t).
mLSTM is parallelizable (we scan chunks); sLSTM is strictly sequential by
construction (hidden recurrence) and scans per step — it is used sparsely
(cfg.slstm_layers), as in the paper's LM configs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, P, P) matrix memory
    n: jax.Array  # (B, H, P) normalizer
    m: jax.Array  # (B, H) stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, D) cell
    n: jax.Array  # (B, D)
    h: jax.Array  # (B, D) hidden (recurrent input)
    m: jax.Array  # (B, D) stabilizer


def _pdim(cfg: ModelConfig) -> int:
    return (2 * cfg.d_model) // cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 4)
    params = {
        "w_up": dense_init(ks[0], d, (d, 2 * di), dt),     # [x_in, z-gate]
        "w_qkv": dense_init(ks[1], di, (di, 3 * di), dt),
        "w_if": dense_init(ks[2], di, (di, 2 * nh), dt),   # exp gates/head
        "w_down": dense_init(ks[3], di, (di, d), dt),
    }
    axes = {"w_up": ("fsdp", "tp"), "w_qkv": ("tp", None),
            "w_if": ("tp", None), "w_down": ("tp", "fsdp")}
    return params, axes


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    nh, p = cfg.n_heads, _pdim(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, nh, p, p), jnp.float32),
        n=jnp.zeros((batch, nh, p), jnp.float32),
        m=jnp.full((batch, nh), -1e30, jnp.float32),
    )


def _mlstm_step(state: MLSTMState, q, k, v, i_raw, f_raw):
    """One time step; q/k/v: (B,H,P), gates: (B,H) raw logits."""
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    logi = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(logf + state.m, logi)
    f_ = jnp.exp(logf + state.m - m_new)
    i_ = jnp.exp(logi - m_new)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    p = qf.shape[-1]
    kf = kf / jnp.sqrt(jnp.float32(p))
    c = f_[..., None, None] * state.c + i_[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])
    n = f_[..., None] * state.n + i_[..., None] * kf
    num = jnp.einsum("bhpq,bhq->bhp", c, qf)
    # Stabilized normalizer: with n normalized by exp(m), the |n.q| >= 1
    # floor of the raw recurrence becomes exp(-m) (official xLSTM form).
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf)),
                      jnp.exp(-m_new))
    y = num / den[..., None]
    return MLSTMState(c=c, n=n, m=m_new), y


def _pick_chunk(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (chunked scans need s % c == 0)."""
    c = min(want, s)
    while s % c:
        c -= 1
    return c


def mlstm_forward(params, x, cfg: ModelConfig, state: MLSTMState | None = None,
                  chunk: int = 128):
    """x: (B,S,D) -> (y, final_state).

    Chunked gated-linear-attention form of the mLSTM recurrence: within a
    chunk the quadratic (t,s) form, across chunks the normalized-state
    carry — algebraically identical to the per-step recurrence (including
    the log-space stabilizer; see test_xlstm.py) but with O(S/c) scan
    steps, so the backward pass saves O(S/c) carries instead of O(S)
    (the 3.9 TB -> GBs fix for the 4k/32k training shapes).
    """
    b, s, d = x.shape
    nh, p = cfg.n_heads, _pdim(cfg)
    di = 2 * d
    up = x @ params["w_up"]
    xin, z = up[..., :di], up[..., di:]
    qkv = xin @ params["w_qkv"]
    q, k, v = jnp.split(qkv.reshape(b, s, 3, nh, p), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    gates = (xin @ params["w_if"]).reshape(b, s, 2, nh)
    i_raw, f_raw = gates[:, :, 0], gates[:, :, 1]
    st = state if state is not None else init_mlstm_state(cfg, b)

    c = _pick_chunk(s, chunk)
    nc = s // c
    qf = q.astype(jnp.float32).reshape(b, nc, c, nh, p)
    kf = (k.astype(jnp.float32) / jnp.sqrt(jnp.float32(p))
          ).reshape(b, nc, c, nh, p)
    vf = v.astype(jnp.float32).reshape(b, nc, c, nh, p)
    logi = i_raw.astype(jnp.float32).reshape(b, nc, c, nh)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32)).reshape(b, nc, c, nh)
    tril = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        c_n, n_n, m_in = carry                  # (b,h,p,p),(b,h,p),(b,h)
        qc, kc, vc, lic, lfc = inp              # (b,c,h,p) / (b,c,h)
        bcum = jnp.cumsum(lfc, axis=1)          # inclusive cumulative logf
        # D[t,s] = b_t - b_s + logi_s for s <= t
        D = bcum[:, :, None, :] - bcum[:, None, :, :] + lic[:, None, :, :]
        D = jnp.where(tril[None, :, :, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)            # (b,c,h)
        m_tot = jnp.maximum(bcum + m_in[:, None, :], m_intra)
        alpha = jnp.exp(bcum + m_in[:, None, :] - m_tot)
        W = jnp.exp(D - m_tot[:, :, None, :])   # (b,t,s,h)
        G = jnp.einsum("bthk,bshk->btsh", qc, kc)
        y_inter = alpha[..., None] * jnp.einsum("bhvk,bthk->bthv", c_n, qc)
        y_num = y_inter + jnp.einsum("btsh,bshv->bthv", W * G, vc)
        n_t = (alpha[..., None] * n_n[:, None]
               + jnp.einsum("btsh,bshk->bthk", W, kc))
        dot = jnp.einsum("bthk,bthk->bth", n_t, qc)
        denom = jnp.maximum(jnp.abs(dot), jnp.exp(-m_tot))
        h_out = y_num / denom[..., None]        # (b,c,h,p)
        # carry update
        total = bcum[:, -1]                     # (b,h)
        w_end = total[:, None, :] - bcum + lic  # (b,s,h)
        m_out = jnp.maximum(total + m_in, jnp.max(w_end, axis=1))
        decay = jnp.exp(total + m_in - m_out)
        wexp = jnp.exp(w_end - m_out[:, None, :])
        c_out = (decay[..., None, None] * c_n
                 + jnp.einsum("bsh,bshv,bshk->bhvk", wexp, vc, kc))
        n_out = decay[..., None] * n_n + jnp.einsum("bsh,bshk->bhk", wexp, kc)
        return (c_out, n_out, m_out), h_out

    (c_f, n_f, m_f), ys = jax.lax.scan(
        chunk_step, (st.c, st.n, st.m),
        (qf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
         logi.swapaxes(0, 1), logf.swapaxes(0, 1)))
    y = ys.transpose(1, 0, 2, 3, 4).astype(x.dtype).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    return y @ params["w_down"], MLSTMState(c=c_f, n=n_f, m=m_f)


def mlstm_forward_reference(params, x, cfg: ModelConfig,
                            state: MLSTMState | None = None):
    """Per-step oracle for the chunked path (tests)."""
    b, s, d = x.shape
    nh, p = cfg.n_heads, _pdim(cfg)
    di = 2 * d
    up = x @ params["w_up"]
    xin, z = up[..., :di], up[..., di:]
    qkv = xin @ params["w_qkv"]
    q, k, v = jnp.split(qkv.reshape(b, s, 3, nh, p), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    gates = (xin @ params["w_if"]).reshape(b, s, 2, nh)
    i_raw, f_raw = gates[:, :, 0], gates[:, :, 1]
    st = state if state is not None else init_mlstm_state(cfg, b)

    def step(carry, t):
        qt, kt, vt, it, ft = t
        return _mlstm_step(carry, qt, kt, vt, it, ft)

    st, ys = jax.lax.scan(
        step, st,
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         i_raw.swapaxes(0, 1), f_raw.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).astype(x.dtype).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    return y @ params["w_down"], st


def mlstm_decode(params, x, cfg: ModelConfig, state: MLSTMState):
    b = x.shape[0]
    nh, p = cfg.n_heads, _pdim(cfg)
    di = 2 * x.shape[-1]
    up = x[:, 0] @ params["w_up"]
    xin, z = up[..., :di], up[..., di:]
    qkv = (xin @ params["w_qkv"]).reshape(b, 3, nh, p)
    gates = (xin @ params["w_if"]).reshape(b, 2, nh)
    st, y = _mlstm_step(state, qkv[:, 0], qkv[:, 1], qkv[:, 2],
                        gates[:, 0], gates[:, 1])
    y = y.astype(x.dtype).reshape(b, di) * jax.nn.silu(z)
    return (y @ params["w_down"])[:, None], st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 3)
    f = max(cfg.d_ff, (8 * d) // 3)
    params = {
        "w_x": dense_init(ks[0], d, (d, 4 * d), dt),   # i,f,z,o from input
        "r_h": dense_init(ks[1], d, (d, 4 * d), dt),   # recurrent
        "w_ff1": dense_init(ks[2], d, (d, f), dt),
        "w_ff2": dense_init(jax.random.fold_in(ks[2], 1), f, (f, d), dt),
    }
    axes = {"w_x": ("fsdp", "tp"), "r_h": ("fsdp", "tp"),
            "w_ff1": ("fsdp", "tp"), "w_ff2": ("tp", "fsdp")}
    return params, axes


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_step(params, state: SLSTMState, xt):
    """xt: (B, D)."""
    d = xt.shape[-1]
    pre = (xt @ params["w_x"]).astype(jnp.float32) \
        + (state.h.astype(xt.dtype) @ params["r_h"]).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    i_ = jnp.exp(i_raw - m_new)
    f_ = jnp.exp(logf + state.m - m_new)
    c = f_ * state.c + i_ * jnp.tanh(z_raw)
    n = f_ * state.n + i_
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_forward(params, x, cfg: ModelConfig, state: SLSTMState | None = None,
                  chunk: int = 64):
    """Strictly-sequential sLSTM; nested chunk scans bound backward memory
    (outer scan saves one small carry per chunk, inner steps recompute
    under jax.checkpoint)."""
    b, s, d = x.shape
    st = state if state is not None else init_slstm_state(cfg, b)
    c = _pick_chunk(s, chunk)
    nc = s // c
    xc = x.reshape(b, nc, c, d).swapaxes(0, 1)  # (nc, b, c, d)

    def chunk_fn(carry, xck):
        def step(stt, xt):
            new = _slstm_step(params, stt, xt)
            return new, new.h

        stt, hs = jax.lax.scan(step, carry, xck.swapaxes(0, 1))
        return stt, hs  # hs: (c, b, d)

    st, hs = jax.lax.scan(jax.checkpoint(chunk_fn), st, xc)
    y = hs.transpose(2, 0, 1, 3).reshape(b, s, d).astype(x.dtype)
    ff = jax.nn.gelu(y @ params["w_ff1"], approximate=True) @ params["w_ff2"]
    return ff, st


def slstm_decode(params, x, cfg: ModelConfig, state: SLSTMState):
    st = _slstm_step(params, state, x[:, 0])
    y = st.h.astype(x.dtype)[:, None]
    ff = jax.nn.gelu(y @ params["w_ff1"], approximate=True) @ params["w_ff2"]
    return ff, st
