"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch layout: tokens are grouped by their data shard — the buffer is
``(G, E, C, D)`` with ``G`` the DP extent, sharded ``(dp, ep, -, -)``.
Each (data-shard, expert-shard) chip pair owns exactly its ``(g, e_local)``
block, so the expert einsum runs with zero weight collectives (weights are
ep-sharded along the same axis).

**Gather-only dataflow (custom VJP).**  XLA's SPMD partitioner handles
batched *gathers* well but falls back to full operand replication for the
*scatters* that appear in a naive dispatch — and in the *backward pass* of
a gather-based dispatch.  Because the kept (token, slot) -> (expert, cap)
mapping is a bijection, every backward scatter can be rewritten as the
opposite-direction gather; ``_dispatch``/``_combine`` carry custom VJPs
doing exactly that, so the whole MoE layer (fwd+bwd) lowers to batched
gathers + einsums only.  (Observed effect at qwen3-train_4k scale:
hundreds of GB of replicated scatter operands disappear.)

Per-arch policy: Qwen3 shards the 128-expert dim over ``tp`` (EP);
Mixtral's 8 experts < 16 chips, so experts replicate and the per-expert
FFN hidden shards over ``tp`` (TP-in-expert).  Tokens overflowing an
expert's capacity are dropped (standard; the aux loss drives balance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import _current_mesh, constraint
from repro.models.common import dense_init


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 4)
    ep = "expert" if cfg.moe_shard_experts else None
    tp_in = None if cfg.moe_shard_experts else "tp"
    params = {
        "router": dense_init(ks[0], d, (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], d, (e, d, f), dt),
        "w_up": dense_init(ks[2], d, (e, d, f), dt),
        "w_down": dense_init(ks[3], f, (e, f, d), dt),
    }
    axes = {
        "router": ("fsdp", None),
        "w_gate": (ep, "fsdp", tp_in),
        "w_up": (ep, "fsdp", tp_in),
        "w_down": (ep, tp_in, "fsdp"),
    }
    return params, axes


def _dp_groups(t: int) -> int:
    mesh = _current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    return g if t % g == 0 else 1


# ---------------------------------------------------------------------------
# gather-only dispatch / combine (custom VJP)
# ---------------------------------------------------------------------------


def _flat_gather(src, flat_idx):
    """src: (G, N, D); flat_idx: (G, M) -> (G, M, D).

    Single-axis take_along_axis: no broadcast of the operand across extra
    index dims (a broadcasted gather materializes (G, E, Tg, D)-sized
    intermediates under SPMD — the 10 TB failure mode this layout avoids).
    """
    return jnp.take_along_axis(src, flat_idx[..., None], axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _dispatch(xt, idx, slot_valid, ej, pos, keep):
    """buf[g,e,c,:] = xt[g, idx[g,e,c], :]  (invalid slots zeroed)."""
    g, e, c = idx.shape
    buf = _flat_gather(xt, idx.reshape(g, e * c)).reshape(g, e, c, -1)
    return jnp.where(slot_valid[..., None], buf, 0)


def _dispatch_fwd(xt, idx, slot_valid, ej, pos, keep):
    return _dispatch(xt, idx, slot_valid, ej, pos, keep), (ej, pos, keep)


def _dispatch_bwd(res, dbuf):
    ej, pos, keep = res  # each (k, G, Tg)
    k = ej.shape[0]
    g_, e_, c_, d_ = dbuf.shape
    flat = dbuf.reshape(g_, e_ * c_, d_)
    dxt = None
    for j in range(k):
        # gather the slot gradient back to its (unique) source token
        grad = _flat_gather(flat, ej[j] * c_ + pos[j])
        grad = jnp.where(keep[j][..., None], grad, 0)
        dxt = grad if dxt is None else dxt + grad
    return (dxt, None, None, None, None, None)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _combine(y, weights, idx, slot_valid, wsel, ej, pos, keep):
    """out[g,t,:] = sum_j weights[g,t,j] * y[g, ej[j], pos[j], :]."""
    k = ej.shape[0]
    g_, e_, c_, d_ = y.shape
    flat = y.reshape(g_, e_ * c_, d_)
    out = None
    for j in range(k):
        gath = _flat_gather(flat, ej[j] * c_ + pos[j])
        gath = jnp.where(keep[j][..., None], gath, 0)
        term = gath * weights[..., j][..., None]
        out = term if out is None else out + term
    return out


def _combine_fwd(y, weights, idx, slot_valid, wsel, ej, pos, keep):
    out = _combine(y, weights, idx, slot_valid, wsel, ej, pos, keep)
    return out, (y, weights, idx, slot_valid, wsel, ej, pos, keep)


def _combine_bwd(res, dout):
    y, weights, idx, slot_valid, wsel, ej, pos, keep = res
    g_, e_, c_, d_ = y.shape
    # dy[g,e,c,:] = wsel[g,e,c] * dout[g, idx[g,e,c], :]   (gather, not
    # scatter: each kept slot has exactly one source token)
    dsrc = _flat_gather(dout, idx.reshape(g_, e_ * c_)).reshape(g_, e_, c_, d_)
    dy = jnp.where(slot_valid[..., None], dsrc * wsel[..., None], 0)
    dy = dy.astype(y.dtype)
    # dweights[g,t,j] = <dout[g,t], y[g, ej, pos]>
    k = ej.shape[0]
    flat = y.reshape(g_, e_ * c_, d_)
    dws = []
    for j in range(k):
        gath = _flat_gather(flat, ej[j] * c_ + pos[j])
        gath = jnp.where(keep[j][..., None], gath, 0)
        dws.append(jnp.sum(dout * gath, axis=-1))
    dweights = jnp.stack(dws, axis=-1).astype(weights.dtype)
    return (dy, dweights, None, None, None, None, None, None)


_combine.defvjp(_combine_fwd, _combine_bwd)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def moe_forward(params, x, cfg: ModelConfig, capacity: int | None = None):
    """x: (B, S, D) -> (B, S, D), plus aux loss (scalar fp32)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = _dp_groups(t)
    tg = t // g
    xt = constraint(x.reshape(g, tg, d), ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"])
    topv, topi = jax.lax.top_k(logits, k)                  # (G, Tg, k)
    weights = jax.nn.softmax(topv, axis=-1).astype(x.dtype)

    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    if capacity is None:
        capacity = max(int(cfg.capacity_factor * tg * k / e), 8)
    capacity = min(capacity, tg)

    ep = "expert" if cfg.moe_shard_experts else None
    buf_axes = ("batch", ep, None, None)

    # FCFS expert queues via top-k on priority score (gathers only).
    member = jnp.zeros((g, tg, e), jnp.int32)
    for j in range(k):
        member = member + jax.nn.one_hot(topi[..., j], e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(member, axis=1) - 1               # (G, Tg, E)
    t_idx = jnp.arange(tg, dtype=jnp.int32)
    score = jnp.where(member.transpose(0, 2, 1) > 0,
                      (tg - t_idx)[None, None, :].astype(jnp.float32),
                      -jnp.inf)                             # (G, E, Tg)
    score = constraint(score, ("batch", ep, None))
    top_scores, idx = jax.lax.top_k(score, capacity)        # (G, E, C)
    slot_valid = top_scores > -jnp.inf

    ej, pos, keep = [], [], []
    for j in range(k):
        e_j = topi[..., j]
        p_j = jnp.take_along_axis(pos_in_e, e_j[..., None], axis=2)[..., 0]
        k_j = p_j < capacity
        ej.append(e_j)
        pos.append(jnp.where(k_j, p_j, capacity - 1))
        keep.append(k_j)
    ej = jnp.stack(ej)
    pos = jnp.stack(pos)
    keep = jnp.stack(keep)

    buf = _dispatch(xt, idx, slot_valid, ej, pos, keep)
    buf = constraint(buf, buf_axes)

    gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = jax.nn.silu(gate) * up
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out_buf = constraint(out_buf, buf_axes)

    # per-slot combine weight (for the gather-only backward)
    w_e = jnp.zeros((g, tg, e), x.dtype)
    for j in range(k):
        w_e = w_e + (jax.nn.one_hot(topi[..., j], e, dtype=x.dtype)
                     * weights[..., j][..., None])
    wsel = jnp.take_along_axis(w_e.transpose(0, 2, 1), idx, axis=2)

    out = _combine(out_buf, weights, idx, slot_valid, wsel, ej, pos, keep)
    out = constraint(out, ("batch", None, None))
    return out.reshape(b, s, d), aux.astype(jnp.float32)
