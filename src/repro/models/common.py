"""Shared model components: norms, initializers, parameter plumbing.

Parameter convention: params are nested dicts of arrays; every init
function returns ``(params, axes)`` where ``axes`` mirrors the params tree
with tuples of *logical* sharding axes (see repro.dist.sharding).  Layer
stacks are stacked along a leading axis for ``lax.scan`` and get ``None``
prepended to their logical axes.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Axes = tuple


def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype, scale: float = 0.02) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def act_fn(name: str) -> Callable:
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def stack_params(param_list, axes):
    """Stack per-layer param trees along a new leading (scan) axis."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)
    stacked_axes = jax.tree.map(
        lambda a: (None,) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x),
    )
    return stacked, stacked_axes


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
