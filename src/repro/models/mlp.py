"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU."""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.common import act_fn, dense_init


def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        params = {
            "w_gate": dense_init(ks[0], d, (d, f), dt),
            "w_up": dense_init(ks[1], d, (d, f), dt),
            "w_down": dense_init(ks[2], f, (f, d), dt),
        }
        axes = {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
                "w_down": ("tp", "fsdp")}
    else:
        params = {
            "w_up": dense_init(ks[0], d, (d, f), dt),
            "w_down": dense_init(ks[1], f, (f, d), dt),
        }
        axes = {"w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}
    return params, axes


def mlp_forward(params, x, cfg: ModelConfig):
    if cfg.mlp_act == "swiglu":
        act = jax.nn.silu(x @ params["w_gate"])
        return (act * (x @ params["w_up"])) @ params["w_down"]
    if cfg.mlp_act == "geglu":
        act = jax.nn.gelu(x @ params["w_gate"], approximate=True)
        return (act * (x @ params["w_up"])) @ params["w_down"]
    h = act_fn("gelu")(x @ params["w_up"])
    return h @ params["w_down"]
