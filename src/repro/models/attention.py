"""Causal attention: MHA / GQA / MQA, sliding window, RoPE, KV cache.

Three execution paths, all numerically cross-checked in tests:
* dense path (train / short prefill): one einsum chain;
* **streaming path** (long prefill): nested q-chunk x kv-chunk scan with a
  running-max softmax (flash-attention recurrence in pure lax), bounding
  activation memory at O(q_chunk x kv_chunk) per step — required for the
  32k/500k shapes on 16 GB chips;
* decode path: single-token query against the cache (+ rolling window
  cache for SWA archs, which is what makes long_500k run on Mixtral).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import axis_extent, constraint
from repro.models.common import dense_init

NEG_INF = -2.0e38

#: Roofline cost-mode hook: forces the dense (non-streaming) attention path
#: so XLA cost analysis sees the full S^2 work (scan bodies are counted
#: once by XLA's analysis; see launch/roofline.py for the methodology).
FORCE_DENSE = False


# ---------------------------------------------------------------------------
# RoPE (standard / partial "2d")
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, rot_dim: int, theta: float):
    """positions: (..., S) int32 -> cos/sin tables (..., S, rot_dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, rot_dim: int):
    """x: (B, S, H, D); rotates the first rot_dim dims (GLM partial RoPE
    keeps the tail un-rotated when rotary_pct < 1)."""
    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    r1, r2 = rot[..., 0::2], rot[..., 1::2]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    o1 = r1 * c - r2 * s
    o2 = r2 * c + r1 * s
    rot_out = jnp.stack([o1, o2], axis=-1).reshape(rot.shape).astype(x.dtype)
    return jnp.concatenate([rot_out, rest], axis=-1) if rest.shape[-1] else rot_out


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d, (d, h * hd), dt),
        "wk": dense_init(ks[1], d, (d, kvh * hd), dt),
        "wv": dense_init(ks[2], d, (d, kvh * hd), dt),
        "wo": dense_init(ks[3], h * hd, (h * hd, d), dt),
    }
    axes = {
        "wq": ("fsdp", "tp"),
        "wk": ("fsdp", "tp"),
        "wv": ("fsdp", "tp"),
        "wo": ("tp", "fsdp"),
    }
    return params, axes


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, window: int):
    """(..., Sq, Sk) additive bias: causal (+ sliding window)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# dense path
# ---------------------------------------------------------------------------


def _repeat_kv(k, h):
    """(B,S,KVH,D) -> (B,S,H,D): GQA group broadcast, TP-cleanly sharded.

    Keeping the einsums on *flat* heads (rather than a (kvh, g) split)
    lets the TP axis shard the head dim evenly even when kvh < TP degree
    (chatglm/glm have kvh=2 on a 16-way model axis); the repeat is a
    broadcast XLA keeps fused and costs no HBM for the weights.
    """
    kvh = k.shape[2]
    if kvh == h:
        return k
    return jnp.repeat(k, h // kvh, axis=2)


def _attn_shard_mode(h: int) -> str:
    """"heads" TP when the head count divides the TP extent, else
    sequence-parallel attention (Ulysses-style): q/scores shard the query
    sequence dim and k/v replicate — works for any head count (deepseek's
    56 and musicgen's 24 heads don't divide a 16-way model axis)."""
    tp = axis_extent("tp")
    return "heads" if h % max(tp, 1) == 0 else "seq"


def _attend_dense(q, k, v, q_pos, k_pos, cfg: ModelConfig):
    """q: (B,Sq,H,D)  k/v: (B,Sk,KVH,D) -> (B,Sq,H,D)."""
    b, sq, h, hd = q.shape
    mode = _attn_shard_mode(h)
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    if mode == "heads":
        k = constraint(k, ("batch", None, "tp", None))
        v = constraint(v, ("batch", None, "tp", None))
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    bias = _mask_bias(q_pos, k_pos, cfg.sliding_window)
    scores = scores + bias[:, None]
    if mode == "heads":
        scores = constraint(scores, ("batch", "tp", None, None))
    else:
        scores = constraint(scores, ("batch", None, "sp", None))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    if mode == "heads":
        return constraint(out, ("batch", None, "tp", None))
    return constraint(out, ("batch", "sp", None, None))


# ---------------------------------------------------------------------------
# streaming (flash-style) path for long sequences
# ---------------------------------------------------------------------------


def _attend_streaming(q, k, v, q_pos, k_pos, cfg: ModelConfig,
                      q_chunk: int = 1024, kv_chunk: int = 1024):
    """Flash-style nested-chunk attention on flat (TP-sharded) heads.

    With head counts that don't divide TP, the q-chunk grid dim shards
    over ``sp`` instead (sequence-parallel attention).
    """
    b, s, h, hd = q.shape
    mode = _attn_shard_mode(h)
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    if mode == "heads":
        k = constraint(k, ("batch", None, "tp", None))
        v = constraint(v, ("batch", None, "tp", None))
    # chunk sizes must divide s (e.g. phi3v prefill: 32768 tokens + 576
    # patch embeddings = 33344 = 64 * 521)
    def _div_chunk(want: int) -> int:
        c = min(want, s)
        while s % c:
            c -= 1
        return c

    q_chunk = _div_chunk(q_chunk)
    kv_chunk = _div_chunk(kv_chunk)
    nq = s // q_chunk
    nk = s // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    q_r = q.reshape(b, nq, q_chunk, h, hd)
    qp_r = q_pos.reshape(b, nq, q_chunk)
    k_r = k.reshape(b, nk, kv_chunk, h, hd)
    v_r = v.reshape(b, nk, kv_chunk, h, hd)
    kp_r = k_pos.reshape(b, nk, kv_chunk)

    def q_step(_, qi):
        qc, qpc = qi  # (b, qc, h, hd), (b, qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, kpc = ki
            s_ = jnp.einsum("bqhd,bshd->bhqs", qc, kc).astype(jnp.float32)
            s_ = s_ * scale + _mask_bias(qpc, kpc, cfg.sliding_window)[:, None]
            m_new = jnp.maximum(m, s_.max(axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(qc.dtype), vc).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_r.swapaxes(0, 1), v_r.swapaxes(0, 1), kp_r.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b, h, qc, hd) -> (b, qc, h, hd)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, outs = jax.lax.scan(
        q_step, None, (q_r.swapaxes(0, 1), qp_r.swapaxes(0, 1)))
    # (nq, b, qc, h, hd) -> (b, s, h, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer cache.  For SWA archs the buffer is a rolling window."""

    k: jax.Array  # (B, S_buf, KVH, HD)
    v: jax.Array
    pos: jax.Array  # (B,) next absolute position


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    buf = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    kvh, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.compute_dtype
    return KVCache(
        k=jnp.zeros((batch, buf, kvh, hd), dt),
        v=jnp.zeros((batch, buf, kvh, hd), dt),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def cache_axes() -> KVCache:
    return KVCache(k=("batch", None, None, "tp"),
                   v=("batch", None, None, "tp"),
                   pos=("batch",))


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def attention_forward(params, x, positions, cfg: ModelConfig,
                      streaming_threshold: int = 8192):
    """Training/prefill attention over a full sequence."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kvh, hd)
    v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    rot = int(cfg.rotary_pct * hd) // 2 * 2
    if rot:
        cos, sin = rope_tables(positions, rot, cfg.rope_theta)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    if _attn_shard_mode(h) == "heads":
        q = constraint(q, ("batch", None, "tp", None))
    else:
        q = constraint(q, ("batch", "sp", None, None))
    if s > streaming_threshold and not FORCE_DENSE:
        out = _attend_streaming(q, k, v, positions, positions, cfg)
    else:
        out = _attend_dense(q, k, v, positions, positions, cfg)
    return out.reshape(b, s, h * hd) @ params["wo"]


def attention_decode(params, x, cache: KVCache, cfg: ModelConfig):
    """Single-token decode step; x: (B, 1, D).  Returns (out, new_cache)."""
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kvh, hd)
    v = (x @ params["wv"]).reshape(b, 1, kvh, hd)
    pos = cache.pos  # (B,)
    rot = int(cfg.rotary_pct * hd) // 2 * 2
    if rot:
        cos, sin = rope_tables(pos[:, None], rot, cfg.rope_theta)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    # Keep the single-token q/k/v on the cache's batch sharding: resharding
    # the (B, 1, ...) activations is KBs, gathering the cache would be GBs.
    q = constraint(q, ("kv_batch", None, None, None))
    k = constraint(k, ("kv_batch", None, None, None))
    v = constraint(v, ("kv_batch", None, None, None))
    buf = cache.k.shape[1]
    if cfg.sliding_window:
        slot = pos % buf
    else:
        slot = jnp.minimum(pos, buf - 1)
    bidx = jnp.arange(b)
    k_buf = cache.k.at[bidx, slot].set(k[:, 0])
    v_buf = cache.v.at[bidx, slot].set(v[:, 0])
    # absolute positions held in each cache slot (rolling for SWA)
    slots = jnp.arange(buf)[None, :]
    if cfg.sliding_window:
        # slot s holds position: the latest p <= pos with p % buf == s
        cur = pos[:, None]
        k_pos = cur - ((cur - slots) % buf)
    else:
        k_pos = jnp.broadcast_to(slots, (b, buf))
    valid = k_pos <= pos[:, None]
    # invalid/empty slots get a +huge sentinel so the causal mask
    # (k_pos <= q_pos) rejects them (a negative sentinel would pass it
    # and leak softmax mass onto zeroed cache slots)
    k_pos = jnp.where(valid, k_pos, 1_000_000_000)
    kr = _repeat_kv(k_buf, h)
    vr = _repeat_kv(v_buf, h)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, kr).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    bias = _mask_bias(pos[:, None], k_pos, cfg.sliding_window)
    scores = scores + bias[:, None]
    scores = constraint(scores, ("kv_batch", None, None, None))
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vr).reshape(b, 1, h * hd)
    out = constraint(out, ("kv_batch", None, None))
    new_cache = KVCache(k=k_buf, v=v_buf, pos=pos + 1)
    return out @ params["wo"], new_cache


def prefill_cache(params, x, positions, cfg: ModelConfig, max_seq: int):
    """Full-sequence prefill that also materializes the cache."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = attention_forward(params, x, positions, cfg)
    k = (x @ params["wk"]).reshape(b, s, kvh, hd)
    v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    rot = int(cfg.rotary_pct * hd) // 2 * 2
    if rot:
        cos, sin = rope_tables(positions, rot, cfg.rope_theta)
        k = apply_rope(k, cos, sin, rot)
    cache = init_cache(cfg, b, max_seq)
    buf = cache.k.shape[1]
    take = min(s, buf)
    # Rolling-window alignment: position p lives in slot p % buf, so the
    # trailing window is written then rolled by (s - take) % buf (zero for
    # the full-cache case where slot == position).
    shift = (s - take) % buf
    k_buf = jax.lax.dynamic_update_slice_in_dim(cache.k, k[:, -take:], 0, axis=1)
    v_buf = jax.lax.dynamic_update_slice_in_dim(cache.v, v[:, -take:], 0, axis=1)
    if shift:
        k_buf = jnp.roll(k_buf, shift, axis=1)
        v_buf = jnp.roll(v_buf, shift, axis=1)
    cache = KVCache(k=k_buf, v=v_buf, pos=jnp.full((b,), s, jnp.int32))
    return out, cache
