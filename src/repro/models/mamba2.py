"""Mamba2 (SSD) mixer: chunked selective-state-space recurrence.

Implements the Mamba-2 scalar-decay-per-head SSM (arXiv:2405.21060) with the
chunked SSD algorithm: within a chunk the quadratic (attention-like) form,
across chunks the state recurrence — so activation memory is
O(chunk^2 + d_state) instead of O(S * d_state) and the 500k-token shape
streams.  Decode is a single O(1) state update.

State per head: h in R^{head_dim x d_state};  per step t:
    h_t = a_t * h_{t-1} + dt_t * x_t (x) B_t      (a_t = exp(-dt_t * A))
    y_t = h_t @ C_t + D * x_t,   gated by silu(z_t)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


class MambaState(NamedTuple):
    h: jax.Array        # (B, H, P, N) SSM state
    conv: jax.Array     # (B, K-1, D_inner + 2N) conv tail


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.n_heads
    p = d_inner // n_heads
    return d_inner, n_heads, p, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, nh, p, n = _dims(cfg)
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * n
    params = {
        # projects to [z (d_inner), x (d_inner), B (n), C (n), dt (nh)]
        "w_in": dense_init(ks[0], d, (d, 2 * d_inner + 2 * n + nh), dt),
        "conv_w": dense_init(ks[1], cfg.ssm_conv, (cfg.ssm_conv, conv_ch), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, (d_inner, d), dt),
    }
    axes = {
        "w_in": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "w_out": ("tp", "fsdp"),
    }
    return params, axes


def _split_proj(proj, cfg: ModelConfig):
    d_inner, nh, p, n = _dims(cfg)
    z = proj[..., :d_inner]
    x = proj[..., d_inner:2 * d_inner]
    b = proj[..., 2 * d_inner:2 * d_inner + n]
    c = proj[..., 2 * d_inner + n:2 * d_inner + 2 * n]
    dt_raw = proj[..., 2 * d_inner + 2 * n:]
    return z, x, b, c, dt_raw


def _causal_conv(xbc, conv_w, tail=None):
    """Depthwise causal conv over (B, S, CH); tail = (B, K-1, CH) history."""
    k = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([tail, xbc], axis=1)
    out = sum(padded[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    new_tail = padded[:, -(k - 1):] if k > 1 else tail
    return jax.nn.silu(out), new_tail


def mamba2_forward(params, x, cfg: ModelConfig, state: MambaState | None = None):
    """Full-sequence forward; returns (y, final_state).

    x: (B, S, D).  S must be a multiple of cfg.ssm_chunk (callers pad).
    """
    bsz, s, _ = x.shape
    d_inner, nh, p, n = _dims(cfg)
    ch = cfg.ssm_chunk
    nchunks = s // ch

    proj = x @ params["w_in"]
    z, xin, b, c, dt_raw = _split_proj(proj, cfg)
    xbc, new_tail = _causal_conv(
        jnp.concatenate([xin, b, c], axis=-1), params["conv_w"],
        None if state is None else state.conv)
    xin, b, c = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + n],
                 xbc[..., d_inner + n:])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])          # (B,S,H)
    a = -jnp.exp(params["a_log"])                       # (H,)
    loga = dt * a                                       # (B,S,H) log decay
    xh = xin.reshape(bsz, s, nh, p)

    # chunked SSD
    loga_c = loga.reshape(bsz, nchunks, ch, nh)
    dt_c = dt.reshape(bsz, nchunks, ch, nh)
    x_c = xh.reshape(bsz, nchunks, ch, nh, p)
    b_c = b.reshape(bsz, nchunks, ch, n).astype(jnp.float32)
    c_c = c.reshape(bsz, nchunks, ch, n).astype(jnp.float32)

    h0 = (jnp.zeros((bsz, nh, p, n), jnp.float32)
          if state is None else state.h)

    def chunk_step(h, inp):
        la, dtk, xk, bk, ck = inp  # (B,ch,H), (B,ch,H), (B,ch,H,P), (B,ch,N)x2
        cum = jnp.cumsum(la, axis=1)                    # (B,ch,H)
        # inter-chunk: y_t += (prod decay to t) * C_t . h0
        y_inter = jnp.einsum("btn,bhpn->bthp", ck, h)
        y_inter = y_inter * jnp.exp(cum).transpose(0, 1, 2)[..., None]
        # intra-chunk quadratic form
        # L[t,s] = exp(cum_t - cum_s) for s <= t  (per head)
        rel = cum[:, :, None, :] - cum[:, None, :, :]   # (B,t,s,H)
        mask = jnp.tril(jnp.ones((ch, ch), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        g = jnp.einsum("btn,bsn->bts", ck, bk)          # (B,t,s)
        dx = xk.astype(jnp.float32) * dtk[..., None]    # (B,s,H,P)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", g, L, dx)
        # state update: h' = exp(sum la) h + sum_s exp(cum_end - cum_s) dx_s B_s
        tot = cum[:, -1]                                # (B,H)
        w = jnp.exp(tot[:, None] - cum)                 # (B,s,H)
        h_new = jnp.exp(tot)[..., None, None] * h + jnp.einsum(
            "bshp,bsn,bsh->bhpn", dx, bk, w)
        return h_new, (y_inter + y_intra)

    h_final, y_chunks = jax.lax.scan(
        chunk_step, h0,
        (loga_c.swapaxes(0, 1), dt_c.swapaxes(0, 1), x_c.swapaxes(0, 1),
         b_c.swapaxes(0, 1), c_c.swapaxes(0, 1)))
    y = y_chunks.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, p)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(bsz, s, d_inner)
    y = y * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, MambaState(h=h_final, conv=new_tail)


def mamba2_decode(params, x, cfg: ModelConfig, state: MambaState):
    """Single-token step; x: (B, 1, D)."""
    bsz = x.shape[0]
    d_inner, nh, p, n = _dims(cfg)
    proj = x @ params["w_in"]
    z, xin, b, c, dt_raw = _split_proj(proj, cfg)
    xbc, new_tail = _causal_conv(
        jnp.concatenate([xin, b, c], axis=-1), params["conv_w"], state.conv)
    xin, b, c = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + n],
                 xbc[..., d_inner + n:])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                              # (B,H)
    xh = xin.reshape(bsz, nh, p).astype(jnp.float32)
    bf = b[:, 0].astype(jnp.float32)
    cf = c[:, 0].astype(jnp.float32)
    h = decay[..., None, None] * state.h + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, bf, dt)
    y = jnp.einsum("bhpn,bn->bhp", h, cf)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.astype(x.dtype).reshape(bsz, 1, d_inner)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], MambaState(h=h, conv=new_tail)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    d_inner, nh, p, n = _dims(cfg)
    return MambaState(
        h=jnp.zeros((batch, nh, p, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * n),
                       cfg.compute_dtype),
    )


def mamba2_reference(params, x, cfg: ModelConfig):
    """Naive per-step recurrence — the oracle for the chunked path."""
    bsz, s, _ = x.shape
    state = init_mamba_state(cfg, bsz)
    ys = []
    for t in range(s):
        y, state = mamba2_decode(params, x[:, t:t + 1], cfg, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
