"""Model assembly: embed -> scanned blocks -> head, for all six families.

Public API (all functional):
  init(key, cfg)                       -> (params, axes)
  forward(params, batch, cfg)          -> logits            (training)
  prefill(params, batch, cfg, max_seq) -> (logits, cache)
  decode(params, tokens, cache, cfg)   -> (logits, cache)   (one step)
  loss_fn(params, batch, cfg, ...)     -> (loss, metrics)

Layer stacks run under ``lax.scan`` with stacked parameters (compile-time
O(1) in depth) and configurable rematerialization.  Decode scans over
(layer params, layer cache) pairs, emitting the updated cache as scan ys.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constraint
from repro.models import attention as attn
from repro.models import mamba2, mlp, moe, xlstm
from repro.models.common import embed_init, rms_norm, split_keys, stack_params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_tblock(key, cfg: ModelConfig):
    """One transformer block (dense or MoE)."""
    k1, k2 = jax.random.split(key)
    a_p, a_ax = attn.init_attention(k1, cfg)
    if cfg.is_moe:
        f_p, f_ax = moe.init_moe(k2, cfg)
        fkey = "moe"
    else:
        f_p, f_ax = mlp.init_mlp(k2, cfg)
        fkey = "mlp"
    params = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32), "attn": a_p,
              "ln2": jnp.zeros((cfg.d_model,), jnp.float32), fkey: f_p}
    axes = {"ln1": (None,), "attn": a_ax, "ln2": (None,), fkey: f_ax}
    return params, axes


def _tblock_forward(p, x, positions, cfg: ModelConfig):
    h = attn.attention_forward(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                               positions, cfg)
    x = x + h
    sp = "sp" if cfg.seq_shard else None
    x = constraint(x, ("batch", sp, None))
    if cfg.is_moe:
        h, aux = moe.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        aux = jnp.float32(0)
    # sequence-parallel carry: the scan-saved residual is seq-sharded
    return constraint(x + h, ("batch", sp, None)), aux


def _tblock_decode(p, x, cache, cfg: ModelConfig):
    h, new_cache = attn.attention_decode(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, cfg)
    x = x + h
    if cfg.is_moe:
        h, _ = moe.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                               cfg, capacity=max(x.shape[0], 8))
    else:
        h = mlp.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + h, new_cache


def _tblock_prefill(p, x, positions, cfg: ModelConfig, max_seq: int):
    h, cache = attn.prefill_cache(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions, cfg, max_seq)
    x = x + h
    if cfg.is_moe:
        h, _ = moe.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + h, cache


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def _init_embed(key, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.family == "audio":
        p = {"tok": embed_init(key, (cfg.n_codebooks, cfg.vocab_size,
                                     cfg.d_model), dt)}
        ax = {"tok": (None, "tp", "fsdp")}
        return p, ax
    p = {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model), dt)}
    ax = {"tok": ("tp", "fsdp")}
    return p, ax


def _embed(p, tokens, cfg: ModelConfig):
    if cfg.family == "audio":
        # tokens: (B, S, CB); sum codebook embeddings (delay pattern stub)
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), cfg.compute_dtype)
        for cb in range(cfg.n_codebooks):
            x = x + jnp.take(p["tok"][cb], tokens[..., cb], axis=0)
    else:
        x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def _init_head(key, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.family == "audio":
        p = {"w": embed_init(key, (cfg.n_codebooks, cfg.d_model,
                                   cfg.vocab_size), dt)}
        return p, {"w": (None, "fsdp", "tp")}
    if cfg.tie_embeddings:
        return {}, {}
    p = {"w": embed_init(key, (cfg.d_model, cfg.vocab_size), dt)}
    return p, {"w": ("fsdp", "tp")}


def _head(p, embed_p, x, cfg: ModelConfig):
    if cfg.family == "audio":
        return jnp.einsum("bsd,cdv->bscv", x, p["w"])
    if cfg.tie_embeddings:
        return x @ embed_p["tok"].T
    return x @ p["w"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig):
    ks = split_keys(key, cfg.n_layers + 4)
    emb_p, emb_ax = _init_embed(ks[0], cfg)
    head_p, head_ax = _init_head(ks[1], cfg)
    params: dict[str, Any] = {"embed": emb_p, "head": head_p,
                              "ln_f": jnp.zeros((cfg.d_model,), jnp.float32)}
    axes: dict[str, Any] = {"embed": emb_ax, "head": head_ax, "ln_f": (None,)}

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cfg.n_layers == 0:  # roofline L0 composition point
            params["blocks"], axes["blocks"] = {}, {}
        else:
            layers = [_init_tblock(ks[2 + i], cfg) for i in range(cfg.n_layers)]
            params["blocks"], axes["blocks"] = stack_params(
                [p for p, _ in layers], layers[0][1])
    elif cfg.family == "hybrid":
        if cfg.n_layers == 0:
            params["blocks"], axes["blocks"] = {}, {}
        else:
            layers = [mamba2.init_mamba2(ks[2 + i], cfg)
                      for i in range(cfg.n_layers)]
            params["blocks"], axes["blocks"] = stack_params(
                [p for p, _ in layers], layers[0][1])
            params["mamba_ln"] = jnp.zeros((cfg.n_layers, cfg.d_model),
                                           jnp.float32)
            axes["mamba_ln"] = (None, None)
            # the Zamba *shared* attention block (one set, reused)
            sp, sax = _init_tblock(ks[2 + cfg.n_layers], cfg)
            params["shared_attn"], axes["shared_attn"] = sp, sax
    elif cfg.family == "ssm":
        blocks, baxes = [], []
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                p, ax = xlstm.init_slstm(ks[2 + i], cfg)
            else:
                p, ax = xlstm.init_mlstm(ks[2 + i], cfg)
            ln = jnp.zeros((cfg.d_model,), jnp.float32)
            blocks.append({"ln": ln, "mix": p})
            baxes.append({"ln": (None,), "mix": ax})
        params["blocks"] = blocks
        axes["blocks"] = baxes
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params, axes


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def forward(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    x = _embed(params["embed"], tokens, cfg)
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constraint(x, ("batch", "sp", None))
    aux_total = jnp.float32(0)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(carry, layer_p):
            h, aux = carry
            h2, a = _tblock_forward(layer_p, h, positions, cfg)
            return (h2, aux + a), None

        if cfg.n_layers > 0:
            (x, aux_total), _ = jax.lax.scan(
                _remat(body, cfg), (x, aux_total), params["blocks"])
    elif cfg.family == "hybrid":
        x, aux_total = _zamba_forward(params, x, positions, cfg)
    elif cfg.family == "ssm":
        for i, bp in enumerate(params["blocks"]):
            def layer_fn(h, bp=bp, i=i):
                hh = rms_norm(h, bp["ln"], cfg.norm_eps)
                if i in cfg.slstm_layers:
                    y, _ = xlstm.slstm_forward(bp["mix"], hh, cfg)
                else:
                    y, _ = xlstm.mlstm_forward(bp["mix"], hh, cfg)
                return constraint(h + y, ("batch", "sp", None))

            x = (jax.checkpoint(layer_fn)(x) if cfg.remat != "none"
                 else layer_fn(x))

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(params["head"], params["embed"], x, cfg)
    if cfg.family == "vlm" and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]
    return logits, aux_total


def _zamba_groups(cfg: ModelConfig):
    per = cfg.attn_every
    n_full = cfg.n_layers // per
    rem = cfg.n_layers - n_full * per
    return n_full, per, rem


def _zamba_forward(params, x, positions, cfg: ModelConfig):
    n_full, per, rem = _zamba_groups(cfg)

    def mamba_body(carry, xs):
        h = carry
        layer_p, ln = xs
        y, _ = mamba2.mamba2_forward(layer_p, rms_norm(h, ln, cfg.norm_eps), cfg)
        return constraint(h + y, ("batch", "sp", None)), None

    body = _remat(mamba_body, cfg)
    shared = _remat(
        lambda h: _tblock_forward(params["shared_attn"], h, positions, cfg),
        cfg)
    aux = jnp.float32(0)
    for g in range(n_full):
        xs = (jax.tree.map(lambda a: a[g * per:(g + 1) * per], params["blocks"]),
              params["mamba_ln"][g * per:(g + 1) * per])
        x, _ = jax.lax.scan(body, x, xs)
        x2, a = shared(x)
        x, aux = x2, aux + a
    if rem:
        xs = (jax.tree.map(lambda a: a[-rem:], params["blocks"]),
              params["mamba_ln"][-rem:])
        x, _ = jax.lax.scan(body, x, xs)
    return x, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg: ModelConfig, z_loss: float = 1e-4,
            aux_coef: Optional[float] = None):
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather along the
    # tp-sharded vocab dim would force XLA to replicate the logits
    # (B x S x V fp32 per chip); the masked reduction stays sharded.
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), lg.shape[-1],
                            dtype=lg.dtype)
    ll = jnp.sum(lg * onehot, axis=-1)
    nll = lse - ll
    if "mask" in batch:
        mask = batch["mask"].astype(jnp.float32)
        if mask.ndim < nll.ndim:
            mask = mask[..., None]
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = (nll * mask).sum() / denom
        zl = ((lse ** 2) * mask).sum() / denom
    else:
        loss = nll.mean()
        zl = (lse ** 2).mean()
    total = loss + z_loss * zl
    coef = cfg.router_aux_coef if aux_coef is None else aux_coef
    if cfg.is_moe:
        total = total + coef * aux / cfg.n_layers
    return total, {"nll": loss, "z_loss": zl, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


class ServeCache(NamedTuple):
    layers: Any         # stacked per-layer cache pytree
    extra: Any          # family-specific (shared attn cache, etc.)


def prefill(params, batch, cfg: ModelConfig, max_seq: int):
    tokens = batch["tokens"]
    x = _embed(params["embed"], tokens, cfg)
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(h, layer_p):
            h2, cache = _tblock_prefill(layer_p, h, positions, cfg, max_seq)
            return h2, cache

        if cfg.n_layers > 0:
            x, caches = jax.lax.scan(body, x, params["blocks"])
        else:  # roofline L0 composition point
            caches = None
        sc = ServeCache(caches, None)
    elif cfg.family == "hybrid":
        x, sc = _zamba_prefill(params, x, positions, cfg, max_seq)
    elif cfg.family == "ssm":
        layer_states = []
        for i, bp in enumerate(params["blocks"]):
            h = rms_norm(x, bp["ln"], cfg.norm_eps)
            if i in cfg.slstm_layers:
                y, st = xlstm.slstm_forward(bp["mix"], h, cfg)
            else:
                y, st = xlstm.mlstm_forward(bp["mix"], h, cfg)
            x = x + y
            layer_states.append(st)
        sc = ServeCache(layer_states, None)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(params["head"], params["embed"], x[:, -1:], cfg)
    return logits, sc


def _zamba_prefill(params, x, positions, cfg, max_seq):
    n_full, per, rem = _zamba_groups(cfg)
    m_states = []
    attn_caches = []

    def mk_body(ln_all):
        def body(carry, xs):
            h = carry
            layer_p, ln = xs
            y, st = mamba2.mamba2_forward(
                layer_p, rms_norm(h, ln, cfg.norm_eps), cfg)
            return h + y, st
        return body

    body = mk_body(params["mamba_ln"])
    for g in range(n_full):
        xs = (jax.tree.map(lambda a: a[g * per:(g + 1) * per], params["blocks"]),
              params["mamba_ln"][g * per:(g + 1) * per])
        x, sts = jax.lax.scan(body, x, xs)
        m_states.append(sts)
        x, cache = _tblock_prefill(params["shared_attn"], x, positions, cfg,
                                   max_seq)
        attn_caches.append(cache)
    if rem:
        xs = (jax.tree.map(lambda a: a[-rem:], params["blocks"]),
              params["mamba_ln"][-rem:])
        x, sts = jax.lax.scan(body, x, xs)
        m_states.append(sts)
    return x, ServeCache(m_states, attn_caches)


def decode(params, tokens, cache: ServeCache, cfg: ModelConfig):
    """One decode step.  tokens: (B, 1) int32 (audio: (B, 1, CB))."""
    x = _embed(params["embed"], tokens, cfg)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(h, xs):
            layer_p, layer_cache = xs
            h2, new_cache = _tblock_decode(layer_p, h, layer_cache, cfg)
            return h2, new_cache

        if cfg.n_layers > 0:
            x, new_caches = jax.lax.scan(
                body, x, (params["blocks"], cache.layers))
        else:  # roofline L0 composition point
            new_caches = cache.layers
        new_sc = ServeCache(new_caches, None)
    elif cfg.family == "hybrid":
        x, new_sc = _zamba_decode(params, x, cache, cfg)
    elif cfg.family == "ssm":
        new_states = []
        for i, bp in enumerate(params["blocks"]):
            h = rms_norm(x, bp["ln"], cfg.norm_eps)
            if i in cfg.slstm_layers:
                y, st = xlstm.slstm_decode(bp["mix"], h, cfg, cache.layers[i])
            else:
                y, st = xlstm.mlstm_decode(bp["mix"], h, cfg, cache.layers[i])
            x = x + y
            new_states.append(st)
        new_sc = ServeCache(new_states, None)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(params["head"], params["embed"], x, cfg)
    return logits, new_sc


def _zamba_decode(params, x, cache: ServeCache, cfg):
    n_full, per, rem = _zamba_groups(cfg)

    def body(carry, xs):
        h = carry
        layer_p, ln, st = xs
        y, st2 = mamba2.mamba2_decode(layer_p, rms_norm(h, ln, cfg.norm_eps),
                                      cfg, st)
        return h + y, st2

    new_m, new_a = [], []
    for g in range(n_full):
        xs = (jax.tree.map(lambda a: a[g * per:(g + 1) * per], params["blocks"]),
              params["mamba_ln"][g * per:(g + 1) * per], cache.layers[g])
        x, sts = jax.lax.scan(body, x, xs)
        new_m.append(sts)
        x, ac = _tblock_decode(params["shared_attn"], x, cache.extra[g], cfg)
        new_a.append(ac)
    if rem:
        xs = (jax.tree.map(lambda a: a[-rem:], params["blocks"]),
              params["mamba_ln"][-rem:], cache.layers[-1])
        x, sts = jax.lax.scan(body, x, xs)
        new_m.append(sts)
    return x, ServeCache(new_m, new_a)


# ---------------------------------------------------------------------------
# cache constructors (decode-from-scratch path used by the dry-run)
# ---------------------------------------------------------------------------


def fresh_cache(cfg: ModelConfig, batch: int, max_seq: int) -> ServeCache:
    """A cache as it would exist after prefilling ``max_seq`` tokens."""
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        one = attn.init_cache(cfg, batch, max_seq)
        layers = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)
        layers = layers._replace(
            pos=jnp.full((cfg.n_layers, batch), max_seq, jnp.int32))
        return ServeCache(layers, None)
    if cfg.family == "hybrid":
        n_full, per, rem = _zamba_groups(cfg)
        m_states, a_caches = [], []
        for g in range(n_full):
            st = mamba2.init_mamba_state(cfg, batch)
            m_states.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (per,) + a.shape), st))
            ac = attn.init_cache(cfg, batch, max_seq)
            a_caches.append(ac._replace(
                pos=jnp.full((batch,), max_seq, jnp.int32)))
        if rem:
            st = mamba2.init_mamba_state(cfg, batch)
            m_states.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (rem,) + a.shape), st))
        return ServeCache(m_states, a_caches)
    if cfg.family == "ssm":
        states = []
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                states.append(xlstm.init_slstm_state(cfg, batch))
            else:
                states.append(xlstm.init_mlstm_state(cfg, batch))
        return ServeCache(states, None)
    raise ValueError(cfg.family)


def init_abstract(cfg: ModelConfig):
    """(abstract params, axes) with no allocation (eval_shape + static
    side-channel for the string-leaved axes tree)."""
    holder = {}

    def only_params(k):
        p, ax = init(k, cfg)
        holder["axes"] = ax
        return p

    params_abs = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return params_abs, holder["axes"]
