"""Admission control: per-tenant row arenas, bounded queues, shedding.

A multi-tenant PUD service has two scarce resources: *subarray rows*
(every queued request will need operand/destination rows in some
session's subarray image) and *queue depth* (unbounded queues turn
overload into unbounded latency).  Admission charges both up front:

* each tenant owns a :class:`TenantArena` — a row budget enforced by a
  capacity-checked :class:`~repro.session.rows.RowAllocator` whose
  reservations are released when the request completes (the allocator's
  free list is what lets a bounded budget admit an unbounded stream);
* queue depth is bounded globally and per tenant; a full queue is
  *backpressure* — :meth:`AdmissionController.admit` raises
  :class:`QueueFullError` and the caller either retries, waits, or
  surfaces the rejection to its own client.

Load-shedding is the third mechanism and happens at the *other* end of
the queue: the batching tick drops requests whose deadline has already
passed (:class:`DeadlineExceededError`), spending dispatch budget only
on work that can still meet its SLO.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serve.queue import PudRequest, RequestQueue, ServeError
from repro.session.rows import PlaneGroup, RowAllocationError, RowAllocator


class AdmissionError(ServeError):
    """Request rejected at admission (backpressure)."""


class QueueFullError(AdmissionError):
    """Global or per-tenant queue depth bound hit."""


class ArenaExhaustedError(AdmissionError):
    """The tenant's subarray-row budget cannot hold the request."""


class DeadlineExceededError(ServeError):
    """Request load-shed: its deadline passed while it was queued."""


@dataclasses.dataclass
class TenantStats:
    """Per-tenant accounting, exposed in the SLO snapshot."""

    submitted: int = 0
    completed: int = 0
    shed: int = 0
    rejected: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class TenantArena:
    """One tenant's subarray-row budget.

    Rows are reserved through a capacity-checked
    :class:`~repro.session.rows.RowAllocator` (the same build-time
    budget mechanism session programs use) and freed on completion.
    The arena's handles are accounting tokens — the batcher lays out
    each tick's actual subarray image with its own per-program
    allocator — so a stale arena handle can never alias an executing
    row.
    """

    def __init__(self, tenant: str, row_budget: int):
        self.tenant = tenant
        self.allocator = RowAllocator(row_budget,
                                      name=f"arena[{tenant}]")
        self.stats = TenantStats()

    @property
    def rows_in_use(self) -> int:
        return self.allocator.in_use

    def reserve(self, req: PudRequest) -> PlaneGroup:
        try:
            return self.allocator.alloc(
                max(req.rows_needed(), 1), tag=f"req[{req.rid}]")
        except RowAllocationError as e:
            raise ArenaExhaustedError(
                f"tenant {self.tenant!r}: {e} — request needs "
                f"{req.rows_needed()} rows") from e

    def release(self, reservation: PlaneGroup) -> None:
        self.allocator.free(reservation)


class AdmissionController:
    """Admit-or-reject gate in front of the request queue.

    ``admit`` validates depth bounds and reserves arena rows; it
    returns the reservation the service must hand back through
    ``release`` when the request completes (or is shed).  Unknown
    tenants get an arena lazily with the default row budget.
    """

    def __init__(self, queue: RequestQueue, *, tenant_rows: int = 4096,
                 tenant_queue_depth: Optional[int] = None):
        self.queue = queue
        self.tenant_rows = tenant_rows
        self.tenant_queue_depth = tenant_queue_depth
        self.arenas: dict[str, TenantArena] = {}

    def arena(self, tenant: str) -> TenantArena:
        if tenant not in self.arenas:
            self.arenas[tenant] = TenantArena(tenant, self.tenant_rows)
        return self.arenas[tenant]

    def admit(self, req: PudRequest) -> PlaneGroup:
        arena = self.arena(req.tenant)
        arena.stats.submitted += 1
        if self.queue.full:
            arena.stats.rejected += 1
            raise QueueFullError(
                f"service queue full ({self.queue.max_depth} requests); "
                f"request {req.rid} from tenant {req.tenant!r} rejected")
        depth_cap = self.tenant_queue_depth
        if depth_cap is not None and \
                self.queue.tenant_depth(req.tenant) >= depth_cap:
            arena.stats.rejected += 1
            raise QueueFullError(
                f"tenant {req.tenant!r} queue depth cap ({depth_cap}) "
                f"hit; request {req.rid} rejected")
        try:
            return arena.reserve(req)
        except ArenaExhaustedError:
            arena.stats.rejected += 1
            raise

    def release(self, req: PudRequest, reservation: PlaneGroup, *,
                shed: bool = False) -> None:
        arena = self.arena(req.tenant)
        arena.release(reservation)
        if shed:
            arena.stats.shed += 1
        else:
            arena.stats.completed += 1

    def tenant_snapshot(self) -> dict[str, dict]:
        return {t: {"rows_in_use": a.rows_in_use,
                    "row_budget": a.allocator.capacity,
                    **a.stats.to_dict()}
                for t, a in sorted(self.arenas.items())}
