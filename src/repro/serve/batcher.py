"""Continuous batching: coalesce a tick's requests into fused Programs.

The schedule :class:`~repro.session.cache.CompileCache` already makes a
*repeated* program shape nearly free; this module makes *concurrent*
requests share one program in the first place — the Orca/vLLM
continuous-batching idea applied to fused PUD programs.  Per batching
tick, requests with equal :meth:`~repro.serve.queue.PudRequest.
coalesce_key` merge into ONE addressed Program built through the typed
:class:`~repro.session.builder.SessionProgram`:

* **heal** — every request's replica tiles concatenate row-wise into X
  input groups; one MAJ per row-image votes into a shared output group.
  All ops are independent, so the schedule is a single level and the
  ``pallas`` backend executes N tenants' votes as ONE batched MAJX
  dispatch.
* **erase** — one WR'd pattern row fans out in Multi-RowCopy waves over
  every request's rows; again a single level, one fused MRC dispatch.
* **verify** — ``mismatch`` is a scalar reduction per request (no
  per-request split of a fused result), so integrity checks share the
  tick and session but execute one bulk op each.

Coalesced execution is bit-exact with per-request execution on every
backend (tests/test_serve_service.py proves it oracle/sim/pallas), so
batching is purely a throughput/dispatch-count optimization — under a
steady request mix the coalesced program repeats shape tick over tick
and the schedule cache makes it 1 miss + N-1 hits.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import calibration as cal
from repro.serve.queue import (EraseRequest, EraseResult, HealRequest,
                               HealResult, IntegrityRequest, IntegrityResult,
                               PudRequest)


@dataclasses.dataclass
class BatchPlan:
    """One coalesced group: requests sharing a fused Program this tick."""

    key: tuple
    requests: list[PudRequest]

    @property
    def kind(self) -> str:
        return self.key[0]

    def __len__(self) -> int:
        return len(self.requests)


@dataclasses.dataclass
class BatchOutcome:
    """Execution record of one plan: per-request results + metadata."""

    plan: BatchPlan
    results: list
    n_ops: int          # fused Program size (0 for direct bulk ops)
    n_levels: int       # schedule depth (0 for direct bulk ops)


class Batcher:
    """Groups a tick's drained requests and executes each group.

    ``coalesce=False`` degrades every group to a single request — the
    sequential baseline the serve bench compares against; the programs
    built either way are identical in semantics, so the comparison
    isolates the batching win.
    """

    def __init__(self, coalesce: bool = True):
        self.coalesce = coalesce

    # ------------------------------------------------------------- planning
    def plan(self, requests: list[PudRequest]) -> list[BatchPlan]:
        """Group by coalesce key, preserving first-arrival order."""
        if not self.coalesce:
            return [BatchPlan(r.coalesce_key(), [r]) for r in requests]
        groups: dict[tuple, BatchPlan] = {}
        for req in requests:
            key = req.coalesce_key()
            if key not in groups:
                groups[key] = BatchPlan(key, [])
            groups[key].requests.append(req)
        return list(groups.values())

    # ------------------------------------------------------------ execution
    def execute(self, plan: BatchPlan, session) -> BatchOutcome:
        """Run one plan on ``session`` (synchronous, fused, cached)."""
        if plan.kind == "heal":
            return self._execute_heal(plan, session)
        if plan.kind == "erase":
            return self._execute_erase(plan, session)
        if plan.kind == "verify":
            return self._execute_verify(plan, session)
        raise ValueError(f"unknown batch kind {plan.kind!r}")

    def _execute_heal(self, plan: BatchPlan, session) -> BatchOutcome:
        from repro.pud.offload import plan_program

        reqs: list[HealRequest] = plan.requests
        _, x, words, n_act = plan.key
        n_act = cal.min_activation_for(
            max(n_act or max(cal.N_ACT_LEVELS), x))
        row_counts = [r.rows for r in reqs]
        total = sum(row_counts)
        b = session.program(rows=(x + 1) * total,
                            name=f"serve/heal-x{x}")
        groups = [
            b.input(np.concatenate([r.replicas[j] for r in reqs]),
                    tag=f"serve/heal/replica[{j}]")
            for j in range(x)
        ]
        out = b.alloc_rows(total, tag="serve/heal/voted")
        for r in range(total):
            b.maj(*(g[r] for g in groups), dst=out[r], n_act=n_act,
                  tag=f"serve/heal/row[{r}]")
        prog = b.build()
        final = session.run_fused(prog, b.initial_state())
        voted = np.asarray(final)[np.asarray(out.indices)]
        sched = session.schedule_for(prog)  # cache hit, not a re-leveling
        decision = plan_program(prog, words * 4, ctx=session.ctx,
                                sched=sched)
        results, off = [], 0
        for req, rows in zip(reqs, row_counts):
            tile = voted[off:off + rows]
            off += rows
            fixed = int(session.mismatch(req.replicas[0], tile))
            results.append(HealResult(healed=tile, fixed_bits=fixed,
                                      decision=decision))
        return BatchOutcome(plan, results, n_ops=len(prog.ops),
                            n_levels=sched.n_levels)

    def _execute_erase(self, plan: BatchPlan, session) -> BatchOutcome:
        reqs: list[EraseRequest] = plan.requests
        _, words, pattern, fanout = plan.key
        total = sum(r.rows for r in reqs)
        b = session.program(rows=total + 1, name=f"serve/erase-f{fanout}")
        src = b.input(np.full(words, pattern, np.uint32),
                      tag="serve/erase/pattern")
        dsts = b.alloc_rows(total, tag="serve/erase/wiped")
        for lo in range(0, total, fanout):
            b.mrc(src, dsts[lo:lo + fanout],
                  tag=f"serve/erase/wave[{lo // fanout}]")
        prog = b.build()
        final = session.run_fused(prog, b.initial_state())
        wiped = np.asarray(final)[np.asarray(dsts.indices)]
        results, off = [], 0
        for req in reqs:
            results.append(EraseResult(wiped=wiped[off:off + req.rows]))
            off += req.rows
        return BatchOutcome(plan, results, n_ops=len(prog.ops),
                            n_levels=session.schedule_for(prog).n_levels)

    def _execute_verify(self, plan: BatchPlan, session) -> BatchOutcome:
        results = []
        for req in plan.requests:
            assert isinstance(req, IntegrityRequest)
            bad = int(session.mismatch(req.live, req.reference))
            results.append(IntegrityResult(
                mismatch_bits=bad, total_bits=int(req.live.size) * 32))
        return BatchOutcome(plan, results, n_ops=0, n_levels=0)
