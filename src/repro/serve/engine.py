"""Serving engine: continuous batching over prefill/decode steps.

A fixed-width decode batch of ``slots``; finished sequences free their slot
and queued requests are prefilled into it (continuous batching a la Orca /
vLLM).  Greedy or temperature sampling.  All model math lives in
repro.models.model; the engine is pure scheduling.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Single-slot-group engine (one jitted decode fn, batch = n slots)."""

    def __init__(self, params, cfg: ModelConfig, max_seq: int = 256,
                 greedy: bool = True, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c: M.decode(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, max_seq))

    def _sample(self, logits) -> np.ndarray:
        lg = np.asarray(logits.astype(jnp.float32))
        if self.cfg.family == "audio":
            return lg.argmax(-1)[:, 0]     # (B, CB)
        return lg.argmax(-1)[:, 0]         # (B,)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with continuous batching."""
        queue = list(requests)
        active: list[Request] = []
        cache = None
        while queue or active:
            # (re)fill the batch: group requests with equal prompt lengths
            # into one prefill (static-shape jit); simple policy: batch all
            # queued requests of the most common length.
            if not active and queue:
                lens = [len(r.prompt) for r in queue]
                target = max(set(lens), key=lens.count)
                batch_reqs = [r for r in queue if len(r.prompt) == target]
                queue = [r for r in queue if len(r.prompt) != target]
                toks = jnp.asarray(np.stack([r.prompt for r in batch_reqs]))
                logits, cache = self._prefill(self.params, {"tokens": toks})
                first = self._sample(logits)
                for i, r in enumerate(batch_reqs):
                    r.out_tokens.append(first[i])
                active = batch_reqs
            # decode until every active request finishes
            while active and not all(r.done for r in active):
                last = np.stack([r.out_tokens[-1] for r in active])
                if self.cfg.family == "audio":
                    toks = jnp.asarray(last.reshape(len(active), 1, -1))
                else:
                    toks = jnp.asarray(last.reshape(len(active), 1))
                logits, cache = self._decode(self.params, toks, cache)
                nxt = self._sample(logits)
                for i, r in enumerate(active):
                    if r.done:
                        continue
                    r.out_tokens.append(nxt[i])
                    tok_scalar = (int(np.asarray(nxt[i]).flat[0])
                                  if np.ndim(nxt[i]) else int(nxt[i]))
                    if (len(r.out_tokens) >= r.max_new_tokens
                            or (r.eos_id is not None
                                and tok_scalar == r.eos_id)):
                        r.done = True
            active = []
            cache = None
        return requests
