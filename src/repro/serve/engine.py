"""Serving engine: continuous batching over prefill/decode steps.

A fixed-width decode batch of ``slots``; finished sequences free their slot
and queued requests are prefilled into it (continuous batching a la Orca /
vLLM).  Greedy or temperature sampling.  All model math lives in
repro.models.model; the engine is pure scheduling.

PUD hooks: the engine carries a :class:`~repro.session.DramSession`
(backend is still a one-string choice) for in-memory integrity work — a
majority vote healing silent corruption across parameter replicas before
they serve traffic, with the offload planner recording where the vote
*would* run on PUD-capable memory (advisory on TPU-only deployments).
The session's compile cache makes repeated votes (every heal after the
first with the same parameter shapes) skip re-scheduling entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionContext
from repro.configs.base import ModelConfig
from repro.core import bitplanes as bp
from repro.models import model as M
from repro.session import DramSession


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Single-slot-group engine (one jitted decode fn, batch = n slots)."""

    def __init__(self, params, cfg: ModelConfig, max_seq: int = 256,
                 greedy: bool = True, seed: int = 0,
                 pud_backend: str = "pallas",
                 pud_ctx: Optional[ExecutionContext] = None):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        # Integrity votes must be error-free: default to an ideal context
        # so a stochastic backend ("sim") can't corrupt params it claims
        # to heal.  Pass a non-ideal pud_ctx explicitly only for fidelity
        # studies, never for a serving deployment.
        self.pud = DramSession(pud_backend,
                               pud_ctx or ExecutionContext(ideal=True),
                               name="serve-pud")
        self.pud_decisions: list = []
        self._decode = jax.jit(
            lambda p, t, c: M.decode(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, max_seq))

    # ------------------------------------------------------------ PUD hooks
    def heal_params(self, replicas: Sequence) -> int:
        """Majority-vote parameter replicas through the PUD backend.

        ``replicas``: >= 3 (odd) pytrees with the engine's param structure.
        Installs the healed params and returns the number of corrected
        bits.

        The whole vote is ONE addressed Program, built through the
        session's typed builder: every leaf's packed words are
        concatenated per replica and bound as input row groups, one MAJ
        op per row-image votes into an output group, and the program
        runs compile-cached through ``self.pud.run_fused`` — a
        single-level schedule the ``pallas`` backend executes as one
        batched MAJX dispatch, with repeat votes over the same shapes
        hitting the session's schedule cache.  The offload planner's
        verdict for the fused program is appended to
        ``self.pud_decisions`` (advisory: where the vote would run on
        PUD-capable memory).
        """
        from repro.core import calibration as cal
        from repro.kernels import tiling
        from repro.pud.offload import plan_program

        x = len(replicas)
        flats = [jax.tree.leaves(r) for r in replicas]
        treedef = jax.tree.structure(replicas[0])
        metas = []  # (n_words, shape, dtype) per leaf, for re-splitting
        for leaf in flats[0]:
            w, shape, dtype = bp.bitcast_to_planes(leaf)
            metas.append((int(w.size), shape, dtype))
        rep_words = [
            jnp.concatenate([bp.bitcast_to_planes(leaf)[0].reshape(-1)
                             for leaf in flat])
            for flat in flats
        ]
        total = int(rep_words[0].size)
        width = min(tiling.MAX_BLOCK_C, total)
        tiles = [tiling.words_to_rows(w, width) for w in rep_words]
        n_rows = tiles[0].shape[0]

        # One MAJ op per row-image; all ops are level 0 -> one dispatch.
        # Votes issue at the full 32-row activation (the §5 replication
        # ladder's best success rate — the same point plan_vote prices).
        b = self.pud.program(rows=(x + 1) * n_rows, name="heal-vote")
        groups = [b.input(tile, tag=f"heal/replica[{rep}]")
                  for rep, tile in enumerate(tiles)]
        out = b.alloc_rows(n_rows, tag="heal/voted")
        n_act = max(cal.N_ACT_LEVELS)
        for r in range(n_rows):
            b.maj(*(g[r] for g in groups), dst=out[r], n_act=n_act,
                  tag=f"heal/row[{r}]")
        prog = b.build()
        final = self.pud.run_fused(prog, b.initial_state())
        voted = final[np.asarray(out.indices)].reshape(-1)[:total]
        fixed_bits = int(self.pud.mismatch(rep_words[0], voted))

        healed_leaves, off = [], 0
        for n_words, shape, dtype in metas:
            healed_leaves.append(bp.bitcast_from_planes(
                voted[off:off + n_words], shape, dtype))
            off += n_words
        self.params = jax.tree.unflatten(treedef, healed_leaves)
        # The planner prices the same schedule the session just executed
        # (a cache hit, not a re-leveling).
        self.pud_decisions.append(
            plan_program(prog, width * 4, ctx=self.pud.ctx,
                         sched=self.pud.schedule_for(prog)))
        return fixed_bits

    def verify_params(self, reference) -> float:
        """Bit-level success rate of live params vs a reference pytree."""
        total_bits = bad = 0
        for a, b in zip(jax.tree.leaves(self.params),
                        jax.tree.leaves(reference)):
            wa, _, _ = bp.bitcast_to_planes(a)
            wb, _, _ = bp.bitcast_to_planes(b)
            bad += int(self.pud.mismatch(wa, wb))
            total_bits += int(wa.size) * 32
        return 1.0 - bad / max(total_bits, 1)

    # ------------------------------------------------------------ serving
    def _sample(self, logits) -> np.ndarray:
        lg = np.asarray(logits.astype(jnp.float32))
        if self.cfg.family == "audio":
            return lg.argmax(-1)[:, 0]     # (B, CB)
        return lg.argmax(-1)[:, 0]         # (B,)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with continuous batching."""
        queue = list(requests)
        active: list[Request] = []
        cache = None
        while queue or active:
            # (re)fill the batch: group requests with equal prompt lengths
            # into one prefill (static-shape jit); simple policy: batch all
            # queued requests of the most common length.
            if not active and queue:
                lens = [len(r.prompt) for r in queue]
                target = max(set(lens), key=lens.count)
                batch_reqs = [r for r in queue if len(r.prompt) == target]
                queue = [r for r in queue if len(r.prompt) != target]
                toks = jnp.asarray(np.stack([r.prompt for r in batch_reqs]))
                logits, cache = self._prefill(self.params, {"tokens": toks})
                first = self._sample(logits)
                for i, r in enumerate(batch_reqs):
                    r.out_tokens.append(first[i])
                active = batch_reqs
            # decode until every active request finishes
            while active and not all(r.done for r in active):
                last = np.stack([r.out_tokens[-1] for r in active])
                if self.cfg.family == "audio":
                    toks = jnp.asarray(last.reshape(len(active), 1, -1))
                else:
                    toks = jnp.asarray(last.reshape(len(active), 1))
                logits, cache = self._decode(self.params, toks, cache)
                nxt = self._sample(logits)
                for i, r in enumerate(active):
                    if r.done:
                        continue
                    r.out_tokens.append(nxt[i])
                    tok_scalar = (int(np.asarray(nxt[i]).flat[0])
                                  if np.ndim(nxt[i]) else int(nxt[i]))
                    if (len(r.out_tokens) >= r.max_new_tokens
                            or (r.eos_id is not None
                                and tok_scalar == r.eos_id)):
                        r.done = True
            active = []
            cache = None
        return requests
