"""Serving engine: continuous batching over prefill/decode steps.

A fixed-width decode batch of ``slots``; finished sequences free their slot
and queued requests are prefilled into it (continuous batching a la Orca /
vLLM).  Greedy or temperature sampling.  All model math lives in
repro.models.model; the engine is pure scheduling.

PUD hooks: the engine's integrity work (replica vote-healing and
bit-level verification) runs through a :class:`~repro.serve.service.
PudService` — the engine is a thin *client* submitting typed
:class:`~repro.serve.queue.HealRequest`/:class:`~repro.serve.queue.
IntegrityRequest` work, so engine votes share the service's session
pool, schedule cache, continuous batching, and SLO accounting with
every other tenant.  The offload planner's verdict (where the vote
*would* run on PUD-capable memory; advisory on TPU-only deployments)
rides back on each heal result.

Integrity votes must be error-free, so healing on a non-ideal
:class:`~repro.backends.context.ExecutionContext` (a stochastic backend
can corrupt the very bits it claims to heal) emits
:class:`IntegrityContextWarning` — or raises
:class:`IntegrityContextError` under ``strict_integrity=True``.
Non-ideal contexts are for fidelity studies, never serving deployments.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionContext
from repro.configs.base import ModelConfig
from repro.core import bitplanes as bp
from repro.models import model as M
from repro.serve.queue import HealRequest, IntegrityRequest, ServeError
from repro.serve.service import PudService, ServiceConfig


class IntegrityContextError(ServeError):
    """heal_params refused to run on a non-ideal context (strict mode)."""


class IntegrityContextWarning(UserWarning):
    """heal_params is running on a non-ideal (stochastic) context."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Single-slot-group engine (one jitted decode fn, batch = n slots)."""

    def __init__(self, params, cfg: ModelConfig, max_seq: int = 256,
                 greedy: bool = True, seed: int = 0,
                 pud_backend: str = "pallas",
                 pud_ctx: Optional[ExecutionContext] = None,
                 pud_service: Optional[PudService] = None,
                 strict_integrity: bool = False,
                 tenant: str = "engine"):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        # Integrity work runs through a PudService; pass a shared
        # ``pud_service`` to pool votes with other engines/tenants, or
        # let the engine own a single-session service.  The service
        # defaults to an ideal context (see module docstring).
        self.service = pud_service or PudService(ServiceConfig(
            backend=pud_backend,
            ctx=pud_ctx or ExecutionContext(ideal=True), pool_size=1))
        self.strict_integrity = strict_integrity
        self.tenant = tenant
        #: Compat: the first pooled session still answers the whole
        #: Backend surface (examples introspect ``engine.pud.ctx`` etc.).
        self.pud = self.service.sessions[0]
        self.pud_decisions: list = []
        self._decode = jax.jit(
            lambda p, t, c: M.decode(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, max_seq))

    # ------------------------------------------------------------ PUD hooks
    def _check_integrity_ctx(self) -> None:
        """Enforce the ideal-context-by-default healing rule.

        Warns on a non-ideal context; raises under ``strict_integrity``.
        """
        if self.service.ctx.ideal:
            return
        msg = (f"heal_params is running on a non-ideal ExecutionContext "
               f"(mfr={self.service.ctx.mfr!r}, ideal=False): a "
               f"stochastic backend can corrupt the very bits it claims "
               f"to heal. Use ExecutionContext(ideal=True) for serving; "
               f"non-ideal contexts are for fidelity studies only.")
        if self.strict_integrity:
            raise IntegrityContextError(msg)
        warnings.warn(msg, IntegrityContextWarning, stacklevel=3)

    def _pack_pytree(self, tree):
        """Pytree -> ((rows, width) tile, metas, total_words, width)."""
        from repro.kernels import tiling

        metas = []  # (n_words, shape, dtype) per leaf, for re-splitting
        for leaf in jax.tree.leaves(tree):
            w, shape, dtype = bp.bitcast_to_planes(leaf)
            metas.append((int(w.size), shape, dtype))
        words = jnp.concatenate([bp.bitcast_to_planes(leaf)[0].reshape(-1)
                                 for leaf in jax.tree.leaves(tree)])
        total = int(words.size)
        width = min(tiling.MAX_BLOCK_C, total)
        return np.asarray(tiling.words_to_rows(words, width)), metas, \
            total, width

    def heal_params(self, replicas: Sequence) -> int:
        """Majority-vote parameter replicas through the PUD service.

        ``replicas``: >= 3 (odd) pytrees with the engine's param
        structure.  Installs the healed params and returns the number
        of corrected bits.

        The engine is a thin client: every replica's packed words
        become one tile of a single typed
        :class:`~repro.serve.queue.HealRequest`, and the service's
        batcher lowers it (coalesced with any concurrent tenants'
        same-shape votes) to ONE single-level fused Program — one
        batched MAJX dispatch on the ``pallas`` backend, schedule
        -cached across repeat votes.  The offload planner's verdict for
        the fused program is appended to ``self.pud_decisions``
        (advisory: where the vote would run on PUD-capable memory).
        """
        self._check_integrity_ctx()
        tiles, metas, total, _ = self._pack_pytree(replicas[0])
        rep_tiles = [tiles] + [self._pack_pytree(r)[0]
                               for r in replicas[1:]]
        [result] = self.service.serve([HealRequest(
            replicas=np.stack(rep_tiles), tenant=self.tenant)])
        voted = result.healed.reshape(-1)[:total]

        healed_leaves, off = [], 0
        treedef = jax.tree.structure(replicas[0])
        for n_words, shape, dtype in metas:
            healed_leaves.append(bp.bitcast_from_planes(
                jnp.asarray(voted[off:off + n_words]), shape, dtype))
            off += n_words
        self.params = jax.tree.unflatten(treedef, healed_leaves)
        self.pud_decisions.append(result.decision)
        return result.fixed_bits

    def verify_params(self, reference) -> float:
        """Bit-level success rate of live params vs a reference pytree.

        One typed :class:`~repro.serve.queue.IntegrityRequest` through
        the service (the tiles' zero padding matches on both sides, so
        the packed comparison equals the per-leaf one; the rate is
        normalized by the real parameter bits, not the padding).
        """
        live, _, total, _ = self._pack_pytree(self.params)
        ref, _, _, _ = self._pack_pytree(reference)
        [result] = self.service.serve([IntegrityRequest(
            live=live, reference=ref, tenant=self.tenant)])
        return 1.0 - result.mismatch_bits / max(total * 32, 1)

    # ------------------------------------------------------------ serving
    def _sample(self, logits) -> np.ndarray:
        lg = np.asarray(logits.astype(jnp.float32))
        if self.cfg.family == "audio":
            return lg.argmax(-1)[:, 0]     # (B, CB)
        return lg.argmax(-1)[:, 0]         # (B,)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve a list of requests with continuous batching."""
        queue = list(requests)
        active: list[Request] = []
        cache = None
        while queue or active:
            # (re)fill the batch: group requests with equal prompt lengths
            # into one prefill (static-shape jit); simple policy: batch all
            # queued requests of the most common length.
            if not active and queue:
                lens = [len(r.prompt) for r in queue]
                target = max(set(lens), key=lens.count)
                batch_reqs = [r for r in queue if len(r.prompt) == target]
                queue = [r for r in queue if len(r.prompt) != target]
                toks = jnp.asarray(np.stack([r.prompt for r in batch_reqs]))
                logits, cache = self._prefill(self.params, {"tokens": toks})
                first = self._sample(logits)
                for i, r in enumerate(batch_reqs):
                    r.out_tokens.append(first[i])
                active = batch_reqs
            # decode until every active request finishes
            while active and not all(r.done for r in active):
                last = np.stack([r.out_tokens[-1] for r in active])
                if self.cfg.family == "audio":
                    toks = jnp.asarray(last.reshape(len(active), 1, -1))
                else:
                    toks = jnp.asarray(last.reshape(len(active), 1))
                logits, cache = self._decode(self.params, toks, cache)
                nxt = self._sample(logits)
                for i, r in enumerate(active):
                    if r.done:
                        continue
                    r.out_tokens.append(nxt[i])
                    tok_scalar = (int(np.asarray(nxt[i]).flat[0])
                                  if np.ndim(nxt[i]) else int(nxt[i]))
                    if (len(r.out_tokens) >= r.max_new_tokens
                            or (r.eos_id is not None
                                and tok_scalar == r.eos_id)):
                        r.done = True
            active = []
            cache = None
        return requests
