"""SLO observability: per-request traces, rolling percentiles, snapshots.

Serving is only as good as what it can prove about itself: the service
records a :class:`RequestTrace` of spans per request (queued ->
admitted -> batched -> executed) and the :class:`SloMonitor` folds
completions into rolling windows — p50/p99 latency, throughput, batch
occupancy, fused-dispatch counts — plus the schedule-cache hit rate
(windowed via :meth:`~repro.session.cache.CacheStats.delta`) and a
per-session :class:`~repro.ft.straggler.StragglerDetector` (one
"worker" per pooled ``DramSession``) that flags persistently slow
sessions exactly as the trainer flags slow SPMD workers.

:meth:`SloMonitor.snapshot` freezes everything into a structured
:class:`SloSnapshot` — the schema ``BENCH_serve.json`` embeds and
``docs/SERVING.md`` documents.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from repro.ft.straggler import StragglerDetector
from repro.session.cache import CacheStats


def _percentile(window, p: float) -> Optional[float]:
    if not window:
        return None
    return float(np.percentile(np.asarray(window, float), p))


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed stage of a request's lifecycle."""

    name: str
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclasses.dataclass
class RequestTrace:
    """Per-request span log (monotonic-clock timestamps)."""

    rid: int
    tenant: str
    kind: str
    created_at: float = dataclasses.field(default_factory=time.monotonic)
    spans: list[Span] = dataclasses.field(default_factory=list)
    _open: dict[str, float] = dataclasses.field(default_factory=dict,
                                                repr=False)

    def begin(self, name: str) -> None:
        self._open[name] = time.monotonic()

    def end(self, name: str) -> None:
        start = self._open.pop(name, self.created_at)
        self.spans.append(Span(name, start, time.monotonic()))

    @property
    def latency_s(self) -> float:
        """created -> end of the last closed span."""
        if not self.spans:
            return 0.0
        return max(s.end_s for s in self.spans) - self.created_at

    def to_dict(self) -> dict:
        return {"rid": self.rid, "tenant": self.tenant, "kind": self.kind,
                "latency_s": self.latency_s,
                "spans": [{"name": s.name,
                           "duration_s": s.duration_s}
                          for s in self.spans]}


@dataclasses.dataclass(frozen=True)
class SloSnapshot:
    """Frozen view of the service's SLO counters (see module docstring)."""

    completed: int
    shed: int
    rejected: int
    batches: int
    dispatches: int
    #: CostModel-priced energy (nJ) the executing backends accrued across
    #: all recorded batches (kernel launches + HBM traffic on pallas;
    #: per-DRAM-command Fig. 5 energy on sim; 0 on oracle).
    energy_nj: float
    p50_latency_s: Optional[float]
    p99_latency_s: Optional[float]
    throughput_rps: float
    batch_occupancy: Optional[float]     # mean requests per executed batch
    cache: dict                          # {hits, misses, hit_rate} window
    tenants: dict
    slow_sessions: list[int]
    session_ema_s: list[float]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SloMonitor:
    """Rolling SLO accounting for one :class:`PudService` (not
    thread-safe by itself — the service mutates it from its event loop
    only)."""

    def __init__(self, n_sessions: int, window: int = 512):
        self._n_sessions = max(n_sessions, 1)
        self._window = window
        self.reset()

    def reset(self, cache_stats: Optional[CacheStats] = None) -> None:
        """Zero every counter/window (bench warm-up exclusion).

        Passing the live cache stats also rebases the hit-rate window;
        the straggler EMAs restart cold.
        """
        self.started_at = time.monotonic()
        self.completed = 0
        self.shed = 0
        self.rejected = 0
        self.batches = 0
        self.dispatches = 0
        self.energy_nj = 0.0
        self._latencies = collections.deque(maxlen=self._window)
        self._occupancy = collections.deque(maxlen=self._window)
        self.stragglers = StragglerDetector(n_workers=self._n_sessions)
        self._cache_mark = (cache_stats.snapshot() if cache_stats
                            else CacheStats())

    # ------------------------------------------------------------- recording
    def record_completion(self, trace: RequestTrace) -> None:
        self.completed += 1
        self._latencies.append(trace.latency_s)

    def record_shed(self) -> None:
        self.shed += 1

    def record_rejected(self) -> None:
        self.rejected += 1

    def record_batch(self, n_requests: int, wall_s: float,
                     dispatches: int, session_idx: int,
                     energy_nj: float = 0.0) -> None:
        self.batches += 1
        self.dispatches += dispatches
        self.energy_nj += energy_nj
        self._occupancy.append(float(n_requests))
        self.stragglers.record(session_idx, max(wall_s, 1e-9))

    # ------------------------------------------------------------- snapshot
    def snapshot(self, cache_stats: CacheStats,
                 tenants: Optional[dict] = None) -> SloSnapshot:
        elapsed = max(time.monotonic() - self.started_at, 1e-9)
        window = cache_stats.delta(self._cache_mark)
        return SloSnapshot(
            completed=self.completed,
            shed=self.shed,
            rejected=self.rejected,
            batches=self.batches,
            dispatches=self.dispatches,
            energy_nj=self.energy_nj,
            p50_latency_s=_percentile(self._latencies, 50),
            p99_latency_s=_percentile(self._latencies, 99),
            throughput_rps=self.completed / elapsed,
            batch_occupancy=(float(np.mean(self._occupancy))
                             if self._occupancy else None),
            cache={"hits": window.hits, "misses": window.misses,
                   "hit_rate": window.hit_rate},
            tenants=tenants or {},
            slow_sessions=self.stragglers.stragglers(),
            session_ema_s=[float(e) for e in self.stragglers.ema],
        )

    def rebase_cache_window(self, cache_stats: CacheStats) -> None:
        """Start a fresh cache-hit-rate window at the current counters."""
        self._cache_mark = cache_stats.snapshot()
