"""PudService: the multi-tenant continuous-batching PUD engine.

One service owns everything a production integrity/erase workload
needs, end to end:

* a **pool of sessions** — ``pool_size`` :class:`~repro.session.
  DramSession`\\ s over one backend choice, all sharing ONE
  :class:`~repro.session.cache.CompileCache` (a schedule is a pure
  content function, so every pooled session benefits from every other
  session's compiles);
* an **async request queue** — typed requests (:mod:`repro.serve.
  queue`) admitted through per-tenant row arenas and bounded-depth
  backpressure (:mod:`repro.serve.admission`);
* **continuous batching** — each tick drains the queue in priority
  order, load-sheds past-deadline work, and coalesces same-shape
  requests into one fused Program per group (:mod:`repro.serve.
  batcher`), so N tenants' votes cost one schedule-cache lookup and one
  batched dispatch set;
* **SLO observability** — per-request traces and a rolling
  :class:`~repro.serve.slo.SloMonitor` snapshot (latency percentiles,
  throughput, occupancy, cache hit rate, straggler sessions).

Two client styles share one engine:

>>> svc = PudService(ServiceConfig(backend="pallas", pool_size=2))
>>> [res] = svc.serve([HealRequest(replicas=tiles)])   # sync clients
>>> async def client():                                # async clients
...     await svc.start()
...     res = await svc.submit(HealRequest(replicas=tiles))
...     await svc.stop()

The serve engine's ``heal_params`` / ``verify_params``
(:mod:`repro.serve.engine`) are thin sync clients of this service, so
the whole integrity workload runs through one engine.
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import Callable, Optional, Union

from repro.backends import Backend, ExecutionContext
from repro.serve.admission import (AdmissionController, AdmissionError,
                                   DeadlineExceededError)
from repro.serve.batcher import Batcher
from repro.serve.queue import PudRequest, RequestQueue
from repro.serve.slo import RequestTrace, SloMonitor, SloSnapshot
from repro.session import CompileCache, DramSession


@dataclasses.dataclass
class ServiceConfig:
    """Service-level knobs (execution-regime knobs stay in ``ctx``).

    ``ctx`` defaults to an *ideal* context: integrity votes must be
    error-free, so a stochastic backend may only be configured
    explicitly (fidelity studies), mirroring the serve engine's rule.
    """

    backend: Union[str, Backend] = "pallas"
    ctx: Optional[ExecutionContext] = None
    pool_size: int = 2
    max_batch: int = 64           # requests drained per tick
    coalesce: bool = True         # False = sequential baseline
    queue_depth: int = 256        # global backpressure bound
    tenant_queue_depth: Optional[int] = None
    tenant_rows: int = 4096       # per-tenant arena row budget
    tick_window_s: float = 0.0    # extra coalescing wait before ticking
                                  # (honored by serve() and the async loop)
    shed_late: bool = True        # drop past-deadline work at tick time
    latency_window: int = 512     # rolling SLO window (completions)


@dataclasses.dataclass
class _Pending:
    req: PudRequest
    reservation: object
    trace: RequestTrace
    deliver: Callable[[object, Optional[BaseException]], None]


class PudService:
    """See module docstring.  Single-threaded: ticks run either inline
    (:meth:`serve`, :meth:`tick`) or on the asyncio event loop
    (:meth:`start` / :meth:`submit`); the shared compile cache is the
    one structure that is also safe under true thread concurrency."""

    def __init__(self, cfg: Optional[ServiceConfig] = None, *,
                 cache: Optional[CompileCache] = None):
        self.cfg = cfg or ServiceConfig()
        ctx = self.cfg.ctx or ExecutionContext(ideal=True)
        self.cache = cache if cache is not None else CompileCache()
        self.sessions = [
            DramSession(self.cfg.backend, ctx, cache=self.cache,
                        name=f"serve-pud[{i}]")
            for i in range(max(self.cfg.pool_size, 1))
        ]
        self.queue = RequestQueue(self.cfg.queue_depth)
        self.admission = AdmissionController(
            self.queue, tenant_rows=self.cfg.tenant_rows,
            tenant_queue_depth=self.cfg.tenant_queue_depth)
        self.batcher = Batcher(self.cfg.coalesce)
        self.slo = SloMonitor(len(self.sessions),
                              window=self.cfg.latency_window)
        self._pending: dict[int, _Pending] = {}
        self._rid = itertools.count()
        self._rr = 0
        self._task: Optional[asyncio.Task] = None
        self._wakeup: Optional[asyncio.Event] = None
        self._running = False

    @property
    def ctx(self) -> ExecutionContext:
        return self.sessions[0].ctx

    # ------------------------------------------------------------ admission
    def _enqueue(self, req: PudRequest,
                 deliver: Callable[[object, Optional[BaseException]], None]
                 ) -> int:
        """Admit + queue one request; raises AdmissionError on rejection."""
        req.rid = next(self._rid)
        req.submitted_at = time.monotonic()
        if req.deadline_s is not None:
            req.deadline_at = req.submitted_at + req.deadline_s
        trace = RequestTrace(req.rid, req.tenant, req.kind)
        trace.begin("queued")
        try:
            reservation = self.admission.admit(req)
        except AdmissionError:
            self.slo.record_rejected()
            raise
        self.queue.push(req)
        self._pending[req.rid] = _Pending(req, reservation, trace, deliver)
        return req.rid

    # ------------------------------------------------------------- batching
    def tick(self) -> int:
        """One batching tick: drain -> shed -> coalesce -> execute.

        Synchronous (the async loop calls it too); returns completions.
        """
        drained = self.queue.drain(self.cfg.max_batch)
        now = time.monotonic()
        live: list[_Pending] = []
        for req in drained:
            pend = self._pending.pop(req.rid)
            pend.trace.end("queued")
            if (self.cfg.shed_late and req.deadline_at is not None
                    and now > req.deadline_at):
                self.admission.release(req, pend.reservation, shed=True)
                self.slo.record_shed()
                pend.deliver(None, DeadlineExceededError(
                    f"request {req.rid} (tenant {req.tenant!r}) shed: "
                    f"deadline passed {now - req.deadline_at:.3f}s before "
                    f"its batching tick"))
                continue
            live.append(pend)
        by_rid = {p.req.rid: p for p in live}
        completed = 0
        for plan in self.batcher.plan([p.req for p in live]):
            idx = self._rr % len(self.sessions)
            self._rr += 1
            session = self.sessions[idx]
            for req in plan.requests:
                by_rid[req.rid].trace.begin("execute")
            t0 = time.perf_counter()
            with session.count_dispatches() as scope:
                outcome = self.batcher.execute(plan, session)
            wall = time.perf_counter() - t0
            self.slo.record_batch(len(plan), wall, scope.count, idx,
                                  energy_nj=scope.energy_nj)
            for req, result in zip(plan.requests, outcome.results):
                pend = by_rid[req.rid]
                pend.trace.end("execute")
                self.admission.release(req, pend.reservation)
                self.slo.record_completion(pend.trace)
                pend.deliver(result, None)
                completed += 1
        return completed

    @property
    def backlog(self) -> int:
        return len(self.queue)

    def snapshot(self) -> SloSnapshot:
        """Structured SLO snapshot (schema in ``docs/SERVING.md``)."""
        return self.slo.snapshot(self.cache.stats,
                                 tenants=self.admission.tenant_snapshot())

    def reset_slo(self) -> None:
        """Restart SLO windows at now (bench warm-up exclusion); the
        cache-hit window rebases to the cache's current counters."""
        self.slo.reset(self.cache.stats)

    # ------------------------------------------------------------- sync API
    def serve(self, requests: list[PudRequest]) -> list:
        """Admit all, tick until drained, return per-request results.

        Results align with ``requests``; a load-shed request's slot
        holds its :class:`DeadlineExceededError` instance (the
        ``asyncio.gather(return_exceptions=True)`` convention).
        Admission rejections raise immediately — backpressure is the
        caller's to handle.

        Honors ``cfg.tick_window_s`` exactly like the async loop: one
        coalescing wait after admission, before the batching ticks —
        giving co-submitted work from other threads the same window to
        land in the queue and coalesce (not one wait per tick, which
        would scale the wall time with the drain length).
        """
        slots: dict[int, object] = {}

        def deliver_to(i):
            def deliver(value, error=None):
                slots[i] = error if error is not None else value
            return deliver

        for i, req in enumerate(requests):
            self._enqueue(req, deliver_to(i))
        if self.cfg.tick_window_s:
            time.sleep(self.cfg.tick_window_s)
        while self.backlog:
            self.tick()
        return [slots[i] for i in range(len(requests))]

    # ------------------------------------------------------------ async API
    async def start(self) -> None:
        """Start the continuous-batching loop on the running event loop."""
        if self._running:
            return
        self._running = True
        self._wakeup = asyncio.Event()
        self._task = asyncio.create_task(self._loop())

    async def stop(self) -> None:
        """Drain the queue, then stop the loop."""
        if not self._running:
            return
        self._running = False
        self._wakeup.set()
        await self._task
        self._task = None

    async def submit(self, req: PudRequest):
        """Admit one request and await its result.

        Raises :class:`~repro.serve.admission.AdmissionError` on
        backpressure and :class:`DeadlineExceededError` if the request
        is shed before execution.
        """
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()

        def deliver(value, error=None):
            if fut.cancelled():
                return
            if error is not None:
                fut.set_exception(error)
            else:
                fut.set_result(value)

        self._enqueue(req, deliver)
        if self._wakeup is not None:
            self._wakeup.set()
        return await fut

    async def _loop(self) -> None:
        while True:
            await self._wakeup.wait()
            self._wakeup.clear()
            if self.cfg.tick_window_s:
                await asyncio.sleep(self.cfg.tick_window_s)
            while self.backlog:
                self.tick()
                await asyncio.sleep(0)  # let new submissions interleave
            if not self._running:
                return
