"""``repro.serve``: the production service layer over PUD sessions.

The paper's headline capabilities — MAJX integrity voting (§5),
Multi-RowCopy healing/bulk-erase (§6/§8.2) — matter at production scale
only if many concurrent requests share the simultaneous-many-row
substrate efficiently.  This package is that service subsystem:

* :mod:`repro.serve.queue` — typed ``IntegrityRequest`` / ``HealRequest``
  / ``EraseRequest`` with priorities, deadlines, per-tenant accounting;
* :mod:`repro.serve.admission` — per-tenant row arenas, bounded queues,
  backpressure, load-shedding;
* :mod:`repro.serve.batcher` — continuous batching: same-shape requests
  coalesce into ONE fused Program per tick;
* :mod:`repro.serve.slo` — request traces + rolling p50/p99/throughput/
  occupancy/cache-hit SLO snapshots;
* :mod:`repro.serve.service` — :class:`PudService`, the engine tying
  them together over a pool of :class:`~repro.session.DramSession`\\ s.

:mod:`repro.serve.engine` (the LM serving engine whose integrity hooks
are thin clients of :class:`PudService`) is imported separately — it
pulls in the model stack, which service-only consumers don't need.
"""

from repro.serve.admission import (AdmissionController, AdmissionError,
                                   ArenaExhaustedError,
                                   DeadlineExceededError, QueueFullError,
                                   TenantArena)
from repro.serve.batcher import Batcher, BatchOutcome, BatchPlan
from repro.serve.queue import (EraseRequest, EraseResult, HealRequest,
                               HealResult, IntegrityRequest,
                               IntegrityResult, Priority, PudRequest,
                               RequestQueue, ServeError)
from repro.serve.service import PudService, ServiceConfig
from repro.serve.slo import RequestTrace, SloMonitor, SloSnapshot, Span

__all__ = [
    "AdmissionController", "AdmissionError", "ArenaExhaustedError",
    "BatchOutcome", "BatchPlan", "Batcher", "DeadlineExceededError",
    "EraseRequest", "EraseResult", "HealRequest", "HealResult",
    "IntegrityRequest", "IntegrityResult", "Priority", "PudRequest",
    "PudService", "QueueFullError", "RequestQueue", "RequestTrace",
    "ServeError", "ServiceConfig", "SloMonitor", "SloSnapshot", "Span",
    "TenantArena",
]
