"""Typed PUD service requests and the priority request queue.

The serve layer's unit of work is a *request*: a tenant asking for one
of the paper's three production capabilities — an integrity check
(bit-level mismatch of a live tile vs a reference), a MAJX heal
(majority vote across replica tiles, §5), or a Multi-RowCopy bulk erase
(§8.2).  Requests are plain dataclasses over packed uint32 bit-plane
tiles (the layout of :mod:`repro.core.bitplanes`), carry priority /
deadline / tenant metadata, and expose the two properties the service
machinery keys on:

* :meth:`PudRequest.coalesce_key` — requests with equal keys can be
  fused into ONE addressed Program per batching tick (see
  :mod:`repro.serve.batcher`);
* :meth:`PudRequest.rows_needed` — the subarray-row footprint admission
  control charges against the tenant's arena
  (:mod:`repro.serve.admission`).

:class:`RequestQueue` is the bounded priority queue between
``PudService.submit`` and the batching loop: strict priority order,
FIFO within a priority, per-tenant accounting, and O(1) depth checks
for backpressure.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Optional

import numpy as np


class ServeError(RuntimeError):
    """Base error of the serve layer."""


class Priority(enum.IntEnum):
    """Dispatch priority; lower value dequeues first."""

    HIGH = 0
    NORMAL = 1
    LOW = 2


def _as_tile(arr, what: str, ndim: int) -> np.ndarray:
    if arr is None:
        raise ServeError(f"{what} is required")
    out = np.asarray(arr, np.uint32)
    if out.ndim != ndim:
        raise ServeError(
            f"{what} must be a rank-{ndim} packed uint32 tile, got "
            f"shape {out.shape}")
    return out


@dataclasses.dataclass
class PudRequest:
    """Base request: tenant + QoS metadata (see module docstring).

    ``deadline_s`` is relative to submission; past-deadline requests
    still queued at a batching tick are load-shed (the future raises
    :class:`~repro.serve.admission.DeadlineExceededError`).  ``rid``,
    ``submitted_at`` and ``deadline_at`` are stamped by the service at
    admission.
    """

    tenant: str = "default"
    priority: Priority = Priority.NORMAL
    deadline_s: Optional[float] = None
    rid: int = dataclasses.field(default=-1, compare=False)
    submitted_at: float = dataclasses.field(default=0.0, compare=False)
    deadline_at: Optional[float] = dataclasses.field(
        default=None, compare=False)

    @property
    def kind(self) -> str:
        return type(self).__name__.removesuffix("Request").lower()

    def coalesce_key(self) -> tuple:
        raise NotImplementedError

    def rows_needed(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class IntegrityRequest(PudRequest):
    """Bit-level verification: live tile vs reference tile.

    Executed as one ``mismatch`` bulk op per request (a scalar
    reduction has no per-request split, so integrity work shares the
    tick and the session pool but not a fused Program).  Result:
    :class:`IntegrityResult`.
    """

    live: Optional[np.ndarray] = None          # required; validated below
    reference: Optional[np.ndarray] = None     # required; validated below

    def __post_init__(self):
        self.live = _as_tile(self.live, "IntegrityRequest.live", 2)
        self.reference = _as_tile(
            self.reference, "IntegrityRequest.reference", 2)
        if self.live.shape != self.reference.shape:
            raise ServeError(
                f"live tile {self.live.shape} != reference tile "
                f"{self.reference.shape}")

    def coalesce_key(self) -> tuple:
        return ("verify", int(self.live.shape[1]))

    def rows_needed(self) -> int:
        return 2 * int(self.live.shape[0])


@dataclasses.dataclass
class HealRequest(PudRequest):
    """X-replica majority-vote heal over packed plane tiles.

    ``replicas``: ``(x, rows, words)`` uint32, ``x`` odd >= 3.  All
    same-``(x, words, n_act)`` heal requests in a tick coalesce into one
    single-level fused Program — one batched MAJX dispatch for every
    tenant's vote.  Result: :class:`HealResult`.
    """

    replicas: Optional[np.ndarray] = None      # required; validated below
    n_act: Optional[int] = None

    def __post_init__(self):
        self.replicas = _as_tile(self.replicas, "HealRequest.replicas", 3)
        x = int(self.replicas.shape[0])
        if x % 2 == 0 or x < 3:
            raise ServeError(
                f"HealRequest needs an odd replica count >= 3, got {x}")

    @property
    def x(self) -> int:
        return int(self.replicas.shape[0])

    @property
    def rows(self) -> int:
        return int(self.replicas.shape[1])

    def coalesce_key(self) -> tuple:
        return ("heal", self.x, int(self.replicas.shape[2]), self.n_act)

    def rows_needed(self) -> int:
        return (self.x + 1) * self.rows  # x input groups + voted output


@dataclasses.dataclass
class EraseRequest(PudRequest):
    """§8.2 Multi-RowCopy bulk erase of ``rows`` x ``words`` planes.

    One WR'd pattern row fans out in waves of ``fanout`` destinations;
    all same-``(words, pattern, fanout)`` erases in a tick share a
    single pattern row and coalesce into one single-level fused
    Program.  Result: :class:`EraseResult`.
    """

    rows: int = 0
    words: int = 0
    pattern: int = 0
    fanout: int = 31

    def __post_init__(self):
        if self.rows < 1 or self.words < 1:
            raise ServeError(
                f"EraseRequest needs rows >= 1 and words >= 1, got "
                f"rows={self.rows} words={self.words}")
        if not 1 <= self.fanout <= 31:
            raise ServeError(
                f"EraseRequest fanout must be in 1..31 (n_act <= 32), "
                f"got {self.fanout}")

    def coalesce_key(self) -> tuple:
        return ("erase", self.words, int(np.uint32(self.pattern)),
                self.fanout)

    def rows_needed(self) -> int:
        return self.rows  # the shared pattern row is charged to no tenant


# ---------------------------------------------------------------- results


@dataclasses.dataclass(frozen=True)
class IntegrityResult:
    mismatch_bits: int
    total_bits: int

    @property
    def success_rate(self) -> float:
        return 1.0 - self.mismatch_bits / max(self.total_bits, 1)


@dataclasses.dataclass(frozen=True)
class HealResult:
    healed: np.ndarray          # (rows, words) voted tile
    fixed_bits: int             # bits corrected vs replica 0
    decision: object = None     # OffloadDecision for the fused program


@dataclasses.dataclass(frozen=True)
class EraseResult:
    wiped: np.ndarray           # (rows, words), pattern everywhere


# ------------------------------------------------------------------ queue


class RequestQueue:
    """Bounded strict-priority FIFO with per-tenant depth accounting.

    Pure data structure: admission policy (what *gets* to be pushed)
    lives in :mod:`repro.serve.admission`; asynchrony (waiting for
    space / for work) lives in :class:`~repro.serve.service.PudService`.
    """

    def __init__(self, max_depth: int = 256):
        self.max_depth = max_depth
        self._heap: list[tuple[int, int, PudRequest]] = []
        self._seq = itertools.count()
        self._tenant_depth: dict[str, int] = {}

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def full(self) -> bool:
        return len(self._heap) >= self.max_depth

    def tenant_depth(self, tenant: str) -> int:
        return self._tenant_depth.get(tenant, 0)

    def push(self, req: PudRequest) -> None:
        if self.full:
            raise ServeError(
                f"queue full ({self.max_depth}); admission should have "
                f"rejected request {req.rid} first")
        heapq.heappush(self._heap, (int(req.priority), next(self._seq), req))
        self._tenant_depth[req.tenant] = self.tenant_depth(req.tenant) + 1

    def pop(self) -> PudRequest:
        _, _, req = heapq.heappop(self._heap)
        self._tenant_depth[req.tenant] -= 1
        return req

    def drain(self, max_requests: Optional[int] = None) -> list[PudRequest]:
        """Dequeue up to ``max_requests`` in priority-then-FIFO order."""
        n = len(self._heap) if max_requests is None else \
            min(max_requests, len(self._heap))
        return [self.pop() for _ in range(n)]
