"""Cold-boot-attack content destruction (paper §8.2).

Three strategies, exactly as the paper schedules them:

1. **RowClone-based**: WR a predetermined pattern to one row, then RowClone
   it to every other row (one op per destination row).
2. **Frac-based**: Frac every row to the neutral VDD/2 state.
3. **Multi-RowCopy-based**: WR one row, then fan it out with N-row
   activation (N in 2..32), destroying N-1 rows per op.

`destruction_time_ns` is the analytical bank-wipe model behind Fig. 17;
`erase_subarray` actually performs the wipe on the behavioural model (used
by :mod:`repro.ckpt` to destroy decommissioned checkpoint shards).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import calibration as cal
from repro.core.subarray import Subarray
from repro.core import rowcopy as rc
from repro.pud.latency import LAT

#: rows per DDR4 bank (2^16, §7.1) and per subarray (512, Mfr H).
BANK_ROWS = 65536


def destruction_time_ns(strategy: str, n_act: int = 32,
                        bank_rows: int = BANK_ROWS) -> float:
    """Total time to overwrite every row in a bank (Fig. 17 model)."""
    if strategy == "rowclone":
        return LAT.wr_row + (bank_rows - 1) * LAT.rowclone
    if strategy == "frac":
        return bank_rows * LAT.frac
    if strategy == "mrc":
        if n_act not in cal.N_ACT_LEVELS:
            raise ValueError(f"n_act must be one of {cal.N_ACT_LEVELS}")
        # Each MRC issue wipes n_act-1 rows (the source is already wiped).
        ops = -(-(bank_rows - 1) // (n_act - 1))
        return LAT.wr_row + ops * LAT.mrc
    raise ValueError(f"unknown strategy {strategy!r}")


def speedup_over_rowclone(strategy: str, n_act: int = 32) -> float:
    return destruction_time_ns("rowclone") / destruction_time_ns(strategy, n_act)


def erase_subarray(sa: Subarray, pattern_word: int = 0, n_act: int = 32) -> float:
    """Functionally destroy a subarray's content with Multi-RowCopy fan-out.

    Returns the modeled wall time (ns).  Walks activation groups across the
    subarray; any rows not covered by a full group fall back to RowClone.
    """
    word = jnp.uint32(pattern_word)
    src = jnp.full((sa.n_words,), word, jnp.uint32)
    t = LAT.wr_row
    covered = set()
    for base in range(sa.rows):
        if base in covered:
            continue
        try:
            rf, rs = sa.decoder.pair_for_n_rows(n_act, base)
            group = sa.decoder.apa_activated_rows(rf, rs)
        except ValueError:
            continue  # group would cross the subarray boundary
        if any(r in covered for r in group):
            continue
        rc.multi_rowcopy(sa, src, n_act, base_row=base)
        covered.update(group)
        t += LAT.mrc
    for r in range(sa.rows):
        if r not in covered:
            sa.write_row(r, src)
            t += LAT.rowclone
    return t
