"""PUD-vs-TPU offload planner.

The paper demonstrates that COTS DRAM computes bulk bitwise ops in-place.
Whether offloading such an op from the TPU to a PUD-capable memory pays off
depends on (a) the TPU roofline cost of the op (pure bandwidth for bitwise
work) vs (b) the PUD command-schedule latency including success-rate-driven
retries, and (c) the saved HBM traffic.  This planner prices both sides —
nanoseconds AND nanojoules (PULSAR's framing: many-row activation
amortizes per-command *energy*) — and is used by the serving engine's
PUD hooks to decide where integrity votes and bulk bitmap ops run.  On
TPU-only deployments it degrades to always-TPU (and the ``pallas``
backend runs the op), so the decision is advisory.

Planning is keyed by the shared
:class:`~repro.backends.context.ExecutionContext`: the calibration point
(manufacturer, temperature, VPP) that fixes the retry counts comes from
the same object the execution backends run under.

All hardware constants come from the one
:data:`repro.core.costmodel.COST` model (TPU v5e-like: 197 TFLOP/s bf16,
819 GB/s HBM), shared with launch/roofline.py so the two can never
drift; ``PEAK_FLOPS``/``HBM_BYTES_PER_S``/``KERNEL_LAUNCH_NS`` below
are re-exports, not definitions.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.backends.context import ExecutionContext
from repro.core import calibration as cal
from repro.core import power as pw
from repro.core.costmodel import (
    COST,
    HBM_BYTES_PER_S as HBM_BYTES_PER_S,
    KERNEL_LAUNCH_NS as KERNEL_LAUNCH_NS,
    PEAK_FLOPS as PEAK_FLOPS,
)
from repro.core.errormodel import ErrorModel, expected_retries
from repro.pud import latency as lat


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    op: str
    n_bytes: int
    tpu_ns: float
    pud_ns: float
    winner: str
    detail: str
    #: Energy of each side (nJ, Fig. 5 power model on the PUD side; the
    #: CostModel's dispatch + HBM-access terms on the TPU side) and the
    #: side that wins on joules — which need not match ``winner``:
    #: offload can save energy even when it costs nanoseconds.
    tpu_energy_nj: float = 0.0
    pud_energy_nj: float = 0.0
    winner_energy: str = ""

    @property
    def speedup(self) -> float:
        return self.tpu_ns / self.pud_ns

    @property
    def energy_savings(self) -> float:
        """TPU-over-PUD energy ratio (>1: offloading saves joules)."""
        return self.tpu_energy_nj / self.pud_energy_nj


def _resolve(ctx: Optional[ExecutionContext],
             errors: Optional[ErrorModel]) -> tuple[ExecutionContext,
                                                    ErrorModel]:
    """One calibration point for both sides of the plan."""
    if ctx is None:
        ctx = ExecutionContext(mfr=errors.mfr if errors else "H")
    return ctx, errors if errors is not None else ctx.error_model


def tpu_bitwise_ns(n_bytes: int, n_operands: int = 2) -> float:
    """Bandwidth-bound cost of a bulk bitwise op on the TPU (read all
    operands + write result; bitwise VPU throughput never binds)."""
    traffic = n_bytes * (n_operands + 1)
    return COST.hbm_ns(traffic)


def tpu_bitwise_energy_nj(n_bytes: int, n_operands: int = 2) -> float:
    """Energy of the same bulk bitwise op on the TPU: the DRAM access
    energy of streaming all operands + the result through HBM (like
    :func:`tpu_bitwise_ns`, launch overhead is excluded — bulk work
    amortizes it)."""
    return COST.hbm_energy_nj(n_bytes * (n_operands + 1))


def pud_majx_ns(n_bytes: int, x: int, n_act: int,
                errors: Optional[ErrorModel] = None, subarrays: int = 48,
                best_group: bool = True,
                ctx: Optional[ExecutionContext] = None) -> float:
    """PUD cost: ceil(bits/row_bits) MAJX issues spread over subarrays."""
    ctx, errors = _resolve(ctx, errors)
    if best_group:
        s = cal.MAJX_BEST_GROUP_SUCCESS[errors.mfr].get(x, 0.005)
    else:
        s = errors.majx_success(x, n_act, t1=ctx.timings.majx_t1,
                                t2=ctx.timings.majx_t2, **ctx.env())
    issues = -(-(n_bytes * 8) // lat.ROW_BITS)
    per = lat.LAT.majx_apa * expected_retries(s)
    waves = -(-issues // subarrays)
    return waves * per


def pud_majx_energy_nj(n_bytes: int, x: int, n_act: int,
                       errors: Optional[ErrorModel] = None,
                       subarrays: int = 48, best_group: bool = True,
                       ctx: Optional[ExecutionContext] = None) -> float:
    """Energy of the MAJX sweep: SiMRA power at ``n_act`` (Fig. 5 /
    Obs 5 — *below* REF at 32 rows) held for the retry-aware sweep
    time."""
    t = pud_majx_ns(n_bytes, x, n_act, errors, subarrays, best_group, ctx)
    return pw.simra_power_w(n_act) * t


def pud_mrc_ns(n_bytes: int, fanout: int,
               errors: Optional[ErrorModel] = None, subarrays: int = 48,
               ctx: Optional[ExecutionContext] = None) -> float:
    ctx, errors = _resolve(ctx, errors)
    s = errors.mrc_success(fanout, t1=ctx.timings.mrc_t1,
                           t2=ctx.timings.mrc_t2, **ctx.env())
    rows = -(-(n_bytes * 8) // lat.ROW_BITS)
    waves = -(-rows // subarrays)
    return waves * lat.LAT.mrc * expected_retries(s)


def pud_mrc_energy_nj(n_bytes: int, fanout: int,
                      errors: Optional[ErrorModel] = None,
                      subarrays: int = 48,
                      ctx: Optional[ExecutionContext] = None) -> float:
    """Energy of the MRC sweep: SiMRA power at the activation count
    (source + ``fanout`` destinations) over the retry-aware sweep time."""
    t = pud_mrc_ns(n_bytes, fanout, errors, subarrays, ctx)
    return pw.simra_power_w(fanout + 1) * t


def tpu_program_ns(program, row_bytes: int, *, fused: bool = True,
                   sched=None) -> float:
    """TPU-side cost of executing an addressed Program's bulk ops.

    Bandwidth term: every value op moves ``len(srcs) + len(dsts)`` rows
    through HBM.  Launch term: one :data:`KERNEL_LAUNCH_NS` per kernel
    dispatch — the per-op interpreter launches one kernel per MAJ/MRC
    op, the fused path one per schedule dispatch group (see
    :mod:`repro.compile.schedule`), which is what makes fusion the
    default executor for deep programs.  Pass a prebuilt ``sched`` to
    avoid re-leveling the program.
    """
    from repro.compile.schedule import VALUE_KINDS, build_schedule

    if sched is None:
        sched = build_schedule(program)
    dispatches = (sched.n_dispatches() if fused
                  else sched.per_op_dispatches())
    rows_moved = sum(len(op.srcs) + len(op.dsts) for op in program.ops
                     if op.dsts and op.kind in VALUE_KINDS)
    return (COST.dispatch_overhead(dispatches)
            + COST.hbm_ns(rows_moved * row_bytes))


def tpu_program_energy_nj(program, row_bytes: int, *, fused: bool = True,
                          sched=None) -> float:
    """TPU-side energy of executing an addressed Program's bulk ops:
    board power held across each kernel launch plus DRAM access energy
    for the rows moved — the same dispatch/traffic split as
    :func:`tpu_program_ns`, priced in nJ by the shared CostModel."""
    from repro.compile.schedule import VALUE_KINDS, build_schedule

    if sched is None:
        sched = build_schedule(program)
    dispatches = (sched.n_dispatches() if fused
                  else sched.per_op_dispatches())
    rows_moved = sum(len(op.srcs) + len(op.dsts) for op in program.ops
                     if op.dsts and op.kind in VALUE_KINDS)
    return (COST.dispatch_energy_nj(dispatches)
            + COST.hbm_energy_nj(rows_moved * row_bytes))


def plan_program(program, row_bytes: int,
                 errors: Optional[ErrorModel] = None,
                 ctx: Optional[ExecutionContext] = None,
                 sched=None) -> OffloadDecision:
    """Where should a whole addressed Program run?

    Prices the PUD side with the program's retry-aware command schedule
    (:meth:`repro.pud.isa.Program.latency_ns`) and the TPU side with the
    *fused* dispatch count, so the decision reflects the executor the
    ``pallas`` backend actually uses.  Pass a prebuilt ``sched`` (e.g.
    ``DramSession.schedule_for``'s cached one) to avoid re-leveling the
    program.  Consumers: the serve engine's integrity-vote hook records
    one decision per healed program.
    """
    from repro.compile.schedule import build_schedule

    ctx, errors = _resolve(ctx, errors)
    if sched is None:
        sched = build_schedule(program)
    tpu = tpu_program_ns(program, row_bytes, fused=True, sched=sched)
    pud = program.latency_ns(errors, **ctx.env())
    tpu_e = tpu_program_energy_nj(program, row_bytes, fused=True,
                                  sched=sched)
    pud_e = program.energy_nj(errors, **ctx.env())
    winner = "pud" if pud < tpu else "tpu"
    n_ops = sum(1 for op in program.ops if op.dsts)
    return OffloadDecision(
        op=f"program[{n_ops}ops]", n_bytes=row_bytes, tpu_ns=tpu,
        pud_ns=pud, winner=winner,
        detail=(f"tpu fused: {sched.n_dispatches()} dispatches over "
                f"{sched.n_levels} levels (vs {sched.per_op_dispatches()} "
                f"per-op); pud: retry-aware command schedule"),
        tpu_energy_nj=tpu_e, pud_energy_nj=pud_e,
        winner_energy="pud" if pud_e < tpu_e else "tpu",
    )


def plan_vote(n_bytes: int, x: int = 3, errors: ErrorModel | None = None,
              subarrays: int = 48,
              ctx: Optional[ExecutionContext] = None) -> OffloadDecision:
    """Where should an X-replica majority vote over ``n_bytes`` run?"""
    ctx, errors = _resolve(ctx, errors)
    tpu = tpu_bitwise_ns(n_bytes, n_operands=x)
    pud = pud_majx_ns(n_bytes, x, 32, errors, subarrays, ctx=ctx)
    tpu_e = tpu_bitwise_energy_nj(n_bytes, n_operands=x)
    pud_e = pud_majx_energy_nj(n_bytes, x, 32, errors, subarrays, ctx=ctx)
    winner = "pud" if pud < tpu else "tpu"
    return OffloadDecision(
        op=f"maj{x}_vote", n_bytes=n_bytes, tpu_ns=tpu, pud_ns=pud,
        winner=winner,
        detail=(f"tpu reads {x}x+writes 1x @819GB/s; pud issues "
                f"{-(-(n_bytes*8)//lat.ROW_BITS)} MAJ{x} over {subarrays} subarrays"),
        tpu_energy_nj=tpu_e, pud_energy_nj=pud_e,
        winner_energy="pud" if pud_e < tpu_e else "tpu",
    )


def plan_broadcast(n_bytes: int, fanout: int,
                   errors: ErrorModel | None = None,
                   subarrays: int = 48,
                   ctx: Optional[ExecutionContext] = None) -> OffloadDecision:
    """One-to-``fanout`` replication: HBM copies vs Multi-RowCopy."""
    ctx, errors = _resolve(ctx, errors)
    tpu = COST.hbm_ns(n_bytes * (1 + fanout))
    pud = pud_mrc_ns(n_bytes * fanout, min(fanout, 31), errors, subarrays,
                     ctx=ctx)
    tpu_e = COST.hbm_energy_nj(n_bytes * (1 + fanout))
    pud_e = pud_mrc_energy_nj(n_bytes * fanout, min(fanout, 31), errors,
                              subarrays, ctx=ctx)
    winner = "pud" if pud < tpu else "tpu"
    return OffloadDecision(
        op=f"broadcast_x{fanout}", n_bytes=n_bytes, tpu_ns=tpu, pud_ns=pud,
        winner=winner, detail="MRC wipes/copies n_act-1 rows per 90ns issue",
        tpu_energy_nj=tpu_e, pud_energy_nj=pud_e,
        winner_energy="pud" if pud_e < tpu_e else "tpu",
    )
