"""PUDDevice: a bank/subarray-structured device executing PUD programs.

Composes the behavioural :class:`~repro.core.subarray.Subarray` model into
the module-level geometry of Table 1 (banks x subarrays), with operation
scheduling, latency/energy accounting, and row allocation.  This is the
"device" the examples and §5/§6 benchmarks drive, and the execution target
the offload planner (:mod:`repro.pud.offload`) prices against the TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.errormodel import ErrorModel
from repro.core.subarray import DeviceProfile, Subarray
from repro.core import majx as mj
from repro.core import rowcopy as rc
from repro.pud.isa import Program
from repro.pud import latency as lat


@dataclasses.dataclass
class DeviceConfig:
    profile: DeviceProfile = dataclasses.field(default_factory=DeviceProfile.mfr_h)
    n_banks: int = 16
    subarrays_per_bank: int = 3  # the paper tests 3 random subarrays/bank
    cols: int = 1024
    temp_c: float = 50.0
    vpp_v: float = 2.5
    ideal: bool = False


class PUDDevice:
    """A (small, simulated) DRAM module capable of PUD operations."""

    def __init__(self, config: Optional[DeviceConfig] = None, seed: int = 0):
        self.config = config or DeviceConfig()
        c = self.config
        self.subarrays = [
            Subarray(c.profile, c.cols, temp_c=c.temp_c, vpp_v=c.vpp_v,
                     seed=seed * 1009 + i, ideal=c.ideal)
            for i in range(c.n_banks * c.subarrays_per_bank)
        ]
        self.errors = ErrorModel(c.profile.mfr)
        self.program = Program()
        self.elapsed_ns = 0.0

    # ------------------------------------------------------------ topology
    def subarray(self, bank: int, index: int = 0) -> Subarray:
        return self.subarrays[bank * self.config.subarrays_per_bank + index]

    @property
    def n_subarrays(self) -> int:
        return len(self.subarrays)

    # ------------------------------------------------------------ PUD ops
    def majx(self, bank: int, operands, n_act: int, **kw) -> jax.Array:
        sa = self.subarray(bank)
        out = mj.majx(sa, operands, n_act, **kw)
        x = len(operands)
        self.program.emit("MAJ", x=x, n_act=n_act, tag=f"bank{bank}")
        self.elapsed_ns += lat.majx_issue_ns(x, n_act)
        return out

    def multi_rowcopy(self, bank: int, src_data, n_act: int, **kw):
        sa = self.subarray(bank)
        out = rc.multi_rowcopy(sa, src_data, n_act, **kw)
        self.program.emit("MRC", n_act=n_act, tag=f"bank{bank}")
        self.elapsed_ns += lat.LAT.mrc
        return out

    def rowclone(self, bank: int, src: int, dst: int) -> None:
        rc.rowclone(self.subarray(bank), src, dst)
        self.program.emit("COPY", tag=f"bank{bank}")
        self.elapsed_ns += lat.LAT.rowclone

    def broadcast_fanout(self, bank: int, src_data, n_rows: int) -> list[int]:
        """Replicate one row image to ``n_rows`` rows with a fan-out tree.

        Uses the widest Multi-RowCopy the decoder supports per step —
        the framework's model of the paper's 1->31 fan-out primitive, and
        the building block of the checkpoint-restore replication path.
        """
        sa = self.subarray(bank)
        done: list[int] = []
        base = 0
        while len(done) < n_rows:
            n_act = 32
            while n_act > 2 and len(done) + (n_act - 1) > n_rows + 31:
                n_act //= 2
            src_row, dests = rc.multi_rowcopy(sa, src_data, n_act, base_row=base)
            self.program.emit("MRC", n_act=n_act, tag=f"bank{bank}/fanout")
            self.elapsed_ns += lat.LAT.mrc
            done.extend(dests[: n_rows - len(done)])
            base += n_act
            if base + n_act > sa.rows:
                break
        return done

    # -------------------------------------------------------- accounting
    def energy_nj(self) -> float:
        return self.program.energy_nj(self.errors)

    def stats(self) -> dict:
        return {
            "elapsed_ns": self.elapsed_ns,
            "ops": len(self.program.ops),
            "histogram": self.program.histogram(),
            "energy_nj": self.energy_nj(),
        }
