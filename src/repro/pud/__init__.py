"""PUD runtime: device model, ISA, bit-serial compiler, TMR, erase, offload."""

from repro.pud.arith import BitSerial, run_elementwise  # noqa: F401
from repro.pud.device import DeviceConfig, PUDDevice  # noqa: F401
from repro.pud.isa import Program, PUDOp  # noqa: F401
from repro.pud.tmr import vote_array, vote_pytree, vote_words  # noqa: F401
