"""Command-schedule latency & throughput model (DRAM Bender measurements, §8).

The paper's case studies measure the latency of each PUD operation by
scheduling its DRAM command sequence on DRAM Bender, then analytically model
microbenchmark execution time from the best measured throughput.  We model
the same pipeline: per-op latency from the command IR timings
(:mod:`repro.core.commands`), throughput from latency x the calibrated
success rate (retry-until-success, geometric estimate; the paper instead
selects the best-throughput row groups, which our expected-retry model
approximates from the average success rate).
"""

from __future__ import annotations

import dataclasses

from repro.core import calibration as cal
from repro.core import commands as cmd
from repro.core.errormodel import ErrorModel, expected_retries

T = cmd.NOMINAL

#: Bits per DRAM row across one rank (8 KB row, §8.1 element layout).
ROW_BITS = 65536
#: Peak module bus bandwidth (DDR4-2400, 64-bit channel), bytes/ns.
BUS_BYTES_PER_NS = 19.2


@dataclasses.dataclass(frozen=True)
class OpLatency:
    """Latency (ns) of one issue of each PUD / support operation."""

    #: APA in charge-share mode + row-cycle close: t1 + t2 + tRAS + tRP.
    majx_apa: float = cal.MAJX_BEST_T1_NS + cal.MAJX_BEST_T2_NS + T.tras + T.trp
    #: APA in Multi-RowCopy mode.  Base schedule tRAS + t2 + tRAS + tRP =
    #: 90 ns plus a sense-amp drive extension for the 32-way fan-out;
    #: the total is *calibrated* to Fig. 17's 20.87x (the paper measures
    #: but does not print per-op latencies).
    mrc: float = 138.1
    #: Consecutive two-row activation (RowClone): tRAS + 6 + tRAS + tRP.
    rowclone: float = T.tras + 6.0 + T.tras + T.trp
    #: Frac neutral-row init: interrupted restore + precharge.  Calibrated
    #: to Fig. 17's RowClone/Frac = 20.87/7.55 ratio (see above).
    frac: float = 18.7 + T.trp
    #: Writing a full row over the bus: tRCD + burst stream + tWR + tRP.
    wr_row: float = T.trcd + (ROW_BITS / 8) / BUS_BYTES_PER_NS + T.twr + T.trp
    #: Reading a full row: tRCD + burst stream + tRP.
    rd_row: float = T.trcd + (ROW_BITS / 8) / BUS_BYTES_PER_NS + T.trp


LAT = OpLatency()


def majx_issue_ns(x: int, n_act: int) -> float:
    """One MAJX issue including operand staging (§8.1 methodology).

    RowClone the X operands into the group (X ops), Multi-RowCopy the
    replicas (one MRC covers the whole group), Frac the neutral rows.
    """
    copies, neutral = cal.replication_plan(x, n_act)
    setup = x * LAT.rowclone
    if copies > 1:
        setup += x * LAT.mrc  # one fan-out per operand
    setup += neutral * LAT.frac
    return setup + LAT.majx_apa


def majx_throughput_bits_per_s(
    x: int, n_act: int, errors: ErrorModel, **env
) -> float:
    """Correct result bits per second for one subarray issuing MAJX.

    throughput = ROW_BITS * success / (issue latency * expected retries)
    — the §8.1 analytical model with our calibrated surfaces.
    """
    s = errors.majx_success(x, n_act, **env)
    t_ns = majx_issue_ns(x, n_act) * expected_retries(s)
    return ROW_BITS * s / (t_ns * 1e-9)


def mrc_throughput_rows_per_s(n_act: int, errors: ErrorModel, **env) -> float:
    """Destination rows written per second by Multi-RowCopy."""
    s = errors.mrc_success(n_act - 1, **env)
    t_ns = LAT.mrc * expected_retries(s)
    return (n_act - 1) / (t_ns * 1e-9)
