"""Command-schedule latency & throughput model — compatibility shim.

The latency table and throughput helpers that historically lived here
moved to :mod:`repro.core.costmodel` so the DRAM side and the TPU side
of every offload decision are priced by ONE :class:`~repro.core.
costmodel.CostModel` (latency *and* energy).  This module re-exports the
public names so existing importers (`pud.isa`, `pud.offload`,
`pud.device`, `pud.secure_erase`, the figure benches) keep working;
new code should import from ``repro.core.costmodel`` directly.
"""

from __future__ import annotations

from repro.core.costmodel import (
    BUS_BYTES_PER_NS as BUS_BYTES_PER_NS,
    LAT as LAT,
    ROW_BITS as ROW_BITS,
    T as T,
    OpLatency as OpLatency,
    majx_issue_ns as majx_issue_ns,
    majx_throughput_bits_per_s as majx_throughput_bits_per_s,
    mrc_throughput_rows_per_s as mrc_throughput_rows_per_s,
)

__all__ = [
    "BUS_BYTES_PER_NS",
    "LAT",
    "ROW_BITS",
    "T",
    "OpLatency",
    "majx_issue_ns",
    "majx_throughput_bits_per_s",
    "mrc_throughput_rows_per_s",
]
