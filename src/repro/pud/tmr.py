"""X-modular-redundancy majority voting built on MAJX (paper §8.1).

The paper points out that MAJ3/5/7/9 directly implement triple (and wider)
modular redundancy voting in memory: MAJX corrects up to floor(X/2) faulty
replicas.  In this framework the voter protects *checkpoint and optimizer
state* against silent data corruption at scale (see
:mod:`repro.ckpt.tmr_store`): replicas are bitwise-voted on restore, so a
corrupted shard on any minority of replicas is healed without recomputation.

Two backends:
* ``vote_words`` — closed-form digital vote on uint32 words (XLA; also the
  oracle for the ``kernels/vote`` Pallas kernel).
* device-model voting via :func:`repro.core.majx.majx` for fidelity studies.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import bitplanes as bp


def vote_words(replicas: jax.Array) -> jax.Array:
    """Bitwise majority over replicas, shape (X, ...) uint32, odd X."""
    replicas = jnp.asarray(replicas, jnp.uint32)
    x = replicas.shape[0]
    if x % 2 == 0:
        raise ValueError("XMR vote needs an odd replica count")
    if x == 3:
        return bp.maj3_words(replicas[0], replicas[1], replicas[2])
    return bp.majority(replicas, axis=0)


def vote_array(replicas: Sequence[jax.Array]) -> jax.Array:
    """Majority-vote arbitrary same-shape/dtype arrays bitwise.

    Works for f32/bf16/f16/i8/u8/i32 etc. by voting on the raw words —
    bit-exact healing, no numerics involved.
    """
    words = []
    shape = dtype = None
    for r in replicas:
        w, shape, dtype = bp.bitcast_to_planes(r)
        words.append(w)
    voted = vote_words(jnp.stack(words))
    return bp.bitcast_from_planes(voted, shape, dtype)


def vote_pytree(replicas: Sequence) -> object:
    """Vote an entire pytree of arrays (e.g. a checkpoint)."""
    flats = [jax.tree_util.tree_flatten(r) for r in replicas]
    treedef = flats[0][1]
    leaves = []
    for i in range(len(flats[0][0])):
        leaves.append(vote_array([f[0][i] for f in flats]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def corrupt(x: jax.Array, key: jax.Array, bit_error_rate: float) -> jax.Array:
    """Inject i.i.d. bit flips (SDC model) — used by tests and demos."""
    words, shape, dtype = bp.bitcast_to_planes(x)
    flip_bits = jax.random.bernoulli(key, bit_error_rate, (words.size * 32,))
    flips = bp.pack(flip_bits.reshape(words.size, 32)).reshape(words.shape)
    return bp.bitcast_from_planes(words ^ flips, shape, dtype)


def residual_word_error_rate(bit_error_rate: float, x: int = 3,
                             word_bits: int = 32) -> float:
    """Analytic post-vote word error rate for i.i.d. flips.

    A bit survives unless >= ceil(X/2) replicas flip it; a word fails if
    any of its bits fail.  Used by tests to check the voter hits theory.
    """
    from math import comb

    p = bit_error_rate
    need = (x + 1) // 2
    p_bit = sum(comb(x, k) * p**k * (1 - p) ** (x - k) for k in range(need, x + 1))
    return 1.0 - (1.0 - p_bit) ** word_bits
