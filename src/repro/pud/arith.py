"""Majority-based bit-serial arithmetic (paper §8.1).

The paper's case study implements 32-bit AND/OR/XOR/ADD/SUB/MUL/DIV with
MAJX operations and evaluates how the new MAJ5/MAJ7/MAJ9 primitives speed
them up over the MAJ3-only state of the art.  This module is both the
*functional* implementation (exact boolean results on packed bit-planes,
tested against numpy integer arithmetic) and the *compiler* (every gate is
recorded into a :class:`~repro.pud.isa.Program` for latency/energy costing).

Gate constructions (all standard majority-logic identities, verified in
tests/test_arith.py):

* ``AND_k(x1..xk)  = MAJ(2k-1)(x1..xk, 0 * (k-1))``
* ``OR_k(x1..xk)   = MAJ(2k-1)(x1..xk, 1 * (k-1))``
* ``NOT``            is a complement-row copy (RowClone through the dual
  row, Ambit-style); complements of inputs can be *staged once* and reused.
* full adder:   ``c' = MAJ3(a,b,c)``;  ``s = MAJ5(a,b,c,~c',~c')``
  (the MAJ5 *input-replication* identity: s=1 iff a+b+c in {1,3}).
* two-position carry skip:  ``c_{i+2} = MAJ7(a_{i+1},a_{i+1},b_{i+1},
  b_{i+1},a_i,b_i,c_i)`` (weights 2,2,1,1,1 — again via input replication).

Tiers: ``tier=3`` restricts gates to MAJ3 (the FracDRAM/ComputeDRAM
state-of-the-art baseline the paper compares against); ``tier=5/7/9``
unlock the wider gates demonstrated by the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core import bitplanes as bp
from repro.pud.isa import Program

Plane = jax.Array  # uint32[W]


class GateExecutor(Protocol):
    """How a recorded gate actually computes its result.

    The bit-serial compiler below emits the *same* Program regardless of
    the executor; backends (repro.backends) inject themselves here so one
    compiled §8.1 program runs through the logical oracle, the
    behavioural subarray simulator, or the Pallas TPU kernels
    interchangeably.
    """

    def gate_maj(self, planes: Sequence[Plane], x: int, n_act: int) -> Plane:
        ...

    def gate_not(self, p: Plane) -> Plane:
        ...


def _maj_planes(planes: Sequence[Plane]) -> Plane:
    k = len(planes)
    if k == 3:
        return bp.maj3_words(*planes)
    return bp.majority(jnp.stack(planes), axis=0)


@dataclasses.dataclass
class BitSerial:
    """Bit-serial execution context: gates compute *and* get recorded."""

    tier: int = 3          # largest MAJ arity available (3/5/7/9)
    n_act: int = 4         # simultaneous activation count per MAJ issue
    program: Program = dataclasses.field(default_factory=Program)
    #: Optional gate executor (see :class:`GateExecutor`); None = logical.
    executor: Optional[GateExecutor] = None

    def __post_init__(self):
        if self.tier not in (3, 5, 7, 9):
            raise ValueError("tier must be one of 3/5/7/9")

    # ------------------------------------------------------------- gates
    def maj(self, *planes: Plane, tag: str = "") -> Plane:
        x = len(planes)
        if x % 2 == 0 or x < 3:
            raise ValueError("MAJ arity must be odd >= 3")
        if x > self.tier:
            raise ValueError(f"MAJ{x} exceeds tier {self.tier}")
        # N-row activation must be a reachable level (2/4/8/16/32) >= X.
        from repro.core import calibration as cal

        n_act = cal.min_activation_for(max(self.n_act, x))
        self.program.emit("MAJ", x=x, n_act=n_act, tag=tag)
        if self.executor is not None:
            return self.executor.gate_maj(planes, x, n_act)
        return _maj_planes(planes)

    def not_(self, p: Plane, tag: str = "") -> Plane:
        self.program.emit("NOT", tag=tag)
        if self.executor is not None:
            return self.executor.gate_not(p)
        return ~jnp.asarray(p, jnp.uint32)

    def const(self, value: int, like: Plane) -> Plane:
        like = jnp.asarray(like, jnp.uint32)
        return jnp.full_like(like, 0xFFFFFFFF if value else 0)

    def and_(self, *ps: Plane, tag: str = "and") -> Plane:
        """k-ary AND, fused into the widest available MAJ gate."""
        ps = list(ps)
        while len(ps) > 1:
            k_max = (self.tier + 1) // 2  # widest AND arity per gate
            k = min(len(ps), k_max)
            group, ps = ps[:k], ps[k:]
            if k == 1:
                ps.append(group[0])
                continue
            zeros = [self.const(0, group[0])] * (k - 1)
            ps.insert(0, self.maj(*group, *zeros, tag=tag))
        return ps[0]

    def or_(self, *ps: Plane, tag: str = "or") -> Plane:
        ps = list(ps)
        while len(ps) > 1:
            k_max = (self.tier + 1) // 2
            k = min(len(ps), k_max)
            group, ps = ps[:k], ps[k:]
            if k == 1:
                ps.append(group[0])
                continue
            ones = [self.const(1, group[0])] * (k - 1)
            ps.insert(0, self.maj(*group, *ones, tag=tag))
        return ps[0]

    def xor(self, a: Plane, b: Plane, tag: str = "xor") -> Plane:
        """XOR = AND(OR(a,b), NAND(a,b)) — 3 MAJ + 1 NOT."""
        o = self.or_(a, b, tag=tag)
        na = self.not_(self.and_(a, b, tag=tag), tag=tag)
        return self.and_(o, na, tag=tag)

    def mux(self, sel: Plane, x: Plane, y: Plane, tag: str = "mux") -> Plane:
        """sel ? x : y = OR(AND(x, sel), AND(y, ~sel))."""
        nsel = self.not_(sel, tag=tag)
        return self.or_(self.and_(x, sel, tag=tag),
                        self.and_(y, nsel, tag=tag), tag=tag)

    # ------------------------------------------------------------ adders
    def full_adder(self, a: Plane, b: Plane, c: Plane, tag: str = "fa"
                   ) -> tuple[Plane, Plane]:
        """Returns (sum, carry_out) using the tier's best construction."""
        if self.tier >= 5:
            cout = self.maj(a, b, c, tag=f"{tag}/carry")
            ncout = self.not_(cout, tag=f"{tag}/ncarry")
            s = self.maj(a, b, c, ncout, ncout, tag=f"{tag}/sum5")
            return s, cout
        cout = self.maj(a, b, c, tag=f"{tag}/carry")
        s = self.xor(self.xor(a, b, tag=f"{tag}/x1"), c, tag=f"{tag}/x2")
        return s, cout

    def carry_skip2(self, a1, b1, a0, b0, c0, tag="skip") -> Plane:
        """c2 = MAJ7(a1,a1,b1,b1,a0,b0,c0) — requires tier >= 7.

        tier 9 maps the gate to MAJ9 by padding one all-0 and one all-1
        row (MAJ9(x.., 0, 1) == MAJ7(x..)) — the widest-gate compiler
        policy whose poor MAJ9 success rate on Mfr H reproduces the
        paper's Fig 16 degradation.
        """
        if self.tier >= 9:
            zero = self.const(0, a1)
            one = self.const(1, a1)
            return self.maj(a1, a1, b1, b1, a0, b0, c0, zero, one, tag=tag)
        return self.maj(a1, a1, b1, b1, a0, b0, c0, tag=tag)

    def add(
        self, A: jax.Array, B: jax.Array, cin: Optional[Plane] = None,
        tag: str = "add",
    ) -> tuple[jax.Array, Plane]:
        """Ripple add of two bit-plane numbers, shape (nbits, W).

        tier>=7 computes every second carry with the MAJ7 two-position skip,
        halving the *sequential* carry depth (subarray-level parallelism;
        op count matches the MAJ5 construction).
        Returns (sum planes, carry_out plane).
        """
        A = jnp.asarray(A, jnp.uint32)
        B = jnp.asarray(B, jnp.uint32)
        nbits = A.shape[0]
        c = cin if cin is not None else self.const(0, A[0])
        sums = []
        i = 0
        while i < nbits:
            if self.tier >= 7 and i + 1 < nbits:
                c1 = self.maj(A[i], B[i], c, tag=f"{tag}/c[{i}]")
                c2 = self.carry_skip2(A[i + 1], B[i + 1], A[i], B[i], c,
                                      tag=f"{tag}/cskip[{i+1}]")
                nc1 = self.not_(c1, tag=tag)
                nc2 = self.not_(c2, tag=tag)
                sums.append(self.maj(A[i], B[i], c, nc1, nc1, tag=f"{tag}/s[{i}]"))
                sums.append(self.maj(A[i + 1], B[i + 1], c1, nc2, nc2,
                                     tag=f"{tag}/s[{i+1}]"))
                c = c2
                i += 2
            else:
                s, c = self.full_adder(A[i], B[i], c, tag=f"{tag}[{i}]")
                sums.append(s)
                i += 1
        return jnp.stack(sums), c

    def neg_planes(self, B: jax.Array, tag: str = "neg") -> jax.Array:
        return jnp.stack([self.not_(B[i], tag=tag) for i in range(B.shape[0])])

    def sub(
        self, A: jax.Array, B: jax.Array, tag: str = "sub"
    ) -> tuple[jax.Array, Plane]:
        """A - B (two's complement).  carry_out == 1 iff A >= B (no borrow)."""
        nB = self.neg_planes(B, tag=f"{tag}/not")
        one = self.const(1, A[0])
        return self.add(A, nB, cin=one, tag=tag)

    def mul(self, A: jax.Array, B: jax.Array, tag: str = "mul") -> jax.Array:
        """Low ``nbits`` of A*B via shift-and-add partial products."""
        nbits = A.shape[0]
        zero = self.const(0, A[0])
        acc = jnp.stack([zero] * nbits)
        for i in range(nbits):
            # Partial product: (A << i) & b_i, restricted to low nbits.
            pp = [self.and_(A[j], B[i], tag=f"{tag}/pp[{i},{j}]")
                  for j in range(nbits - i)]
            pp_planes = jnp.stack([zero] * i + pp)
            # Accumulate only the live positions.
            hi, _ = self.add(acc[i:], pp_planes[i:], tag=f"{tag}/acc[{i}]")
            acc = jnp.concatenate([acc[:i], hi], axis=0)
        return acc

    def div(
        self, A: jax.Array, B: jax.Array, tag: str = "div"
    ) -> tuple[jax.Array, jax.Array]:
        """Unsigned restoring division: returns (quotient, remainder).

        Divide-by-zero lanes return Q=all-ones, R=A (hardware convention).
        """
        nbits = A.shape[0]
        zero = self.const(0, A[0])
        # Remainder is nbits+1 wide to absorb the shift before compare.
        R = jnp.stack([zero] * (nbits + 1))
        Bx = jnp.concatenate([B, jnp.stack([zero])], axis=0)
        q = []
        for step in range(nbits - 1, -1, -1):
            # R = (R << 1) | a_step
            R = jnp.concatenate([A[step][None], R[:-1]], axis=0)
            diff, no_borrow = self.sub(R, Bx, tag=f"{tag}/cmp[{step}]")
            q.append(no_borrow)
            R = jnp.stack([
                self.mux(no_borrow, diff[i], R[i], tag=f"{tag}/sel[{step}]")
                for i in range(nbits + 1)
            ])
        Q = jnp.stack(list(reversed(q)))
        return Q, R[:nbits]


# ---------------------------------------------------------------------------
# element-level convenience API (uint32 vectors <-> planes)
# ---------------------------------------------------------------------------


def run_elementwise(op: str, a, b, tier: int = 3, n_act: int = 4,
                    executor: Optional[GateExecutor] = None,
                    ) -> tuple[jax.Array, Program]:
    """Run a §8.1 microbenchmark op over uint32 element vectors.

    Returns (uint32 results, recorded Program).  ``a``/``b`` may be any
    shape; they are flattened into bit-serial lanes.  ``executor``
    selects where each recorded gate computes (default: logical oracle):
    a backend, or — the session-API entry point — a
    :class:`repro.session.DramSession` (what
    ``DramSession.elementwise`` passes).

    Executors with native batch dispatch (``pallas``) take the *fused*
    path: the gate stream is first lowered to an addressed Program
    (:func:`repro.compile.compile_elementwise`) and then executed in
    level-batched kernel dispatches via ``executor.run_fused`` — the
    values still come from that executor's kernels, and the returned
    Program additionally carries row addresses (same op histogram as the
    per-gate recording).  When the executor is a session, that
    ``run_fused`` resolves through its content-hashed compile cache, so
    re-running a traced program (same op/tier/width) skips
    re-scheduling.
    """
    caps = getattr(executor, "capabilities", None)
    if caps is not None and executor.capabilities().native_batch:
        from repro.compile import compile_elementwise

        cp = compile_elementwise(op, a, b, tier=tier, n_act=n_act)
        final = executor.run_fused(cp.program, cp.state)
        return cp.outputs(final), cp.program

    a = jnp.asarray(a, jnp.uint32).reshape(-1)
    b = jnp.asarray(b, jnp.uint32).reshape(-1)
    k = a.shape[0]
    A = bp.pack_uint_elements(a)
    B = bp.pack_uint_elements(b)
    ctx = BitSerial(tier=tier, n_act=n_act, executor=executor)
    if op == "and":
        out = jnp.stack([ctx.and_(A[i], B[i]) for i in range(A.shape[0])])
    elif op == "or":
        out = jnp.stack([ctx.or_(A[i], B[i]) for i in range(A.shape[0])])
    elif op == "xor":
        out = jnp.stack([ctx.xor(A[i], B[i]) for i in range(A.shape[0])])
    elif op == "add":
        out, _ = ctx.add(A, B)
    elif op == "sub":
        out, _ = ctx.sub(A, B)
    elif op == "mul":
        out = ctx.mul(A, B)
    elif op == "div":
        out, _ = ctx.div(A, B)
    else:
        raise ValueError(f"unknown op {op!r}")
    return bp.unpack_uint_elements(out, k), ctx.program
