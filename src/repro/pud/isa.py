"""PUD instruction stream: the compilation target of the bit-serial compiler.

Every §8.1 microbenchmark lowers to a stream of :class:`PUDOp` (MAJX issues,
row copies, Frac inits, NOTs-via-complement-copy).  The stream is both
executable (logical backend in :mod:`repro.pud.arith`, device backend in
:mod:`repro.pud.device`) and costable (:mod:`repro.pud.latency`), which is
how the Fig. 16 / Fig. 17 benchmarks derive execution time from the same
program the correctness tests run.
"""

from __future__ import annotations

import collections
import dataclasses
import json

from repro.core.costmodel import COST
from repro.core.errormodel import ErrorModel


@dataclasses.dataclass(frozen=True)
class PUDOp:
    kind: str          # MAJ | NOT | COPY | MRC | FRAC | WR | RD
    x: int = 0         # majority arity (MAJ only)
    n_act: int = 0     # simultaneous activation count (MAJ/MRC)
    tag: str = ""      # provenance (e.g. "add/carry[7]")
    #: Row addresses, making the stream *executable* by any registered
    #: backend (repro.backends): MAJ reads the X distinct operand rows in
    #: ``srcs`` and writes ``dsts``; COPY/NOT/MRC read ``srcs[0]`` and
    #: write every row in ``dsts``; FRAC neutral-inits ``dsts``.  Programs
    #: recorded purely for costing leave both empty.
    srcs: tuple[int, ...] = ()
    dsts: tuple[int, ...] = ()


@dataclasses.dataclass
class Program:
    ops: list[PUDOp] = dataclasses.field(default_factory=list)

    def emit(self, kind: str, x: int = 0, n_act: int = 0, tag: str = "",
             srcs: tuple[int, ...] = (), dsts: tuple[int, ...] = ()) -> None:
        self.ops.append(PUDOp(kind, x, n_act, tag, tuple(srcs), tuple(dsts)))

    def extend(self, other: "Program") -> None:
        self.ops.extend(other.ops)

    def n_rows(self) -> int:
        """Rows an executing backend must hold (max address + 1)."""
        mx = -1
        for op in self.ops:
            for r in op.srcs + op.dsts:
                mx = max(mx, r)
        return mx + 1

    def histogram(self) -> dict[tuple, int]:
        h: dict[tuple, int] = collections.Counter()
        for op in self.ops:
            h[(op.kind, op.x, op.n_act)] += 1
        return dict(h)

    # -------------------------------------------------------- serialization
    def to_json(self) -> str:
        """Canonical JSON form (golden-program regression fixtures)."""
        return json.dumps([dataclasses.asdict(op) for op in self.ops])

    @classmethod
    def from_json(cls, text: str) -> "Program":
        prog = cls()
        for raw in json.loads(text):
            prog.emit(raw["kind"], x=raw["x"], n_act=raw["n_act"],
                      tag=raw["tag"], srcs=tuple(raw["srcs"]),
                      dsts=tuple(raw["dsts"]))
        return prog

    # ------------------------------------------------------------- costing
    def latency_ns(
        self, errors: ErrorModel, *, pipelined: bool = False,
        best_group: bool = False, **env,
    ) -> float:
        """Expected execution time with retry-until-success semantics.

        ``pipelined=True`` drops operand staging (RowClone/Frac setup) from
        MAJ issues — the steady-state cost when operands already live in the
        subarray, as in the paper's tightly-scheduled §8.1 programs.
        ``best_group=True`` uses the best-row-group success rates the case
        studies select (calibration.MAJX_BEST_GROUP_SUCCESS).

        Delegates to the shared :data:`repro.core.costmodel.COST` — the
        same model that prices the TPU side of offload decisions.
        """
        return COST.program_latency_ns(self, errors, pipelined=pipelined,
                                       best_group=best_group, **env)

    def energy_nj(self, errors: ErrorModel, **env) -> float:
        """Energy from the Fig.-5 power model over the schedule (W x ns =
        nJ; delegates to :data:`repro.core.costmodel.COST`)."""
        return COST.program_energy_nj(self, errors, **env)
