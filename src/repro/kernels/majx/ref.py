"""Pure-jnp oracle for the bulk MAJX kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def majx_ref(planes: jax.Array) -> jax.Array:
    """Bitwise majority across axis 0 of packed uint32 planes.

    planes: (N, ...) uint32, N odd.  Returns (...) uint32 where each output
    bit is 1 iff more than N/2 of the stacked bits are 1 — the charge-share
    semantics of an N-row activation (paper §5).
    """
    planes = jnp.asarray(planes, jnp.uint32)
    n = planes.shape[0]
    if n % 2 == 0:
        raise ValueError("MAJX needs odd N")
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)
    count = jnp.sum(bits.astype(jnp.int32), axis=0)
    out = (2 * count > n).astype(jnp.uint32)
    return jnp.sum(out << shifts, axis=-1, dtype=jnp.uint32)
