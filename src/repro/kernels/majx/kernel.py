"""Pallas TPU kernel: bulk bitwise MAJX over packed bit-planes.

TPU-native adaptation of the paper's N-row charge-share majority (§5).
Instead of per-bit popcounts (32 shift iterations per word), the kernel
keeps a **bit-sliced carry-save counter** in vector registers: each of the
N operand planes is added into a ceil(log2(N+1))-bit counter whose "digits"
are uint32 planes, using only AND/XOR/OR — the VPU executes 32 bitlines per
word-lane per op, the same bulk-parallel geometry as the DRAM subarray
(every bitline computes simultaneously).

Memory layout: operands are staged as (N, R, C) uint32 in HBM and streamed
through VMEM in (N, BR, BC) blocks; BR/BC are multiples of the (8, 128)
VPU tile.  The majority threshold for odd N is evaluated directly on the
counter digits (no decode step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _csa_accumulate(planes):
    """Bit-sliced counter: returns digit planes [c0, c1, ...] (LSB first)."""
    max_digits = len(planes).bit_length()
    digits = []
    for w in planes:
        carry = w
        for d in range(len(digits)):
            new_carry = digits[d] & carry
            digits[d] = digits[d] ^ carry
            carry = new_carry
        if len(digits) < max_digits:
            digits.append(carry)
    return digits


def _ge_threshold(digits, thresh: int) -> jax.Array:
    """Bitwise (count >= thresh) from counter digit planes.

    Standard bit-sliced magnitude comparison against a constant, scanned
    MSB-first with greater-so-far / equal-so-far accumulators.
    """
    width = len(digits)
    t_bits = [(thresh >> i) & 1 for i in range(width)]
    ge = None  # strictly-greater-so-far, scanning MSB -> LSB
    eq = None  # equal-so-far
    for i in range(width - 1, -1, -1):
        d = digits[i]
        if t_bits[i]:
            gt_here = jnp.zeros_like(d)
            eq_here = d
        else:
            gt_here = d
            eq_here = ~d
        if ge is None:
            ge, eq = gt_here, eq_here
        else:
            ge = ge | (eq & gt_here)
            eq = eq & eq_here
    return ge | eq


def majx_kernel(x_ref, o_ref, *, n: int):
    planes = [x_ref[i] for i in range(n)]
    digits = _csa_accumulate(planes)
    o_ref[...] = _ge_threshold(digits, (n + 1) // 2)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def majx_pallas(
    planes: jax.Array,
    *,
    block_r: int = 8,
    block_c: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """planes: (N, R, C) uint32, N odd -> (R, C) uint32 majority."""
    n, r, c = planes.shape
    if n % 2 == 0:
        raise ValueError("MAJX needs odd N")
    grid = (pl.cdiv(r, block_r), pl.cdiv(c, block_c))
    return pl.pallas_call(
        functools.partial(majx_kernel, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_r, block_c), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.uint32),
        interpret=interpret,
    )(planes)
