"""jit'd public wrapper for the bulk MAJX kernel (+ TMR vote entry point)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitplanes as bp
from repro.kernels import tiling
from repro.kernels.majx.kernel import majx_pallas
from repro.kernels.majx.ref import majx_ref


def majx(planes: jax.Array, *, interpret: bool = True,
         block_r: int = 8, block_c: int = 512) -> jax.Array:
    """Bulk MAJX over (N, R, C) packed uint32 planes -> (R, C).

    Pads to VPU-aligned tiles, dispatches the Pallas kernel, crops.
    ``interpret=True`` is the validated CPU path; on real TPUs pass False.
    """
    planes = jnp.asarray(planes, jnp.uint32)
    if planes.ndim == 2:
        planes = planes[:, None, :]
        squeeze = True
    else:
        squeeze = False
    block_c = tiling.clamp_block_c(block_c)
    padded, rc = tiling.pad_to_tile(planes, block_r, block_c)
    out = tiling.crop(majx_pallas(padded, block_r=block_r, block_c=block_c,
                                  interpret=interpret), rc)
    return out[0] if squeeze else out


def vote(replicas, *, interpret: bool = True):
    """TMR/XMR vote over replicas of an arbitrary fixed-width array.

    Bitcasts each replica to packed words, majority-votes them through the
    MAJX kernel, and bitcasts back (see repro.pud.tmr for the digital
    oracle used in tests).
    """
    shape, dtype = None, None
    stacked = []
    for rep in replicas:
        w, shape, dtype = bp.bitcast_to_planes(rep)
        stacked.append(w)
    words = jnp.stack(stacked)  # (X, n_words)
    c = words.shape[1]
    planes = tiling.words_to_rows(words, tiling.MAX_BLOCK_C)
    voted = majx(planes, interpret=interpret).reshape(-1)[:c]
    return bp.bitcast_from_planes(voted, shape, dtype)


__all__ = ["majx", "vote", "majx_ref"]
