"""Shared VPU tile-alignment helpers for the bulk-bitwise Pallas kernels.

Every kernel wrapper in ``repro.kernels`` stages packed uint32 planes
through VMEM in (BR, BC) blocks that must be multiples of the TPU VPU
tile (8 sublanes x 128 lanes).  The padding/cropping arithmetic used to
be copy-pasted per wrapper; it lives here once and is what the
``pallas`` execution backend (:mod:`repro.backends.pallas`) dispatches
through.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: TPU VPU tile geometry: 8 sublanes x 128 lanes.
VPU_SUBLANES = 8
VPU_LANES = 128

#: Widest column block any wrapper uses (bounds VMEM per grid step).
MAX_BLOCK_C = 4096


def clamp_block_c(block_c: int, hi: int = MAX_BLOCK_C) -> int:
    """Clamp a requested column block to [VPU_LANES, hi]."""
    return max(VPU_LANES, min(block_c, hi))


def pad_to_tile(x: jax.Array, block_r: int, block_c: int
                ) -> tuple[jax.Array, tuple[int, int]]:
    """Pad the trailing (R, C) dims up to multiples of (block_r, block_c).

    Accepts any number of leading dims.  Returns ``(padded, (r, c))``
    where (r, c) are the original trailing sizes, for cropping the
    kernel output back with :func:`crop`.
    """
    *lead, r, c = x.shape
    pr = (-r) % block_r
    pc = (-c) % block_c
    if pr or pc:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pr), (0, pc)])
    return x, (r, c)


def crop(x: jax.Array, rc: tuple[int, int]) -> jax.Array:
    """Crop the trailing (R, C) dims back to the pre-padding sizes."""
    r, c = rc
    return x[..., :r, :c]


def words_to_rows(words: jax.Array, width: int) -> jax.Array:
    """Reshape flat word vectors (..., W) into a (..., rows, width) tile.

    Pads the trailing dim with zero words so W fits ``rows * width`` —
    the standard lowering of a 1-D packed plane onto the 2-D VPU grid.
    """
    w = words.shape[-1]
    rows = -(-w // width)
    pad = rows * width - w
    if pad:
        words = jnp.pad(words,
                        [(0, 0)] * (words.ndim - 1) + [(0, pad)])
    return words.reshape(*words.shape[:-1], rows, width)
