"""Pure-jnp oracle for the mismatch-count (success-rate) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mismatch_count_ref(got: jax.Array, want: jax.Array) -> jax.Array:
    """Total number of differing bits between two packed-plane arrays."""
    g = jnp.asarray(got, jnp.uint32)
    w = jnp.asarray(want, jnp.uint32)
    x = g ^ w
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    per_word = ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    return jnp.sum(per_word, dtype=jnp.int32)
