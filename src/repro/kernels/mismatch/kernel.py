"""Pallas TPU kernel: fused XOR + popcount reduction (success-rate counter).

The characterization harness (§3-§6) compares millions of read-back cells
against expected data per trial; the hot loop is "count differing bits".
The kernel fuses XOR, SWAR popcount, and a grid-carried scalar reduction:
each (BR, BC) block contributes its partial sum into a single accumulator
block that every grid step maps to, so HBM sees the operands exactly once
and one int32 comes back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def mismatch_kernel(g_ref, w_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = g_ref[...] ^ w_ref[...]
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    per_word = ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    partial = jnp.sum(per_word, dtype=jnp.int32)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[0, 0] = 0

    o_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def mismatch_pallas(
    got: jax.Array,
    want: jax.Array,
    *,
    block_r: int = 8,
    block_c: int = 512,
    interpret: bool = True,
) -> jax.Array:
    r, c = got.shape
    grid = (pl.cdiv(r, block_r), pl.cdiv(c, block_c))
    spec = pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))
    return pl.pallas_call(
        mismatch_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(got, want)[0, 0]
