"""jit'd wrapper for the mismatch/success-rate kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import tiling
from repro.kernels.mismatch.kernel import mismatch_pallas
from repro.kernels.mismatch.ref import mismatch_count_ref


def mismatch_count(got: jax.Array, want: jax.Array, *,
                   interpret: bool = True) -> jax.Array:
    """Number of differing bits between packed arrays of any shape."""
    g = jnp.asarray(got, jnp.uint32).reshape(-1)
    w = jnp.asarray(want, jnp.uint32).reshape(-1)
    width = 512
    g2 = tiling.words_to_rows(g, width)
    w2 = tiling.words_to_rows(w, width)
    return mismatch_pallas(g2, w2, interpret=interpret)


def success_rate(got, want, n_bits: int | None = None, *,
                 interpret: bool = True) -> float:
    g = jnp.asarray(got, jnp.uint32)
    total = int(n_bits) if n_bits else g.size * 32
    bad = int(mismatch_count(got, want, interpret=interpret))
    return 1.0 - bad / total


__all__ = ["mismatch_count", "success_rate", "mismatch_count_ref"]
