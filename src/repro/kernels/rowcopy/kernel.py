"""Pallas TPU kernel: one-to-many row fan-out (Multi-RowCopy, §6).

The paper's Multi-RowCopy writes one source row into up to 31 destinations
in a single command.  The TPU analogue is a broadcast whose *source block
is fetched from HBM once per grid column and fanned out to every
destination block from VMEM* — the BlockSpec index_map pins the source
block regardless of the fan-out grid index, so HBM read traffic is
1/fanout of a naive copy loop (the same traffic asymmetry the DRAM op
exploits).  Used by the checkpoint-restore replicator (repro/ckpt) and the
elastic re-replication path.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


def fanout_kernel(src_ref, o_ref):
    o_ref[...] = src_ref[...][None]


@functools.partial(jax.jit, static_argnames=("fanout", "block_r", "block_c",
                                              "interpret"))
def fanout_pallas(
    src: jax.Array,
    *,
    fanout: int,
    block_r: int = 8,
    block_c: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """src: (R, C) -> (fanout, R, C) broadcast."""
    r, c = src.shape
    grid = (fanout, pl.cdiv(r, block_r), pl.cdiv(c, block_c))
    return pl.pallas_call(
        fanout_kernel,
        grid=grid,
        in_specs=[
            # Source block independent of the fan-out index k: fetched once,
            # reused across the fan-out dimension.
            pl.BlockSpec((block_r, block_c), lambda k, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_r, block_c), lambda k, i, j: (k, i, j)),
        out_shape=jax.ShapeDtypeStruct((fanout, r, c), src.dtype),
        interpret=interpret,
    )(src)
