"""jit'd wrapper for the Multi-RowCopy fan-out kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import tiling
from repro.kernels.rowcopy.kernel import fanout_pallas
from repro.kernels.rowcopy.ref import fanout_ref


def fanout(src: jax.Array, fanout_n: int, *, interpret: bool = True,
           block_r: int = 8, block_c: int = 512) -> jax.Array:
    """Broadcast (R, C) -> (fanout_n, R, C), Multi-RowCopy style."""
    src = jnp.asarray(src)
    squeeze = src.ndim == 1
    if squeeze:
        src = src[None, :]
    padded, rc = tiling.pad_to_tile(src, block_r, block_c)
    out = tiling.crop(
        fanout_pallas(padded, fanout=fanout_n, block_r=block_r,
                      block_c=block_c, interpret=interpret), rc)
    return out[:, 0, :] if squeeze else out


__all__ = ["fanout", "fanout_ref"]
