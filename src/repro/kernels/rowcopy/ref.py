"""Pure-jnp oracle for the fan-out row-copy kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fanout_ref(src: jax.Array, fanout: int) -> jax.Array:
    """Broadcast a (R, C) source block to (fanout, R, C) — Multi-RowCopy."""
    src = jnp.asarray(src)
    return jnp.broadcast_to(src[None], (fanout, *src.shape))
