"""Pallas TPU kernel: scan a Schedule's level tables in ONE dispatch.

The per-level executor costs one kernel launch per dependency level;
this kernel runs *every* level of a lowered
:class:`~repro.compile.megakernel.MegaLowering` inside a single
``pallas_call``.  The three level tables (operand indices, destination
rows, complement flags) are staged as scalar-prefetch metadata — they
are index/control data, not bit-planes — and ``lax.scan`` walks the
level axis with the packed ``uint32`` state block carried through VMEM.

One level is one unified primitive:

    gather (W, X) operand rows  ->  bit-sliced CSA majority over X
    ->  XOR with the slot's complement mask  ->  scatter to W rows

reusing the word-packed carry-save counter of the standalone MAJX
kernel (:mod:`repro.kernels.majx.kernel`): the VPU computes 32 bitlines
per word lane per op, the same bulk geometry as the DRAM subarray, and
votes run on packed words — never unpacked bool planes.

Every op is bitwise per packed word, so word columns are independent:
the grid tiles the word axis, and when the image is wider than one
VMEM-budgeted block the Pallas pipeline streams column slabs through
its double-buffered HBM fetches.  Grid steps are not dispatches — the
launch count stays 1 either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.majx.kernel import _csa_accumulate, _ge_threshold


def schedule_kernel(src_ref, dst_ref, inv_ref, x_ref, o_ref, *, x: int):
    """Execute all levels against one (rows_aug, block_c) column slab."""
    state = x_ref[...]
    w = src_ref.shape[1]
    bc = state.shape[-1]

    def level(st, tables):
        srcs, dsts, invs = tables              # (W, X), (W,), (W,)
        # Gather samples the level-entry state; the single scatter below
        # commits at level exit — WAW leveling guarantees disjoint rows.
        ops = jnp.take(st, srcs.reshape(-1), axis=0).reshape(w, x, bc)
        digits = _csa_accumulate([ops[:, i, :] for i in range(x)])
        vote = _ge_threshold(digits, (x + 1) // 2)
        # invs is 0/1; 0 - 1 == all-ones in uint32, so this is the NOT
        # slots' complement and a no-op everywhere else.
        vote = vote ^ (jnp.uint32(0) - invs)[:, None]
        return st.at[dsts].set(vote), None

    final, _ = jax.lax.scan(
        level, state, (src_ref[...], dst_ref[...], inv_ref[...]))
    o_ref[...] = final


@functools.partial(jax.jit,
                   static_argnames=("x", "block_c", "interpret"))
def schedule_pallas(
    src: jax.Array,
    dst: jax.Array,
    inv: jax.Array,
    state: jax.Array,
    *,
    x: int,
    block_c: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """One dispatch over an augmented (rows_aug, C) uint32 image.

    ``src``/``dst``/``inv`` are the (n_levels, w_max[, x_max]) tables of
    a :class:`~repro.compile.megakernel.MegaLowering`; ``rows_aug`` and
    ``C`` must already be padded to the (block_r, block_c) tile (see
    ``ops.run_lowering``).  Programs with the same table *shapes* share
    one compilation — the tables themselves are traced operands.
    """
    rows_aug, c = state.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(pl.cdiv(c, block_c),),
        in_specs=[pl.BlockSpec((rows_aug, block_c), lambda j, *_: (0, j))],
        out_specs=pl.BlockSpec((rows_aug, block_c), lambda j, *_: (0, j)),
    )
    return pl.pallas_call(
        functools.partial(schedule_kernel, x=x),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_aug, c), jnp.uint32),
        interpret=interpret,
    )(src, dst, inv, state)
