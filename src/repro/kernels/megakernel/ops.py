"""Host-side wrapper: run a MegaLowering against a bit-plane image.

``run_lowering`` builds the augmented image (three constant rows in
front of the program rows, see :mod:`repro.compile.megakernel`), pads
it to the VPU tile, launches :func:`repro.kernels.megakernel.kernel.
schedule_pallas` exactly once, and crops the program rows back out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compile.megakernel import MegaLowering, N_CONST_ROWS, ONE_ROW
from repro.kernels.megakernel.kernel import schedule_pallas
from repro.kernels.tiling import VPU_LANES, VPU_SUBLANES, clamp_block_c


def run_lowering(
    lowering: MegaLowering,
    state: jax.Array,
    *,
    block_c: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Execute lowered level tables on a (rows, words) uint32 image.

    One Pallas dispatch regardless of level count or of how many
    ``block_c``-wide column slabs the grid streams.  Rows beyond what
    the lowering addresses ride along untouched (they are gathered
    never, scattered never); an empty lowering is the identity.
    """
    state = jnp.asarray(state, jnp.uint32)
    rows, words = state.shape
    if lowering.n_levels == 0 or lowering.w_max == 0:
        return state
    if lowering.n_rows > rows:
        raise ValueError(
            f"lowering addresses {lowering.n_rows} rows but state has "
            f"only {rows}")

    block_c = clamp_block_c(block_c)
    rows_aug = -(-(rows + N_CONST_ROWS) // VPU_SUBLANES) * VPU_SUBLANES
    cols = -(-words // block_c) * block_c
    aug = jnp.zeros((rows_aug, cols), jnp.uint32)
    # The ones row spans the full padded width so MAJ padding stays
    # exact in the ragged last column block.
    aug = aug.at[ONE_ROW].set(jnp.uint32(0xFFFFFFFF))
    aug = aug.at[N_CONST_ROWS:N_CONST_ROWS + rows, :words].set(state)

    out = schedule_pallas(
        jnp.asarray(lowering.src),
        jnp.asarray(lowering.dst),
        jnp.asarray(lowering.inv),
        aug,
        x=int(lowering.x_max),
        block_c=block_c,
        interpret=interpret,
    )
    return out[N_CONST_ROWS:N_CONST_ROWS + rows, :words]


def pick_block_c(words: int, budget_block_c: int) -> int:
    """Snap a planner-chosen block width onto the wrapper's clamp rule."""
    cols = -(-words // VPU_LANES) * VPU_LANES
    return clamp_block_c(min(budget_block_c, cols))
