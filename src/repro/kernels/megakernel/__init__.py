"""Megakernel: one Pallas dispatch executes a whole fused Schedule.

Lowered level tables come from :mod:`repro.compile.megakernel`; the
kernel here scans them inside a single ``pallas_call`` (word-packed
MAJX votes, identity-vote row copies, complement via XOR) with the
bit-plane state resident in VMEM.  ``ops.run_lowering`` is the public
entry the ``pallas`` backend dispatches; ``ref.schedule_exec_ref`` is
the pure-numpy oracle the differential tests compare against.
"""

from repro.kernels.megakernel.ops import run_lowering
from repro.kernels.megakernel.ref import schedule_exec_ref

__all__ = ["run_lowering", "schedule_exec_ref"]
