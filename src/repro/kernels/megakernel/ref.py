"""Pure-numpy oracle for lowered level tables.

Executes a :class:`~repro.compile.megakernel.MegaLowering` against a
program-rows state image with per-slot python loops — deliberately the
dumbest possible interpretation of the tables, so the differential
tests can separate *lowering* bugs (tables disagree with the Program)
from *kernel* bugs (the Pallas scan disagrees with its own tables).
"""

from __future__ import annotations

import numpy as np

from repro.compile.megakernel import MegaLowering, N_CONST_ROWS, ONE_ROW


def schedule_exec_ref(lowering: MegaLowering, state: np.ndarray) -> np.ndarray:
    """Run the level tables on a (rows, words) uint32 image, per slot."""
    state = np.asarray(state, np.uint32)
    rows, words = state.shape
    aug = np.zeros((rows + N_CONST_ROWS, words), np.uint32)
    aug[ONE_ROW] = np.uint32(0xFFFFFFFF)
    aug[N_CONST_ROWS:] = state
    for li in range(lowering.n_levels):
        entry = aug.copy()
        for w in range(lowering.w_max):
            operands = entry[lowering.src[li, w]]          # (x_max, words)
            # Bit-position majority, the slow-but-obvious way: unpack to
            # individual bits, count votes, repack.
            bits = (operands[:, :, None] >>
                    np.arange(32, dtype=np.uint32)) & np.uint32(1)
            vote_bits = (bits.sum(axis=0, dtype=np.int64) * 2
                         > lowering.x_max).astype(np.uint64)
            vote = (vote_bits << np.arange(32, dtype=np.uint64)) \
                .sum(axis=-1).astype(np.uint32)
            if lowering.inv[li, w]:
                vote = ~vote
            aug[lowering.dst[li, w]] = vote
    return aug[N_CONST_ROWS:]
