"""jit'd wrapper: bit-serial add on packed planes or uint element vectors."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitplanes as bp
from repro.kernels import tiling
from repro.kernels.bitserial.kernel import bitserial_add_pallas
from repro.kernels.bitserial.ref import bitserial_add_ref


def bitserial_add(a_planes: jax.Array, b_planes: jax.Array, *,
                  interpret: bool = True, block_r: int = 8,
                  block_c: int = 256) -> jax.Array:
    """(NBITS, R, C) or (NBITS, C) packed planes -> sum planes."""
    a = jnp.asarray(a_planes, jnp.uint32)
    b = jnp.asarray(b_planes, jnp.uint32)
    squeeze = a.ndim == 2
    if squeeze:
        a, b = a[:, None, :], b[:, None, :]
    a, rc = tiling.pad_to_tile(a, block_r, block_c)
    b, _ = tiling.pad_to_tile(b, block_r, block_c)
    out = tiling.crop(
        bitserial_add_pallas(a, b, block_r=block_r, block_c=block_c,
                             interpret=interpret), rc)
    return out[:, 0, :] if squeeze else out


def add_u32(a: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """uint32 element vectors -> uint32 sums, via the bit-plane kernel."""
    a = jnp.asarray(a, jnp.uint32).reshape(-1)
    b = jnp.asarray(b, jnp.uint32).reshape(-1)
    k = a.shape[0]
    pa = bp.pack_uint_elements(a)
    pb = bp.pack_uint_elements(b)
    out = bitserial_add(pa, pb, interpret=interpret)
    return bp.unpack_uint_elements(out, k)


__all__ = ["bitserial_add", "add_u32", "bitserial_add_ref"]
