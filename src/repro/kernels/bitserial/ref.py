"""Pure-jnp oracle for the fused bit-serial adder kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bitserial_add_ref(a_planes: jax.Array, b_planes: jax.Array) -> jax.Array:
    """Ripple-carry addition over bit-planes, LSB-first along axis 0.

    a/b: (NBITS, ...) uint32 packed planes.  Returns sum planes (NBITS, ...)
    (carry-out discarded — fixed-width wraparound like uint arithmetic).
    Each full adder is the §8.1 majority construction:
      carry' = MAJ3(a, b, c);  sum = a ^ b ^ c.
    """
    a = jnp.asarray(a_planes, jnp.uint32)
    b = jnp.asarray(b_planes, jnp.uint32)
    nbits = a.shape[0]
    c = jnp.zeros_like(a[0])
    outs = []
    for i in range(nbits):
        s = a[i] ^ b[i] ^ c
        c = (a[i] & b[i]) | (b[i] & c) | (a[i] & c)
        outs.append(s)
    return jnp.stack(outs)
