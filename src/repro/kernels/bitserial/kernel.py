"""Pallas TPU kernel: fused bit-serial MAJ-based ripple-carry adder (§8.1).

The paper's ADD microbenchmark chains 32 majority-based full adders across
DRAM rows.  The TPU adaptation keeps *all 32 bit-planes of both operands in
VMEM at once* and holds the running carry plane in vector registers across
the (trace-time-unrolled) bit loop — the in-VMEM analogue of the subarray
holding every bit-plane under one set of sense amps.  One HBM round trip
total, instead of one per bit-step as a naive plane-at-a-time translation
would incur (a 32x traffic reduction; see benchmarks/bench_kernels.py).

Block geometry: operands (NBITS, R, C) stream as (NBITS, BR, BC) VMEM
blocks; BC a multiple of 128 lanes, BR of 8 sublanes.  VMEM per block =
2 * NBITS * BR * BC * 4B (+ output), so the default (8, 256) tile holds
3 * 32 * 8 * 256 * 4B = 768 KiB — sized for 16 MiB VMEM with double
buffering headroom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bitserial_add_kernel(a_ref, b_ref, o_ref, *, nbits: int):
    carry = jnp.zeros_like(a_ref[0])
    for i in range(nbits):
        a = a_ref[i]
        b = b_ref[i]
        o_ref[i] = a ^ b ^ carry
        # carry' = MAJ3(a, b, c) — the paper's majority carry.
        carry = (a & b) | (b & carry) | (a & carry)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def bitserial_add_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_r: int = 8,
    block_c: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """a, b: (NBITS, R, C) uint32 planes -> (NBITS, R, C) sum planes."""
    nbits, r, c = a.shape
    grid = (pl.cdiv(r, block_r), pl.cdiv(c, block_c))
    spec = pl.BlockSpec((nbits, block_r, block_c), lambda i, j: (0, i, j))
    return pl.pallas_call(
        functools.partial(bitserial_add_kernel, nbits=nbits),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((nbits, r, c), jnp.uint32),
        interpret=interpret,
    )(a, b)
