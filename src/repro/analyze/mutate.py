"""Seeded mutations of megakernel slot tables — the analyzer's negative gate.

A verifier that has only ever seen correct compiler output is
untested.  This module applies small, *realistic* corruptions to a
:class:`~repro.compile.megakernel.MegaLowering` — each one a bug class
the lowering code could plausibly grow — and CI asserts that
:func:`repro.analyze.cert.certify` rejects every applicable mutation on
every golden fixture (``python -m repro.analyze --mutate``).

Each mutation returns a new lowering (the input is never modified) or
``None`` when the artifact has no site for it (e.g. ``drop_inv`` on a
program without NOT ops).  Mutations prefer sites in the *latest*
applicable level so the corruption survives to the final state and the
equivalence pass cannot be masked by a later overwrite.

The six classes and the finding each must trigger:

==================  ====================================================
``swap_dst``        two slots' destinations exchanged → ``EQ_TABLE_ROW``
``drop_inv``        a NOT slot's invert flag cleared → ``EQ_TABLE_ROW``
``reorder_level``   two dependent levels swapped → stale entry reads
``const_write``     a live slot retargeted at the constant-zero row →
                    ``RACE_CONST_WRITE`` (and clobbered-const dataflow)
``truncate_slot``   a live slot blanked to inert padding → its write
                    vanishes → ``EQ_TABLE_ROW``
``stale_pad``       one constant-one pad operand flipped to zero → the
                    pad pairs no longer cancel → ``EQ_TABLE_ROW``
==================  ====================================================
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

import numpy as np

from repro.analyze.races import _is_inert_slot
from repro.compile.megakernel import (MegaLowering, ONE_ROW, TRASH_ROW,
                                      ZERO_ROW)


def _copy(low: MegaLowering) -> MegaLowering:
    return dataclasses.replace(low, src=low.src.copy(), dst=low.dst.copy(),
                               inv=low.inv.copy())


def _live_slots(low: MegaLowering, reverse: bool = True
                ) -> Iterator[tuple[int, int]]:
    """(level, slot) pairs of non-inert slots, latest level first."""
    levels = range(low.n_levels - 1, -1, -1) if reverse \
        else range(low.n_levels)
    for li in levels:
        for w in range(low.w_max):
            if not _is_inert_slot(low.src[li, w], int(low.dst[li, w]),
                                  int(low.inv[li, w])):
                yield li, w


def _slot_sig(low: MegaLowering, li: int, w: int) -> tuple:
    return (tuple(int(r) for r in low.src[li, w]), int(low.inv[li, w]))


def swap_dst(low: MegaLowering) -> Optional[MegaLowering]:
    """Exchange the destination rows of two differing slots of one level."""
    by_level: dict[int, list[int]] = {}
    for li, w in _live_slots(low):
        by_level.setdefault(li, []).append(w)
    for li in sorted(by_level, reverse=True):
        slots = by_level[li]
        for a in slots:
            for b in slots:
                if (low.dst[li, a] != low.dst[li, b]
                        and _slot_sig(low, li, a) != _slot_sig(low, li, b)):
                    m = _copy(low)
                    m.dst[li, a], m.dst[li, b] = (low.dst[li, b],
                                                  low.dst[li, a])
                    return m
    return None


def drop_inv(low: MegaLowering) -> Optional[MegaLowering]:
    """Clear the invert flag of one NOT slot."""
    for li, w in _live_slots(low):
        if low.inv[li, w]:
            m = _copy(low)
            m.inv[li, w] = 0
            return m
    return None


def reorder_level(low: MegaLowering) -> Optional[MegaLowering]:
    """Swap two adjacent levels that carry a real dataflow dependency.

    Only dependent pairs qualify — swapping independent levels is
    legal, and a mutation the analyzer *should* accept is not a
    negative test.
    """
    for li in range(low.n_levels - 2, -1, -1):
        written = {int(low.dst[li, w]) for li_, w in _live_slots(low)
                   if li_ == li} - {TRASH_ROW}
        reads_next = {int(r) for li_, w in _live_slots(low) if li_ == li + 1
                      for r in low.src[li_, w]}
        if written & reads_next:
            m = _copy(low)
            for arr in (m.src, m.dst, m.inv):
                arr[[li, li + 1]] = arr[[li + 1, li]]
            meta = list(low.level_meta)
            meta[li], meta[li + 1] = meta[li + 1], meta[li]
            return dataclasses.replace(m, level_meta=tuple(meta))
    return None


def const_write(low: MegaLowering) -> Optional[MegaLowering]:
    """Retarget one live slot at the constant-zero row."""
    for li, w in _live_slots(low):
        m = _copy(low)
        m.dst[li, w] = ZERO_ROW
        return m
    return None


def truncate_slot(low: MegaLowering) -> Optional[MegaLowering]:
    """Blank one live slot to inert padding — its write silently vanishes."""
    for li, w in _live_slots(low):
        m = _copy(low)
        m.src[li, w] = ZERO_ROW
        m.dst[li, w] = TRASH_ROW
        m.inv[li, w] = 0
        return m
    return None


def stale_pad(low: MegaLowering) -> Optional[MegaLowering]:
    """Flip one constant-one pad operand to constant-zero.

    Breaks the ``MAJ_k == MAJ_{k+2m}`` padding identity: the popcount
    threshold no longer matches the added constants, so the slot votes
    a different function than its source op.  Real operand rows are
    shifted past the constant prefix, so any ``ONE_ROW`` operand in a
    live slot is padding by construction.
    """
    for li, w in _live_slots(low):
        ones = np.flatnonzero(low.src[li, w] == ONE_ROW)
        if ones.size:
            m = _copy(low)
            m.src[li, w, int(ones[-1])] = ZERO_ROW
            return m
    return None


#: Name -> mutation, in the order CI reports them.
MUTATIONS: dict[str, Callable[[MegaLowering], Optional[MegaLowering]]] = {
    "swap_dst": swap_dst,
    "drop_inv": drop_inv,
    "reorder_level": reorder_level,
    "const_write": const_write,
    "truncate_slot": truncate_slot,
    "stale_pad": stale_pad,
}


def apply_mutation(low: MegaLowering, name: str) -> Optional[MegaLowering]:
    """Apply one named mutation; None when the artifact has no site."""
    return MUTATIONS[name](low)
