"""Findings and reports: the shared result vocabulary of the analyzer.

Every analysis pass (:mod:`repro.analyze.races`,
:mod:`repro.analyze.liveness`, :mod:`repro.analyze.equiv`) emits
:class:`Finding` records instead of raising — so one run can report
*all* defects of an artifact, and the driver (:func:`repro.analyze.cert.
certify`) decides what is fatal.  ``error`` findings block
certification; ``warning`` findings are advisory (dead ops, inferred
inputs, physically questionable activation counts) and are recorded in
the :class:`~repro.analyze.cert.Certificate` pass summary without
failing it.

Codes are stable strings (``RACE_*`` / ``LIVE_*`` / ``EQ_*``) so tests
and CI gates assert on *which* defect was found, not on message
wording.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect (or advisory observation) in one analyzed artifact.

    ``where`` names the artifact region: an op index for program-level
    findings, ``level L / slot W`` for table-level ones, a row index
    for liveness intervals.  ``code`` is the stable machine-readable
    defect class; ``message`` the human explanation.
    """

    pass_name: str          # "race" | "liveness" | "equivalence"
    severity: str           # ERROR | WARNING
    code: str               # stable defect class, e.g. "RACE_WAW_LEVEL"
    message: str
    where: str = ""

    def __str__(self) -> str:
        loc = f" @ {self.where}" if self.where else ""
        return f"[{self.severity}] {self.code}{loc}: {self.message}"


@dataclasses.dataclass
class AnalysisReport:
    """All findings of one analysis run, queryable by severity/pass."""

    subject: str = "program"
    findings: list[Finding] = dataclasses.field(default_factory=list)

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def ok(self) -> bool:
        """True when nothing blocks certification (warnings allowed)."""
        return not self.errors

    def by_pass(self, pass_name: str) -> list[Finding]:
        return [f for f in self.findings if f.pass_name == pass_name]

    def summary(self) -> tuple[tuple[str, int, int], ...]:
        """Deterministic (pass, n_errors, n_warnings) triples.

        The shape frozen into golden-fixture ``certificate`` sections:
        passes appear in canonical order even when clean, so a pass
        silently not running changes the summary (and the digest).
        """
        order = ("race", "liveness", "equivalence")
        extra = sorted({f.pass_name for f in self.findings} - set(order))
        out = []
        for name in (*order, *extra):
            errs = sum(1 for f in self.findings
                       if f.pass_name == name and f.severity == ERROR)
            warns = sum(1 for f in self.findings
                        if f.pass_name == name and f.severity == WARNING)
            out.append((name, errs, warns))
        return tuple(out)

    def render(self, limit: Optional[int] = None) -> str:
        lines = [f"{self.subject}: {len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        shown = self.findings if limit is None else self.findings[:limit]
        lines.extend(f"  {f}" for f in shown)
        if limit is not None and len(self.findings) > limit:
            lines.append(f"  ... {len(self.findings) - limit} more")
        return "\n".join(lines)
