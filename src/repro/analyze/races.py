"""Race detection over Programs, Schedule levels, and lowered slot tables.

The fused executors assume one hazard model — *reads sample the
level-entry state, writes commit at level exit* — and the scheduler's
leveling is what makes that model agree with sequential program order.
This pass re-derives the safety conditions from the artifacts
themselves instead of trusting the compiler:

* **Program ops** (:func:`check_ops`) — the cheap structural pass every
  :func:`repro.session.validate.check_program` call runs: row addresses
  in range, no destination written twice inside one op, MAJ arity
  odd/complete, single-source kinds single-sourced.
* **Schedule levels** (:func:`schedule_findings`) — no two ops of one
  level writing the same row with different values (intra-level WAW;
  identical redundant writes, e.g. one op's duplicated destination
  list, are benign), and no op reading a row that an
  earlier-in-program-order op of the *same* level writes (intra-level
  RAW: the executor would feed it stale entry state).  WAR sharing —
  a writer leveled with earlier readers of its destination — is legal
  by the entry-state model and is not flagged.
* **Slot tables** (:func:`lowering_findings`) — per level of a
  :class:`~repro.compile.megakernel.MegaLowering`: no two live slots
  writing one row (unless they compute the identical vote), no slot
  writing the front constant rows, no live slot reading the trash row,
  all indices inside the augmented image, pad parity intact.

Everything here is pure content inspection — no backend, no state — so
the checks run at compile/cache-insert time in O(ops) / O(slots).
"""

from __future__ import annotations

import collections
from typing import Iterator, Optional

import numpy as np

from repro.analyze.report import ERROR, WARNING, Finding
from repro.compile.megakernel import (MegaLowering, N_CONST_ROWS, ONE_ROW,
                                      TRASH_ROW, ZERO_ROW)
from repro.compile.schedule import Schedule, VALUE_KINDS
from repro.pud.isa import Program, PUDOp

#: Kinds that read exactly one source row when addressed.
SINGLE_SRC = ("NOT", "COPY", "MRC")

#: Every kind the ISA defines (the scheduler raises on anything else;
#: the analyzer reports instead).
KNOWN_KINDS = (*VALUE_KINDS, "FRAC", "WR", "RD")


def _schedulable(op: PUDOp) -> bool:
    """Value-affecting addressed ops — the scheduler's predicate, but
    total: unknown kinds are excluded here and flagged by
    :func:`check_ops` rather than raising mid-analysis."""
    return bool(op.dsts) and op.kind in VALUE_KINDS


def _label(i: int, op: PUDOp) -> str:
    tag = f", tag {op.tag!r}" if op.tag else ""
    return f"op[{i}] {op.kind}{tag}"


# --------------------------------------------------------- program ops


def check_ops(program: Program, n_rows: int,
              where: str = "program") -> list[Finding]:
    """The cheap per-op structural pass (validation-grade, error-level).

    This is the single source of truth behind
    :func:`repro.session.validate.check_program`: the session layer
    raises on any error finding returned here, and the certifier runs
    the same pass so a hand-built Program cannot reach a backend in a
    shape the analyzer would reject.
    """
    out: list[Finding] = []
    for i, op in enumerate(program.ops):
        if op.kind not in KNOWN_KINDS:
            out.append(Finding(
                "race", ERROR, "OP_UNKNOWN_KIND",
                f"{where}: {_label(i, op)} has unknown kind "
                f"{op.kind!r}", where=f"op[{i}]"))
            continue
        if not op.dsts:
            continue  # cost-only record: nothing addressable to check
        for role, addrs in (("source", op.srcs), ("destination", op.dsts)):
            for r in addrs:
                if not 0 <= r < n_rows:
                    out.append(Finding(
                        "race", ERROR, "OP_ROW_RANGE",
                        f"{where}: {_label(i, op)} {role} row {r} is "
                        f"outside the {n_rows}-row subarray image",
                        where=f"op[{i}]"))
        dup = sorted(r for r, c in collections.Counter(op.dsts).items()
                     if c > 1)
        if dup:
            out.append(Finding(
                "race", ERROR, "OP_DUP_DST",
                f"{where}: {_label(i, op)} writes destination row(s) "
                f"{dup} more than once in a single op "
                f"({n_rows}-row subarray image)", where=f"op[{i}]"))
        if op.kind == "MAJ":
            x = op.x or len(op.srcs)
            if x % 2 == 0 or x < 3:
                out.append(Finding(
                    "race", ERROR, "OP_MAJ_ARITY",
                    f"{where}: {_label(i, op)} MAJ arity must be odd "
                    f">= 3, got {x}", where=f"op[{i}]"))
            elif len(op.srcs) != x:
                out.append(Finding(
                    "race", ERROR, "OP_MAJ_OPERANDS",
                    f"{where}: {_label(i, op)} MAJ{x} carries "
                    f"{len(op.srcs)} source rows (needs exactly {x})",
                    where=f"op[{i}]"))
            elif op.n_act and op.n_act < x:
                # Physically underpowered issue (x voting rows need at
                # least x simultaneous activations) — advisory only:
                # grid programs legitimately probe infeasible regimes.
                out.append(Finding(
                    "race", WARNING, "OP_NACT_UNDER_ARITY",
                    f"{where}: {_label(i, op)} MAJ{x} issued with "
                    f"n_act={op.n_act} < arity", where=f"op[{i}]"))
        elif op.kind in SINGLE_SRC and len(op.srcs) != 1:
            out.append(Finding(
                "race", ERROR, "OP_SRC_COUNT",
                f"{where}: {_label(i, op)} takes exactly one source "
                f"row, got {len(op.srcs)}", where=f"op[{i}]"))
    return out


# ----------------------------------------------------- schedule levels


def _value_sig(op: PUDOp) -> tuple:
    """What determines an op's written value under entry-state reads."""
    return (op.kind, op.x, op.srcs)


def iter_level_ops(sched: Schedule, program: Optional[Program] = None
                   ) -> Iterator[tuple[int, list[tuple[int, PUDOp]]]]:
    """Per level: ops annotated with their *program-order* position.

    Group order inside a level is by kind (MAJ, MRC, NOT, COPY), not
    program order, so hazard checks recover the source order from the
    Program: content-equal ops consume ascending program indices (they
    are interchangeable, so the assignment is exact for hazard
    purposes).  Without a program, falls back to schedule order.
    """
    queues: dict[PUDOp, collections.deque[int]] = {}
    if program is not None:
        by_op: dict[PUDOp, collections.deque[int]] = \
            collections.defaultdict(collections.deque)
        for i, op in enumerate(program.ops):
            if _schedulable(op):
                by_op[op].append(i)
        queues = by_op
    counter = 0
    for li, lvl in enumerate(sched.levels):
        annotated: list[tuple[int, PUDOp]] = []
        for g in lvl:
            for op in g.ops:
                if queues and queues.get(op):
                    annotated.append((queues[op].popleft(), op))
                else:
                    annotated.append((counter, op))
                counter += 1
        yield li, sorted(annotated, key=lambda t: t[0])


def schedule_findings(sched: Schedule, program: Optional[Program] = None,
                      where: str = "schedule") -> list[Finding]:
    """Intra-level WAW / RAW races plus op-set completeness vs source."""
    out: list[Finding] = []
    for li, ops in iter_level_ops(sched, program):
        written: dict[int, tuple] = {}       # row -> value signature
        writer: dict[int, int] = {}          # row -> program index
        for pi, op in ops:
            for s in op.srcs:
                if s in written:
                    out.append(Finding(
                        "race", ERROR, "RACE_RAW_LEVEL",
                        f"{where}: level {li} op (program index {pi}, "
                        f"{op.kind}) reads row {s} written earlier in "
                        f"the same level (program index {writer[s]}) — "
                        f"the fused executor would feed it stale "
                        f"level-entry state", where=f"level {li}"))
            for d in op.dsts:
                sig = _value_sig(op)
                if d in written and written[d] != sig:
                    out.append(Finding(
                        "race", ERROR, "RACE_WAW_LEVEL",
                        f"{where}: level {li} has two writers of row "
                        f"{d} with different values (program indices "
                        f"{writer[d]} and {pi}) — level-exit commit "
                        f"order is unspecified", where=f"level {li}"))
                written[d] = sig
                writer[d] = pi
    if program is not None:
        want = collections.Counter(
            op for op in program.ops if _schedulable(op))
        got = collections.Counter(
            op for lvl in sched.levels for g in lvl for op in g.ops)
        if want != got:
            missing = list((want - got).elements())[:3]
            extra = list((got - want).elements())[:3]
            out.append(Finding(
                "race", ERROR, "SCHED_OP_SET",
                f"{where}: scheduled op multiset differs from the "
                f"source program (missing {len(list((want - got).elements()))}, "
                f"extra {len(list((got - want).elements()))}; e.g. "
                f"missing={missing!r} extra={extra!r})"))
    return out


# --------------------------------------------------- lowered slot tables


def _is_inert_slot(src_row: np.ndarray, dst: int, inv: int) -> bool:
    """The padding shape :func:`lower_schedule` emits for unused slots."""
    return (dst == TRASH_ROW and inv == 0
            and bool(((src_row == ZERO_ROW) | (src_row == ONE_ROW)).all()))


def lowering_findings(low: MegaLowering,
                      where: str = "lowering") -> list[Finding]:
    """Structural safety of megakernel level tables (see module doc)."""
    out: list[Finding] = []
    n_aug = low.n_rows + N_CONST_ROWS
    if low.x_max % 2 == 0:
        out.append(Finding(
            "race", ERROR, "TAB_X_PARITY",
            f"{where}: padded vote arity x_max={low.x_max} is even — "
            f"majority is undefined"))
    for li in range(low.n_levels):
        writers: dict[int, tuple] = {}   # row -> (operand tuple, inv)
        for w in range(low.w_max):
            src_row = low.src[li, w]
            dst = int(low.dst[li, w])
            inv = int(low.inv[li, w])
            here = f"level {li} / slot {w}"
            if not 0 <= dst < n_aug:
                out.append(Finding(
                    "race", ERROR, "TAB_DST_RANGE",
                    f"{where}: {here} writes row {dst}, outside the "
                    f"{n_aug}-row augmented image", where=here))
                continue
            bad_src = [int(r) for r in src_row if not 0 <= r < n_aug]
            if bad_src:
                out.append(Finding(
                    "race", ERROR, "TAB_SRC_RANGE",
                    f"{where}: {here} reads row(s) {bad_src}, outside "
                    f"the {n_aug}-row augmented image", where=here))
                continue
            if dst in (ZERO_ROW, ONE_ROW):
                out.append(Finding(
                    "race", ERROR, "RACE_CONST_WRITE",
                    f"{where}: {here} writes constant row {dst} — the "
                    f"0/1 planes every padded vote depends on",
                    where=here))
            inert = _is_inert_slot(src_row, dst, inv)
            if not inert and TRASH_ROW in src_row:
                out.append(Finding(
                    "race", ERROR, "RACE_TRASH_READ",
                    f"{where}: {here} reads the trash row "
                    f"({TRASH_ROW}) outside an inert slot — trash "
                    f"holds garbage from prior levels", where=here))
            if dst == TRASH_ROW:
                continue  # trash collects every inert write; never raced
            sig = (tuple(int(r) for r in src_row), inv)
            if dst in writers and writers[dst] != sig:
                out.append(Finding(
                    "race", ERROR, "RACE_WAW_SLOTS",
                    f"{where}: level {li} has two slots scattering "
                    f"different votes into row {dst} — scatter order "
                    f"within a level is unspecified", where=here))
            writers[dst] = sig
    return out
