"""Certification driver: run every pass, emit a content-hashed Certificate.

:func:`certify` is the one entry point compiles go through: it runs the
race pass over the program, its fused schedule, and (when supplied) its
megakernel lowering, the liveness pass, and the symbolic equivalence
pass, then freezes the outcome into a :class:`Certificate` — a frozen,
JSON-able record whose ``digest`` covers the program content, the
artifact digests, the analyzer version, and the full pass summary.
Golden fixtures pin certificates byte-for-byte, and
:meth:`repro.session.cache.CompileCache.certificate_for` memoizes them
under the program content key, so re-certifying a cached schedule is a
dictionary hit, not a re-analysis.

Any ``error``-severity finding raises :class:`CertificationError`
carrying the whole :class:`~repro.analyze.report.AnalysisReport`;
warnings (dead ops, inferred inputs, advisory activation counts) are
counted in the certificate but do not block it.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Optional

from repro.analyze import equiv, liveness, races
from repro.analyze.report import AnalysisReport
from repro.compile.megakernel import MegaLowering
from repro.compile.schedule import Schedule, build_schedule
from repro.pud.isa import Program

#: Bump when a pass changes meaning: cached/golden certificates from
#: older analyzers must not satisfy newer gates.
ANALYZER_VERSION = 1

#: Error codes after which symbolic execution cannot run safely
#: (out-of-range indices would crash or silently wrap the exec arrays).
_RANGE_CODES = ("OP_ROW_RANGE", "TAB_SRC_RANGE", "TAB_DST_RANGE",
                "OP_UNKNOWN_KIND", "OP_MAJ_ARITY", "OP_MAJ_OPERANDS",
                "OP_SRC_COUNT")


class CertificationError(RuntimeError):
    """A compiled artifact failed static certification."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        super().__init__(report.render(limit=12))


def schedule_digest(sched: Schedule) -> str:
    """Content fingerprint of a Schedule's level/group/op structure."""
    h = hashlib.sha256()
    for lvl in sched.levels:
        for g in lvl:
            h.update(f"{g.kind}|{g.param}\n".encode())
            for op in g.ops:
                h.update(f"{op.kind}|{op.x}|{op.n_act}|{op.srcs}|"
                         f"{op.dsts}\n".encode())
        h.update(b"--\n")
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Certificate:
    """Frozen proof-of-analysis for one compiled program.

    ``summary`` is the deterministic (pass, errors, warnings) triple
    sequence of :meth:`~repro.analyze.report.AnalysisReport.summary`;
    a certificate only exists when every error count is zero.
    ``lowering_digest`` is None when the program was certified for
    fused execution only — asking for megakernel certification later
    upgrades the cached entry (see ``CompileCache.certificate_for``).
    """

    program_key: str
    schedule_digest: str
    lowering_digest: Optional[str]
    n_ops: int
    n_rows: int
    n_levels: int
    summary: tuple[tuple[str, int, int], ...]
    analyzer_version: int = ANALYZER_VERSION

    @property
    def covers_lowering(self) -> bool:
        return self.lowering_digest is not None

    @property
    def digest(self) -> str:
        h = hashlib.sha256()
        h.update(f"{self.program_key}|{self.schedule_digest}|"
                 f"{self.lowering_digest}|{self.n_ops}|{self.n_rows}|"
                 f"{self.n_levels}|v{self.analyzer_version}\n".encode())
        for name, errs, warns in self.summary:
            h.update(f"{name}:{errs}:{warns}\n".encode())
        return h.hexdigest()

    def to_dict(self) -> dict:
        """JSON form (golden ``certificate`` sections, CLI output)."""
        return {
            "digest": self.digest,
            "program_key": self.program_key,
            "schedule_digest": self.schedule_digest,
            "lowering_digest": self.lowering_digest,
            "n_ops": self.n_ops,
            "n_rows": self.n_rows,
            "n_levels": self.n_levels,
            "analyzer_version": self.analyzer_version,
            "passes": {name: {"errors": e, "warnings": w}
                       for name, e, w in self.summary},
        }


def analyze(program: Program, *, sched: Optional[Schedule] = None,
            lowering: Optional[MegaLowering] = None,
            n_rows: Optional[int] = None,
            inputs: Optional[Iterable[int]] = None,
            outputs: Optional[Iterable[int]] = None,
            where: str = "program") -> AnalysisReport:
    """Run every pass; never raises — inspect ``report.ok``.

    ``sched`` defaults to a fresh :func:`build_schedule` of the program
    (callers holding a cached schedule pass it to pin *that* artifact).
    ``lowering`` is analyzed only when given.  ``n_rows`` overrides the
    image height for range checks (defaults to the program's own).
    """
    report = AnalysisReport(subject=where)
    rows = n_rows if n_rows is not None else program.n_rows()
    report.extend(races.check_ops(program, rows, where=where))
    report.extend(liveness.liveness_findings(
        program, inputs=inputs, outputs=outputs, where=where))

    if sched is None:
        unsafe = {f.code for f in report.errors} & set(_RANGE_CODES)
        if not unsafe:
            sched = build_schedule(program)
    if sched is not None:
        report.extend(races.schedule_findings(sched, program, where=where))
    if lowering is not None:
        report.extend(races.lowering_findings(lowering, where=where))

    # Symbolic execution indexes arrays by the recorded rows — only
    # sound once every range/shape error class is clear.
    if not ({f.code for f in report.errors} & set(_RANGE_CODES)):
        report.extend(equiv.equivalence_findings(
            program, sched, lowering, where=where))
    return report


def certify(program: Program, *, sched: Optional[Schedule] = None,
            lowering: Optional[MegaLowering] = None,
            inputs: Optional[Iterable[int]] = None,
            outputs: Optional[Iterable[int]] = None,
            where: str = "program",
            key: Optional[str] = None) -> Certificate:
    """Analyze and, if clean, freeze a :class:`Certificate`.

    Raises :class:`CertificationError` (with the full report) on any
    error finding.  ``key`` optionally supplies a precomputed program
    content key to skip re-hashing.
    """
    from repro.session.cache import program_key as _pk

    if sched is None:
        sched = build_schedule(program)
    report = analyze(program, sched=sched, lowering=lowering,
                     inputs=inputs, outputs=outputs, where=where)
    if not report.ok:
        raise CertificationError(report)
    return Certificate(
        program_key=key or _pk(program),
        schedule_digest=schedule_digest(sched),
        lowering_digest=lowering.digest() if lowering is not None else None,
        n_ops=len(program.ops),
        n_rows=program.n_rows(),
        n_levels=sched.n_levels,
        summary=report.summary())
