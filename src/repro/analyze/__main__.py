"""``python -m repro.analyze`` — lint and certify the repo's real programs.

Subjects (combine freely; ``--all`` is every subject plus the negative
mutation gate and the certificate-cache check):

* ``--golden``  — every ``tests/golden/*.json`` fixture program,
  certified against a freshly built schedule AND megakernel lowering;
  when the fixture carries a frozen ``certificate`` section, the
  recomputed digest must match it byte-for-byte.
* ``--serve``   — the heal and erase tick programs the serve batcher
  actually builds (captured from a real
  :class:`~repro.serve.batcher.Batcher` tick on the oracle backend).
* ``--sweep``   — the fused MAJX chunk programs of the smoke sweep
  spec, as planned by :func:`repro.sweep.planner.plan`.
* ``--mutate``  — the negative gate: every applicable seeded mutation
  (:mod:`repro.analyze.mutate`) of every golden lowering must be
  *rejected*; an accepted mutation is a hole in the analyzer and fails
  the run.
* ``--cache-check`` — certify one golden program twice through a fresh
  :class:`~repro.session.cache.CompileCache` and assert the second
  lookup is a pure cache hit (zero re-analysis).

Exit status is nonzero on any error finding, digest mismatch, accepted
mutation, or missed cache hit — ``scripts/ci.sh`` runs ``--all`` as the
analyzer gate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analyze.cert import CertificationError, certify
from repro.analyze.mutate import MUTATIONS
from repro.compile.megakernel import lower_schedule
from repro.compile.schedule import build_schedule
from repro.pud.isa import Program


def _golden_dir(override: str = "") -> str:
    if override:
        return override
    return os.path.join(os.getcwd(), "tests", "golden")


def _load_golden(path: str) -> tuple[str, Program, dict]:
    with open(path) as f:
        doc = json.load(f)
    return doc["name"], Program.from_json(json.dumps(doc["ops"])), doc


def _certify_one(name: str, prog: Program, *, verbose: bool,
                 frozen: dict | None = None) -> bool:
    """Certify prog (schedule + lowering); print one line; True on OK."""
    sched = build_schedule(prog)
    low = lower_schedule(sched)
    try:
        cert = certify(prog, sched=sched, lowering=low, where=name)
    except CertificationError as e:
        print(f"FAIL {name}")
        print("  " + "\n  ".join(str(f) for f in e.report.errors[:10]))
        return False
    warns = sum(w for _, _, w in cert.summary)
    print(f"OK   {name}: {cert.n_ops} ops / {cert.n_levels} levels, "
          f"{warns} warning(s), cert {cert.digest[:12]}")
    if verbose:
        for pname, errs, ws in cert.summary:
            print(f"       {pname}: {errs} error(s), {ws} warning(s)")
    if frozen is not None and frozen.get("digest") != cert.digest:
        print(f"FAIL {name}: frozen certificate digest "
              f"{frozen.get('digest', '?')[:12]} != recomputed "
              f"{cert.digest[:12]} — regenerate tests/golden or fix "
              f"the analyzer drift")
        return False
    return True


def _golden_programs(golden_dir: str) -> list[tuple[str, Program, dict]]:
    paths = sorted(
        os.path.join(golden_dir, p) for p in os.listdir(golden_dir)
        if p.endswith(".json"))
    return [_load_golden(p) for p in paths]


def lint_golden(golden_dir: str, verbose: bool) -> bool:
    ok = True
    for name, prog, doc in _golden_programs(golden_dir):
        ok &= _certify_one(f"golden/{name}", prog, verbose=verbose,
                           frozen=doc.get("certificate"))
    return ok


def lint_serve(verbose: bool) -> bool:
    """Certify the tick programs a real Batcher builds (oracle backend)."""
    import numpy as np

    from repro.backends import ExecutionContext
    from repro.serve.batcher import Batcher
    from repro.serve.queue import EraseRequest, HealRequest
    from repro.session import DramSession

    session = DramSession("oracle", ExecutionContext(ideal=True),
                          name="analyze/serve")
    captured: list[tuple[str, Program]] = []
    inner = session.run_fused

    def run_fused(prog, state, **kw):
        captured.append((prog.ops[0].tag or "tick", prog))
        return inner(prog, state, **kw)

    session.run_fused = run_fused  # capture the real construction path
    rng = np.random.default_rng(7)
    heal = [HealRequest(tenant=f"t{i}", replicas=rng.integers(
        0, 2**32, (3, 2, 4), dtype=np.uint32)) for i in range(3)]
    erase = [EraseRequest(tenant=f"t{i}", rows=5, words=4, pattern=0,
                          fanout=4) for i in range(2)]
    batcher = Batcher()
    for plan in batcher.plan([*heal, *erase]):
        batcher.execute(plan, session)

    ok = bool(captured)
    if not captured:
        print("FAIL serve: no tick programs captured")
    for i, (tag, prog) in enumerate(captured):
        ok &= _certify_one(f"serve/tick{i}[{tag}]", prog, verbose=verbose)
    return ok


def lint_sweep(verbose: bool) -> bool:
    """Certify the fused chunk programs of the smoke sweep spec."""
    from repro.sweep.planner import fused_majx_program, plan
    from repro.sweep.presets import smoke_spec

    spec = smoke_spec()
    ok = True
    seen: set[str] = set()
    for chunk in plan(spec):
        prog, _ = fused_majx_program(chunk.points, spec.rows)
        from repro.session.cache import program_key
        key = program_key(prog)
        if key in seen:
            continue  # same chunk shape across backends — one lint
        seen.add(key)
        ok &= _certify_one(f"sweep/{spec.name}/{chunk.key}", prog,
                           verbose=verbose)
    return ok


def mutation_gate(golden_dir: str, verbose: bool) -> bool:
    """Every applicable seeded mutation must be rejected on every fixture."""
    ok = True
    applied: dict[str, int] = {m: 0 for m in MUTATIONS}
    rejected: dict[str, int] = {m: 0 for m in MUTATIONS}
    for name, prog, _ in _golden_programs(golden_dir):
        sched = build_schedule(prog)
        low = lower_schedule(sched)
        for mname, fn in MUTATIONS.items():
            bad = fn(low)
            if bad is None:
                continue  # no site on this fixture (e.g. no NOT ops)
            applied[mname] += 1
            try:
                certify(prog, sched=sched, lowering=bad,
                        where=f"{name}+{mname}")
                print(f"FAIL mutate/{name}+{mname}: corrupted lowering "
                      f"was certified — analyzer hole")
                ok = False
            except CertificationError as e:
                rejected[mname] += 1
                if verbose:
                    codes = sorted({f.code for f in e.report.errors})
                    print(f"     {name}+{mname}: rejected via {codes}")
    for mname in MUTATIONS:
        if applied[mname] == 0:
            print(f"FAIL mutate/{mname}: applicable to zero fixtures — "
                  f"the negative gate never exercised it")
            ok = False
        else:
            print(f"OK   mutate/{mname}: rejected "
                  f"{rejected[mname]}/{applied[mname]} seeded corruption(s)")
    return ok


def cache_check(golden_dir: str) -> bool:
    """Repeat certification of a cached program must be zero re-analysis."""
    from repro.session.cache import CompileCache

    name, prog, _ = _golden_programs(golden_dir)[0]
    cache = CompileCache()
    sched = cache.schedule_for(prog)
    low = cache.lowering_for(prog, sched=sched)
    first = cache.certificate_for(prog, sched=sched, lowering=low)
    again = cache.certificate_for(prog, sched=sched, lowering=low)
    stats = cache.certificate_stats
    if stats.hits != 1 or stats.misses != 1 or first is not again:
        print(f"FAIL cache: expected 1 miss + 1 hit, got "
              f"{stats.misses} miss(es) + {stats.hits} hit(s)")
        return False
    print(f"OK   cache: {name} re-certification was a pure hit "
          f"(cert {first.digest[:12]}, 1 miss + 1 hit)")
    return True


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Certify the repo's PUD programs and compiled "
                    "artifacts (races / liveness / equivalence).")
    ap.add_argument("--golden", action="store_true",
                    help="lint every tests/golden fixture")
    ap.add_argument("--serve", action="store_true",
                    help="lint the serve batcher's tick programs")
    ap.add_argument("--sweep", action="store_true",
                    help="lint the smoke sweep's chunk programs")
    ap.add_argument("--mutate", action="store_true",
                    help="negative gate: seeded mutations must be rejected")
    ap.add_argument("--cache-check", action="store_true",
                    help="assert repeat certification is a pure cache hit")
    ap.add_argument("--all", action="store_true",
                    help="every subject plus the mutation and cache gates")
    ap.add_argument("--golden-dir", default="",
                    help="override the golden fixture directory")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if not any((args.golden, args.serve, args.sweep, args.mutate,
                args.cache_check, args.all)):
        args.all = True

    golden_dir = _golden_dir(args.golden_dir)
    ok = True
    if args.golden or args.all:
        ok &= lint_golden(golden_dir, args.verbose)
    if args.serve or args.all:
        ok &= lint_serve(args.verbose)
    if args.sweep or args.all:
        ok &= lint_sweep(args.verbose)
    if args.mutate or args.all:
        ok &= mutation_gate(golden_dir, args.verbose)
    if args.cache_check or args.all:
        ok &= cache_check(golden_dir)
    print("analyze: all gates passed" if ok
          else "analyze: FAILURES (see above)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
