"""Dataflow-equivalence certification by symbolic execution.

The differential suites sample random programs; this pass *proves* a
specific compiled artifact.  All three execution forms of a program —
the sequential op stream, the hazard-leveled
:class:`~repro.compile.schedule.Schedule`, and the megakernel
:class:`~repro.compile.megakernel.MegaLowering` slot tables — are
symbolically executed over an abstract dataflow domain, and the final
per-row values must be *structurally identical* terms.

The domain is a hash-consed term algebra:

* ``Input(r)`` — row ``r``'s initial-state value,
* ``Const0`` / ``Const1`` — the all-zero / all-one planes,
* ``Not(v)`` — bitwise complement, with ``Not(Not(v)) = v`` and
  constant folding,
* ``Maj(v_1..v_k)`` — bit-position majority, canonicalized by operand
  *sort* (majority is symmetric; duplicates are preserved — input
  replication is semantically meaningful), with two sound rewrites:

  - **arity-padding cancellation**: matched (Const0, Const1) operand
    pairs are removed — the exact
    ``MAJ_k == MAJ_{k+2m}(.., 0*m, 1*m)`` identity the fused and
    megakernel paths rely on (each pair adds one to the popcount and
    one to the threshold);
  - **identity collapse**: a 1-ary majority is its operand (how the
    MRC/COPY/NOT arity-1 expansion slots certify), and an all-constant
    majority folds to its constant.

Every rewrite is a true identity of the concrete semantics, so equal
normal forms imply bit-equal execution on every backend; the rewrites
are exactly the transformations the compiler performs, so the correct
compiler output always normalizes back onto the source program's terms
— any surviving structural difference is a genuine compilation bug
(or an injected mutation: see :mod:`repro.analyze.mutate`).

Hazard semantics match the executors: schedule and table execution
read the *level-entry* state and commit writes at level exit, while
the sequential reference commits op by op.  A leveling bug therefore
shows up as a term mismatch here even if the race pass missed it.
"""

from __future__ import annotations

from typing import Optional

from repro.analyze.report import ERROR, Finding
from repro.compile.megakernel import (MegaLowering, N_CONST_ROWS, ONE_ROW,
                                      ZERO_ROW)
from repro.compile.schedule import Schedule
from repro.pud.isa import Program

_SKIP_KINDS = ("FRAC", "WR", "RD")


class SymbolicDomain:
    """Hash-consed term interner: structural equality is id equality."""

    def __init__(self):
        self._ids: dict[tuple, int] = {}
        self._terms: list[tuple] = []
        self.const0 = self._intern(("const", 0))
        self.const1 = self._intern(("const", 1))

    def _intern(self, key: tuple) -> int:
        vid = self._ids.get(key)
        if vid is None:
            vid = len(self._terms)
            self._ids[key] = vid
            self._terms.append(key)
        return vid

    # ----------------------------------------------------- constructors
    def input(self, row: int) -> int:
        return self._intern(("in", row))

    def not_(self, v: int) -> int:
        if v == self.const0:
            return self.const1
        if v == self.const1:
            return self.const0
        term = self._terms[v]
        if term[0] == "not":
            return term[1]           # Not(Not(v)) = v
        return self._intern(("not", v))

    def maj(self, operands: tuple[int, ...]) -> int:
        """Canonical majority term (see module docstring rewrites)."""
        ops = list(operands)
        # Arity-padding cancellation: drop matched (0, 1) pairs.
        pairs = min(ops.count(self.const0), ops.count(self.const1))
        for _ in range(pairs):
            ops.remove(self.const0)
            ops.remove(self.const1)
        if not ops:
            raise ValueError("majority over zero operands")
        if len(ops) == 1:
            return ops[0]            # MAJ_1(v) = v (identity slots)
        consts = {self.const0, self.const1}
        if all(o in consts for o in ops):
            ones = sum(1 for o in ops if o == self.const1)
            return self.const1 if 2 * ones > len(ops) else self.const0
        return self._intern(("maj", tuple(sorted(ops))))

    def render(self, v: int, depth: int = 3) -> str:
        """Short human form of a term, for finding messages."""
        kind, *rest = self._terms[v]
        if kind == "const":
            return str(rest[0])
        if kind == "in":
            return f"in[{rest[0]}]"
        if depth <= 0:
            return "..."
        if kind == "not":
            return f"~{self.render(rest[0], depth - 1)}"
        args = ", ".join(self.render(o, depth - 1) for o in rest[0][:5])
        more = ", ..." if len(rest[0]) > 5 else ""
        return f"maj({args}{more})"


def _apply_op(dom: SymbolicDomain, op, read) -> Optional[int]:
    """The value an op writes to every destination, reading via ``read``."""
    if not op.dsts or op.kind in _SKIP_KINDS:
        return None
    if op.kind == "MAJ":
        return dom.maj(tuple(read(s) for s in op.srcs))
    if op.kind == "NOT":
        return dom.not_(read(op.srcs[0]))
    if op.kind in ("COPY", "MRC"):
        return read(op.srcs[0])
    return None  # unknown kinds are reported by the race pass


def exec_program(dom: SymbolicDomain, program: Program,
                 n_rows: Optional[int] = None) -> list[int]:
    """Sequential symbolic execution — the reference dataflow."""
    n = n_rows if n_rows is not None else program.n_rows()
    state = [dom.input(r) for r in range(n)]
    for op in program.ops:
        v = _apply_op(dom, op, lambda s: state[s])
        if v is None:
            continue
        for d in op.dsts:
            state[d] = v
    return state


def exec_schedule(dom: SymbolicDomain, sched: Schedule,
                  n_rows: int) -> list[int]:
    """Level-at-a-time execution: entry-state reads, exit commits."""
    state = [dom.input(r) for r in range(n_rows)]
    for lvl in sched.levels:
        entry = list(state)
        for g in lvl:
            for op in g.ops:
                v = _apply_op(dom, op, lambda s: entry[s])
                if v is None:
                    continue
                for d in op.dsts:
                    state[d] = v
    return state


def exec_lowering(dom: SymbolicDomain, low: MegaLowering) -> list[int]:
    """Slot-table execution over the augmented (const-prefixed) image.

    Returns the augmented row values; program row ``r`` lives at index
    ``r + N_CONST_ROWS``.  The trash row participates (inert slots
    write it) but is excluded from comparison by the caller.
    """
    state = [dom.const0, dom.const1, dom.const0]   # zero / one / trash
    state += [dom.input(r) for r in range(low.n_rows)]
    for li in range(low.n_levels):
        entry = list(state)
        for w in range(low.w_max):
            operands = tuple(entry[int(r)] for r in low.src[li, w])
            v = dom.maj(operands)
            if low.inv[li, w]:
                v = dom.not_(v)
            state[int(low.dst[li, w])] = v
    return state


def equivalence_findings(program: Program, sched: Optional[Schedule] = None,
                         lowering: Optional[MegaLowering] = None, *,
                         where: str = "program") -> list[Finding]:
    """Prove schedule / lowering dataflow equal to the source program.

    One shared :class:`SymbolicDomain` interns all three executions, so
    comparison is integer equality per row.  Findings carry rendered
    terms for the first few mismatching rows.
    """
    out: list[Finding] = []
    dom = SymbolicDomain()
    n_rows = program.n_rows()
    ref = exec_program(dom, program, n_rows)

    if sched is not None:
        got = exec_schedule(dom, sched, n_rows)
        for r in range(n_rows):
            if got[r] != ref[r]:
                out.append(Finding(
                    "equivalence", ERROR, "EQ_SCHEDULE_ROW",
                    f"{where}: schedule computes row {r} = "
                    f"{dom.render(got[r])}, program computes "
                    f"{dom.render(ref[r])}", where=f"row {r}"))

    if lowering is not None:
        if lowering.n_rows != n_rows:
            out.append(Finding(
                "equivalence", ERROR, "EQ_TABLE_SHAPE",
                f"{where}: lowering covers {lowering.n_rows} program "
                f"rows, program addresses {n_rows}"))
            return out
        aug = exec_lowering(dom, lowering)
        if aug[ZERO_ROW] != dom.const0 or aug[ONE_ROW] != dom.const1:
            out.append(Finding(
                "equivalence", ERROR, "EQ_CONST_CLOBBERED",
                f"{where}: a slot overwrote the constant 0/1 rows — "
                f"every later padded vote is corrupted"))
        for r in range(n_rows):
            if aug[r + N_CONST_ROWS] != ref[r]:
                out.append(Finding(
                    "equivalence", ERROR, "EQ_TABLE_ROW",
                    f"{where}: level tables compute row {r} = "
                    f"{dom.render(aug[r + N_CONST_ROWS])}, program "
                    f"computes {dom.render(ref[r])}", where=f"row {r}"))
    # TRASH_ROW deliberately uncompared: it is the inert-slot sink.
    return out
