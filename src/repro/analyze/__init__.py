"""Static analysis of PUD programs and their compiled artifacts.

Three passes over the compile pipeline's three artifact forms
(:class:`~repro.pud.isa.Program` op streams, fused
:class:`~repro.compile.schedule.Schedule` levels, megakernel
:class:`~repro.compile.megakernel.MegaLowering` slot tables):

* **races** (:mod:`repro.analyze.races`) — structural op validation
  plus intra-level RAW/WAW hazards and slot-table safety (constant-row
  writes, trash-row reads, conflicting scatters);
* **liveness** (:mod:`repro.analyze.liveness`) — per-row lifetime
  intervals, dead ops, inferred inputs, and
  :class:`~repro.session.rows.RowAllocator` audits (use-after-free,
  leaks);
* **equivalence** (:mod:`repro.analyze.equiv`) — symbolic execution
  over a hash-consed term algebra proving schedule and level tables
  compute exactly the source program's dataflow (including the MAJ
  arity-padding and MRC/COPY/NOT expansion identities).

:func:`certify` drives all three and freezes a content-hashed
:class:`Certificate`; :class:`~repro.session.cache.CompileCache`
memoizes certificates so every :class:`~repro.session.DramSession`
execution is certified at one-analysis-per-program-content cost.
``python -m repro.analyze`` lints the golden fixtures, serve tick
programs, and sweep chunk programs, and runs the seeded-mutation
negative gate (:mod:`repro.analyze.mutate`).
"""

from repro.analyze.cert import (ANALYZER_VERSION, Certificate,
                                CertificationError, analyze, certify,
                                schedule_digest)
from repro.analyze.equiv import (SymbolicDomain, equivalence_findings,
                                 exec_lowering, exec_program, exec_schedule)
from repro.analyze.liveness import (RowLifetime, allocator_findings,
                                    lifetimes, liveness_findings)
from repro.analyze.mutate import MUTATIONS, apply_mutation
from repro.analyze.races import (check_ops, iter_level_ops,
                                 lowering_findings, schedule_findings)
from repro.analyze.report import (ERROR, WARNING, AnalysisReport, Finding)

__all__ = [
    "ANALYZER_VERSION", "AnalysisReport", "Certificate",
    "CertificationError", "ERROR", "Finding", "MUTATIONS", "RowLifetime",
    "SymbolicDomain", "WARNING", "allocator_findings", "analyze",
    "apply_mutation", "certify", "check_ops", "equivalence_findings",
    "exec_lowering", "exec_program", "exec_schedule", "iter_level_ops",
    "lifetimes", "liveness_findings", "lowering_findings",
    "schedule_digest", "schedule_findings",
]
