"""Row-lifetime analysis: intervals, dead ops, leaks, use-after-free.

A PUD program's rows are a manually-managed resource — the §8.1 traces
stream through dozens of SSA scratch rows, serve tenants draw on
bounded :class:`~repro.session.rows.RowAllocator` arenas, and nothing
until now reported which rows a compiled artifact actually *uses*.
This pass computes per-row lifetime intervals over the op stream:

``first_write`` / ``last_write`` / ``first_read`` / ``last_read`` per
row (op indices), from which it derives

* **dead ops** — value-affecting ops none of whose written rows are
  ever read afterwards nor listed in ``outputs`` (warning: the
  executors deliberately still run them, but a compiled artifact full
  of dead votes is paying activations for nothing);
* **inferred inputs** — rows read before any write hold initial-state
  values; with an explicit ``inputs`` set, reading an undeclared row
  before writing it is an **error** (the SSA tracers declare exactly
  their bound input rows);
* **allocator audit** (:func:`allocator_findings`) — references to
  rows sitting on a :class:`~repro.session.rows.RowAllocator` free
  list are use-after-free **errors** (a freed index will be handed to
  the next reservation — the cross-tenant aliasing bug class), refs
  past the high-water mark are errors, and in-use rows the program
  never touches are leak warnings.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, TYPE_CHECKING

from repro.analyze.report import ERROR, WARNING, Finding
from repro.pud.isa import Program

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids layer cycle
    from repro.session.rows import RowAllocator


@dataclasses.dataclass
class RowLifetime:
    """Op-index interval of one row's activity (None = never)."""

    row: int
    first_write: Optional[int] = None
    last_write: Optional[int] = None
    first_read: Optional[int] = None
    last_read: Optional[int] = None

    @property
    def used(self) -> bool:
        return self.first_write is not None or self.first_read is not None

    @property
    def read_before_write(self) -> bool:
        """True when the row's initial value is observed."""
        if self.first_read is None:
            return False
        return self.first_write is None or self.first_read < self.first_write


#: Value-neutral kinds: they disturb cells / record cost but never
#: change a row's logical value, so they are invisible to dataflow.
_NEUTRAL_KINDS = ("FRAC", "WR", "RD")


def lifetimes(program: Program) -> dict[int, RowLifetime]:
    """Per-row lifetime intervals over the addressed op stream.

    Only value-affecting addressed ops register: a FRAC disturb
    "write" must not mask a genuine read-before-write on the same row.
    """
    lt: dict[int, RowLifetime] = {}

    def _at(r: int) -> RowLifetime:
        if r not in lt:
            lt[r] = RowLifetime(r)
        return lt[r]

    for i, op in enumerate(program.ops):
        if not op.dsts or op.kind in _NEUTRAL_KINDS:
            continue
        for s in op.srcs:
            row = _at(s)
            if row.first_read is None:
                row.first_read = i
            row.last_read = i
        for d in op.dsts:
            row = _at(d)
            if row.first_write is None:
                row.first_write = i
            row.last_write = i
    return lt


def liveness_findings(program: Program, *,
                      inputs: Optional[Iterable[int]] = None,
                      outputs: Optional[Iterable[int]] = None,
                      where: str = "program") -> list[Finding]:
    """Dead ops and initial-state reads (see module docstring)."""
    out: list[Finding] = []
    lt = lifetimes(program)
    out_rows = set(outputs) if outputs is not None else None
    in_rows = set(inputs) if inputs is not None else None

    for i, op in enumerate(program.ops):
        if not op.dsts or op.kind in _NEUTRAL_KINDS:
            continue
        live = False
        for d in op.dsts:
            row = lt[d]
            if row.last_read is not None and row.last_read > i:
                live = True       # someone reads this row later
            elif row.last_write == i and (out_rows is None
                                          or d in out_rows):
                # Last writer of the row: live unless the caller gave
                # an explicit output set that excludes it.  Without
                # outputs, final state is compared wholesale (the
                # differential suites), so last writes count as live.
                live = True
            if live:
                break
        if not live:
            tag = f", tag {op.tag!r}" if op.tag else ""
            out.append(Finding(
                "liveness", WARNING, "LIVE_DEAD_OP",
                f"{where}: op[{i}] {op.kind}{tag} writes row(s) "
                f"{list(op.dsts)} that nothing reads afterwards",
                where=f"op[{i}]"))

    for r in sorted(lt):
        row = lt[r]
        if not row.read_before_write:
            continue
        if in_rows is not None and r not in in_rows:
            out.append(Finding(
                "liveness", ERROR, "LIVE_UNDECLARED_INPUT",
                f"{where}: row {r} is read (op[{row.first_read}]) "
                f"before any write but is not a declared input row",
                where=f"row {r}"))
    return out


def allocator_findings(program: Program, allocator: "RowAllocator", *,
                       where: str = "program") -> list[Finding]:
    """Audit a program against the allocator that owns its row space.

    Catches the handle-lifecycle bugs the serve layer's tenant arenas
    are exposed to: an op referencing a *freed* row (use-after-free —
    that index will alias the next reservation), references past the
    allocator's high-water mark, and reserved rows the program never
    touches (leaks against a bounded arena budget).
    """
    out: list[Finding] = []
    freed = set(allocator.free_rows)
    high = allocator.n_rows
    referenced: set[int] = set()
    for i, op in enumerate(program.ops):
        if not op.dsts:
            continue
        for r in (*op.srcs, *op.dsts):
            referenced.add(r)
            if r in freed:
                out.append(Finding(
                    "liveness", ERROR, "LIVE_USE_AFTER_FREE",
                    f"{where}: op[{i}] {op.kind} references row {r}, "
                    f"which sits on {allocator.name}'s free list — a "
                    f"later reservation will alias it",
                    where=f"op[{i}]"))
            elif r >= high:
                out.append(Finding(
                    "liveness", ERROR, "LIVE_UNALLOCATED",
                    f"{where}: op[{i}] {op.kind} references row {r}, "
                    f"past {allocator.name}'s high-water mark "
                    f"({high} rows allocated)", where=f"op[{i}]"))
    leaked = sorted(set(range(high)) - freed - referenced)
    if leaked:
        out.append(Finding(
            "liveness", WARNING, "LIVE_LEAKED_ROWS",
            f"{where}: {len(leaked)} reserved row(s) never referenced "
            f"by the program (e.g. {leaked[:8]}) — still charged "
            f"against {allocator.name}'s budget"))
    return out
