"""``repro.session``: the typed entry point for executing PUD work.

The paper's workloads — MAJX trees, Multi-RowCopy waves, §8.1
bit-serial arithmetic — are programs over subarray rows, and (PULSAR
-style) their value comes from *composing and re-running* those
programs.  :class:`DramSession` packages what every consumer needs for
that: a resolved backend + :class:`~repro.backends.context.
ExecutionContext`, typed :class:`Row`/:class:`PlaneGroup` allocation
with build-time validation, automatic lowering through
:mod:`repro.compile`, and a content-hashed :class:`CompileCache` so a
repeated program skips straight to fused execution.

>>> from repro.session import DramSession
>>> sess = DramSession("pallas")                # or "oracle" / "sim"
>>> b = sess.program(rows=8)
>>> ops = b.input(planes)                       # typed row handles
>>> out = b.maj(ops[0], ops[1], ops[2])
>>> final = b.run()                             # validate -> cache -> fuse
>>> vals, prog = sess.elementwise("add", a, b_) # §8.1, compile-cached

``repro.backends.get_backend`` remains as the compat layer underneath;
sessions are how examples, the serve engine's integrity hooks, the
sweep runner, and the bench harness execute.
"""

from repro.session.builder import SessionProgram
from repro.session.cache import CacheStats, CompileCache, program_key
from repro.session.rows import (PlaneGroup, Row, RowAllocationError,
                                RowAllocator, SessionError)
from repro.session.session import DramSession
from repro.session.validate import ProgramValidationError, check_program

__all__ = [
    "CacheStats", "CompileCache", "DramSession", "PlaneGroup",
    "ProgramValidationError", "Row", "RowAllocationError", "RowAllocator",
    "SessionError", "SessionProgram", "check_program", "program_key",
]
