"""Content-hashed compile cache: schedule a Program once, run it forever.

Fused execution pays a host-side compile step per program —
:func:`repro.compile.schedule.build_schedule` levels the op stream and
groups each level's dispatches.  The workloads that matter repeat the
*same* program many times (serve ``heal_params`` votes every epoch,
sweep chunks share one chunk shape, ``pud.arith`` executors re-run a
traced adder per batch), so :class:`CompileCache` memoizes schedules by
program *content*: a SHA-256 over every op's semantic fields — kind,
arity, activation count, row addresses — deliberately excluding the
provenance ``tag``, which executors never read.  Two sweep chunks whose
ops differ only in point-index tags therefore share one schedule.

A :class:`~repro.compile.schedule.Schedule` is a pure function of that
content (frozen dataclasses, no backend state), so one cache can be
shared across sessions — the sweep runner shares a process-wide cache
across its per-chunk sessions, and the serve layer's session pool
shares one across concurrent request batches.  Lookups are serialized
by a lock (build included), so N concurrent submissions of one program
shape are exactly 1 miss + N-1 hits — never N racing builds.
``stats`` records hits/misses; the bench harnesses report the hit rate
in ``BENCH_fused.json`` / ``BENCH_serve.json``.

Megakernel artifacts cache under the *same* content key: a
:class:`~repro.compile.megakernel.MegaLowering` is a pure function of
the schedule, which is a pure function of program content, so
:meth:`CompileCache.lowering_for` keys its table store by
``program_key`` too.  Lowerings keep separate ``lowering_stats`` —
schedule hit/miss counts are load-bearing in the serve tests and must
not move when a consumer opts into megakernel mode.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
from typing import Optional, TYPE_CHECKING

from repro.compile.schedule import Schedule, build_schedule
from repro.pud.isa import Program

if TYPE_CHECKING:  # pragma: no cover - import cycle guard for hints only
    from repro.analyze.cert import Certificate
    from repro.compile.megakernel import MegaLowering


def program_key(program: Program) -> str:
    """Content hash of a Program's semantic fields (tags excluded)."""
    h = hashlib.sha256()
    for op in program.ops:
        h.update(
            f"{op.kind}|{op.x}|{op.n_act}|{op.srcs}|{op.dsts}\n".encode())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    """Hit/miss counters, comparable across snapshots for windowing."""

    hits: int = 0
    misses: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)

    def delta(self, earlier: "CacheStats") -> "CacheStats":
        """Stats accumulated since an ``earlier`` :meth:`snapshot`."""
        return CacheStats(hits=self.hits - earlier.hits,
                          misses=self.misses - earlier.misses)


class CompileCache:
    """LRU cache: ``program_key`` -> built :class:`Schedule`.

    A second LRU store under the same keys holds megakernel
    :class:`~repro.compile.megakernel.MegaLowering` tables
    (:meth:`lowering_for`), with its own ``lowering_stats`` window; a
    third holds analysis :class:`~repro.analyze.cert.Certificate`
    records (:meth:`certificate_for`, ``certificate_stats``) so a
    repeated program certifies once and is a pure lookup afterwards.
    """

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self.stats = CacheStats()
        self.lowering_stats = CacheStats()
        self.certificate_stats = CacheStats()
        self._entries: collections.OrderedDict[str, Schedule] = \
            collections.OrderedDict()
        self._lowerings: "collections.OrderedDict[str, MegaLowering]" = \
            collections.OrderedDict()
        self._certificates: "collections.OrderedDict[str, Certificate]" = \
            collections.OrderedDict()
        self._lock = threading.RLock()

    def __len__(self) -> int:
        return len(self._entries)

    def schedule_for(self, program: Program,
                     key: Optional[str] = None) -> Schedule:
        """The program's schedule — cached, or built and admitted.

        Pass a precomputed ``key`` (from :func:`program_key`) to skip
        re-hashing when the caller already derived it.  Thread-safe:
        the first caller for a key builds under the lock, concurrent
        callers for the same key wait and hit.
        """
        key = key or program_key(program)
        with self._lock:
            sched = self._entries.get(key)
            if sched is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return sched
            self.stats.misses += 1
            sched = build_schedule(program)
            self._entries[key] = sched
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return sched

    def lowering_for(self, program: Program, key: Optional[str] = None,
                     sched: Optional[Schedule] = None) -> "MegaLowering":
        """The program's megakernel level tables — cached by content.

        Resolves the schedule through :meth:`schedule_for` first (the
        lock is re-entrant, so this is one serialized pass) unless the
        caller hands one in.  Hits/misses land on ``lowering_stats``,
        never on ``stats`` — schedule-cache accounting is unchanged by
        megakernel execution.
        """
        from repro.compile.megakernel import lower_schedule

        key = key or program_key(program)
        with self._lock:
            low = self._lowerings.get(key)
            if low is not None:
                self._lowerings.move_to_end(key)
                self.lowering_stats.hits += 1
                return low
            self.lowering_stats.misses += 1
            if sched is None:
                sched = self.schedule_for(program, key=key)
            low = lower_schedule(sched)
            self._lowerings[key] = low
            while len(self._lowerings) > self.maxsize:
                self._lowerings.popitem(last=False)
            return low

    def certificate_for(self, program: Program, key: Optional[str] = None,
                        sched: Optional[Schedule] = None,
                        lowering: "Optional[MegaLowering]" = None
                        ) -> "Certificate":
        """The program's analysis :class:`~repro.analyze.cert.Certificate`.

        Cached under the same content key as schedules, with a third
        stats window (``certificate_stats``): a *hit* means the artifact
        was admitted analyzed and zero re-analysis happened — the
        property the CI gate asserts.  A cached fused-only certificate
        is *upgraded* (one extra miss) the first time the caller also
        hands in a megakernel ``lowering``; a lowering-covering
        certificate satisfies fused-only lookups.  Raises
        :class:`~repro.analyze.cert.CertificationError` on any error
        finding — a program that fails certification is never admitted.
        """
        from repro.analyze.cert import certify

        key = key or program_key(program)
        with self._lock:
            cert = self._certificates.get(key)
            if cert is not None and (lowering is None
                                     or cert.lowering_digest
                                     == lowering.digest()):
                self._certificates.move_to_end(key)
                self.certificate_stats.hits += 1
                return cert
            self.certificate_stats.misses += 1
            if sched is None:
                sched = self.schedule_for(program, key=key)
            cert = certify(program, sched=sched, lowering=lowering,
                           key=key, where=f"program {key[:12]}")
            self._certificates[key] = cert
            while len(self._certificates) > self.maxsize:
                self._certificates.popitem(last=False)
            return cert
