"""SessionProgram: record PUD ops against typed row handles.

The builder is the session-level replacement for hand-emitting
:class:`~repro.pud.isa.PUDOp` streams with integer addresses: operands
and destinations are :class:`~repro.session.rows.Row` /
:class:`~repro.session.rows.PlaneGroup` handles from the builder's own
allocator, every op is validated as it is recorded (arity, ownership,
duplicate destinations), and activation counts default from the
session's :class:`~repro.backends.context.ExecutionContext` through the
§4 reachable-level ladder — the same defaulting the §8.1 ``BitSerial``
compiler applies.

``input(planes)`` binds initial row values, so the builder can also
materialize the ``(rows, words)`` image the program executes against
(:meth:`initial_state`), and :meth:`run` hands both to the owning
:class:`~repro.session.DramSession` — compile cache included.
"""

from __future__ import annotations

import collections
from typing import Optional, Union

import numpy as np

from repro.core import calibration as cal
from repro.pud.isa import Program
from repro.session.rows import (PlaneGroup, Row, RowAllocator, SessionError)
from repro.session.validate import check_program


class SessionProgram:
    """A typed program under construction (see module docstring).

    ``rows`` caps the subarray row budget (allocation past it raises
    with the budget in the message); ``None`` lets the image grow to
    whatever the program needs.
    """

    def __init__(self, session, rows: Optional[int] = None,
                 name: str = "session-program"):
        self._session = session
        self.name = name
        self.alloc = RowAllocator(rows, name=name)
        self.program = Program()
        self._bound: dict[int, np.ndarray] = {}
        self._width: Optional[int] = None

    # ------------------------------------------------------------- rows
    def alloc_row(self, tag: str = "") -> Row:
        return self.alloc.alloc_row(tag=tag)

    def alloc_rows(self, n: int, tag: str = "") -> PlaneGroup:
        return self.alloc.alloc(n, tag=tag)

    def input(self, planes, tag: str = "input"
              ) -> Union[Row, PlaneGroup]:
        """Allocate row(s) holding initial values.

        ``planes``: (words,) for one row -> :class:`Row`;
        (rows, words) -> :class:`PlaneGroup`.  The bound values build
        :meth:`initial_state`.
        """
        arr = np.asarray(planes, np.uint32)
        if arr.ndim not in (1, 2):
            raise SessionError(
                f"{self.name}: input planes must be (words,) or "
                f"(rows, words), got shape {arr.shape}")
        width = int(arr.shape[-1])
        if self._width is not None and width != self._width:
            raise SessionError(
                f"{self.name}: input row width {width} != bound "
                f"width {self._width} (one subarray, one row width)")
        self._width = width
        if arr.ndim == 1:
            row = self.alloc.alloc_row(tag=tag)
            self._bound[row.index] = arr
            return row
        group = self.alloc.alloc(arr.shape[0], tag=tag)
        for row, vals in zip(group, arr):
            self._bound[row.index] = vals
        return group

    def _own(self, row, what: str) -> Row:
        if not isinstance(row, Row):
            raise SessionError(
                f"{self.name}: {what} must be a Row handle (from "
                f".alloc_row()/.input()), got {type(row).__name__}")
        if not self.alloc.owns(row):
            raise SessionError(
                f"{self.name}: {what} row {row.index} (tag "
                f"{row.tag!r}) belongs to a different program — row "
                f"handles cannot alias across subarray images")
        return row

    def _n_act(self, n_act: Optional[int], floor: int) -> int:
        return cal.min_activation_for(
            max(n_act or self._session.ctx.n_act, floor))

    # -------------------------------------------------------------- ops
    def maj(self, *srcs: Row, dst: Optional[Row] = None,
            n_act: Optional[int] = None, tag: str = "maj") -> Row:
        """MAJ over the operand rows (duplicates = input replication).

        Allocates ``dst`` when not given; ``n_act`` defaults to the
        session context's count, raised to the smallest reachable
        activation level holding the arity.
        """
        x = len(srcs)
        if x % 2 == 0 or x < 3:
            raise SessionError(
                f"{self.name}: MAJ arity must be odd >= 3, got {x} "
                f"(tag {tag!r})")
        srcs = tuple(self._own(s, "MAJ operand") for s in srcs)
        dst = self._own(dst, "MAJ destination") if dst is not None \
            else self.alloc.alloc_row(tag=tag)
        self.program.emit("MAJ", x=x, n_act=self._n_act(n_act, x),
                          tag=tag, srcs=tuple(s.index for s in srcs),
                          dsts=(dst.index,))
        return dst

    def mrc(self, src: Row, dsts: Union[int, PlaneGroup],
            n_act: Optional[int] = None, tag: str = "mrc") -> PlaneGroup:
        """Multi-RowCopy ``src`` to ``dsts`` (a fan-out count or group)."""
        src = self._own(src, "MRC source")
        if isinstance(dsts, int):
            dsts = self.alloc.alloc(dsts, tag=tag)
        group = PlaneGroup(tuple(
            self._own(d, "MRC destination") for d in dsts))
        dup = sorted(r for r, c in collections.Counter(group.indices).items()
                     if c > 1)
        if dup:
            raise SessionError(
                f"{self.name}: MRC (tag {tag!r}) writes destination "
                f"row(s) {dup} more than once in a single op")
        # MRC activates source + fan-out rows together: default to the
        # smallest reachable level covering them (ctx.n_act is the MAJ
        # replication knob, not a copy fan-out).
        self.program.emit(
            "MRC", n_act=cal.min_activation_for(
                max(n_act or 0, len(group) + 1)),
            tag=tag, srcs=(src.index,), dsts=group.indices)
        return group

    def not_(self, src: Row, dst: Optional[Row] = None,
             tag: str = "not") -> Row:
        return self._unary("NOT", src, dst, tag)

    def copy(self, src: Row, dst: Optional[Row] = None,
             tag: str = "copy") -> Row:
        return self._unary("COPY", src, dst, tag)

    def _unary(self, kind: str, src: Row, dst: Optional[Row],
               tag: str) -> Row:
        src = self._own(src, f"{kind} source")
        dst = self._own(dst, f"{kind} destination") if dst is not None \
            else self.alloc.alloc_row(tag=tag)
        self.program.emit(kind, tag=tag, srcs=(src.index,),
                          dsts=(dst.index,))
        return dst

    # -------------------------------------------------------- finishing
    def build(self) -> Program:
        """Validate the whole recorded stream and return the Program."""
        check_program(self.program, self.alloc.n_rows, where=self.name)
        return self.program

    def initial_state(self, width: Optional[int] = None) -> np.ndarray:
        """(rows, words) uint32 image: bound inputs hold their values,
        scratch/output rows start zeroed."""
        w = width or self._width
        if w is None:
            raise SessionError(
                f"{self.name}: no input rows bound; pass width= to "
                f"size the subarray image")
        state = np.zeros((self.alloc.n_rows, w), np.uint32)
        for idx, vals in self._bound.items():
            state[idx] = vals
        return state

    def run(self, state=None, fused: bool = True):
        """Build, then execute on the owning session (compile-cached)."""
        prog = self.build()
        if state is None:
            state = self.initial_state()
        run = self._session.run_fused if fused else self._session.run
        return run(prog, state)
