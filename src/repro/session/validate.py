"""Build-time program validation: fail before any kernel launches.

An addressed :class:`~repro.pud.isa.Program` that references rows
outside its subarray image, or writes one destination row twice in a
single op, would previously fail *inside* an executing kernel — a
cryptic gather/scatter shape error (pallas), a silently-wrong row image
(sim), or nothing at all.  :func:`check_program` walks the op stream
once on the host and raises :class:`ProgramValidationError` with the op,
its provenance tag, and the subarray context, so every
:class:`~repro.session.DramSession` execution path rejects malformed
programs up front.
"""

from __future__ import annotations

import collections

from repro.pud.isa import Program
from repro.session.rows import SessionError

#: Kinds that read exactly one source row when addressed.
_SINGLE_SRC = ("NOT", "COPY", "MRC")


class ProgramValidationError(SessionError):
    """An addressed Program failed build-time validation."""


def _label(i: int, op) -> str:
    tag = f", tag {op.tag!r}" if op.tag else ""
    return f"op[{i}] {op.kind}{tag}"


def check_program(program: Program, n_rows: int,
                  where: str = "program") -> None:
    """Validate every addressed op against an ``n_rows``-row subarray.

    Checks, per op with destinations (cost-only and I/O ops are exempt
    like in the scheduler): all ``srcs``/``dsts`` inside ``[0, n_rows)``,
    no destination row written twice *within* the op, MAJ arity odd >= 3
    with one source per operand plane (duplicate sources are legal —
    that is the paper's input-replication identity), and single-source
    kinds carrying exactly one source.
    """
    for i, op in enumerate(program.ops):
        if not op.dsts:
            continue  # cost-only record: nothing addressable to check
        for role, addrs in (("source", op.srcs), ("destination", op.dsts)):
            for r in addrs:
                if not 0 <= r < n_rows:
                    raise ProgramValidationError(
                        f"{where}: {_label(i, op)} {role} row {r} is "
                        f"outside the {n_rows}-row subarray image")
        dup = sorted(r for r, c in collections.Counter(op.dsts).items()
                     if c > 1)
        if dup:
            raise ProgramValidationError(
                f"{where}: {_label(i, op)} writes destination row(s) "
                f"{dup} more than once in a single op "
                f"({n_rows}-row subarray image)")
        if op.kind == "MAJ":
            x = op.x or len(op.srcs)
            if x % 2 == 0 or x < 3:
                raise ProgramValidationError(
                    f"{where}: {_label(i, op)} MAJ arity must be odd "
                    f">= 3, got {x}")
            if len(op.srcs) != x:
                raise ProgramValidationError(
                    f"{where}: {_label(i, op)} MAJ{x} carries "
                    f"{len(op.srcs)} source rows (needs exactly {x})")
        elif op.kind in _SINGLE_SRC and len(op.srcs) != 1:
            raise ProgramValidationError(
                f"{where}: {_label(i, op)} takes exactly one source "
                f"row, got {len(op.srcs)}")
