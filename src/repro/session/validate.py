"""Build-time program validation: fail before any kernel launches.

An addressed :class:`~repro.pud.isa.Program` that references rows
outside its subarray image, or writes one destination row twice in a
single op, would previously fail *inside* an executing kernel — a
cryptic gather/scatter shape error (pallas), a silently-wrong row image
(sim), or nothing at all.  :func:`check_program` rejects malformed
programs up front with the op, its provenance tag, and the subarray
context in the message.

The checks themselves live in :func:`repro.analyze.races.check_ops` —
the same structural pass the certifier runs — so session-layer
validation and :mod:`repro.analyze` certification can never disagree
about what a well-formed program is.  This wrapper keeps the historical
raise-on-first-error contract: ``error`` findings raise
:class:`ProgramValidationError` (message of the first defect, full
list attached as ``findings``); ``warning`` findings (advisory
activation counts) never block execution.
"""

from __future__ import annotations

from repro.analyze.races import check_ops
from repro.analyze.report import ERROR, Finding
from repro.pud.isa import Program
from repro.session.rows import SessionError


class ProgramValidationError(SessionError):
    """An addressed Program failed build-time validation.

    ``findings`` carries every error-severity
    :class:`~repro.analyze.report.Finding` of the failed pass, not just
    the first one the message shows.
    """

    def __init__(self, message: str, findings: tuple[Finding, ...] = ()):
        super().__init__(message)
        self.findings = findings


def check_program(program: Program, n_rows: int,
                  where: str = "program") -> None:
    """Validate every addressed op against an ``n_rows``-row subarray.

    Checks, per op with destinations (cost-only and I/O ops are exempt
    like in the scheduler): known op kind, all ``srcs``/``dsts`` inside
    ``[0, n_rows)``, no destination row written twice *within* the op,
    MAJ arity odd >= 3 with one source per operand plane (duplicate
    sources are legal — that is the paper's input-replication
    identity), and single-source kinds carrying exactly one source.
    """
    errors = tuple(f for f in check_ops(program, n_rows, where=where)
                   if f.severity == ERROR)
    if errors:
        raise ProgramValidationError(errors[0].message, findings=errors)
