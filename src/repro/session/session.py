"""DramSession: the one entry point for executing PUD work.

A session owns a resolved :class:`~repro.backends.base.Backend` plus its
:class:`~repro.backends.context.ExecutionContext`, and layers the three
things every consumer was hand-assembling on top of the registry:

* **typed construction** — :meth:`program` opens a
  :class:`~repro.session.builder.SessionProgram` whose row handles come
  from a per-program allocator (build-time range/aliasing errors instead
  of kernel-side failures);
* **validated execution** — :meth:`run` / :meth:`run_fused` check any
  addressed Program (typed or hand-built) against the state image before
  a single kernel launches;
* **compile caching** — :meth:`run_fused` resolves the program's fused
  schedule through a content-hashed :class:`~repro.session.cache.
  CompileCache`, so repeated programs (serve votes, sweep chunks, §8.1
  executors) skip re-scheduling and go straight to the backend's
  ``run_fused``.

A session also satisfies the full backend surface by delegation (bulk
ops, ``capabilities``, the ``GateExecutor`` protocol, dispatch
counters), so anything that accepted a ``Backend`` accepts a
``DramSession`` — which is how ``run_elementwise`` transparently routes
batch-native sessions through the cache.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro.backends import Backend, ExecutionContext, resolve_backend
from repro.compile.schedule import Schedule
from repro.pud.isa import Program
from repro.session.builder import SessionProgram
from repro.session.cache import CompileCache, program_key
from repro.session.validate import check_program

#: Validation results cached per session: (program content key, n_rows).
_MAX_VALIDATED = 4096


class DramSession:
    """Typed facade over one backend + context + compile cache.

    ``backend`` is a registry name (the one-string choice) or an already
    -constructed :class:`Backend`; ``cache`` may be shared across
    sessions (schedules are pure program-content functions — the sweep
    runner shares one cache across its per-chunk sessions).
    """

    def __init__(self, backend: Union[str, Backend] = "pallas",
                 ctx: Optional[ExecutionContext] = None, *,
                 cache: Optional[CompileCache] = None,
                 name: Optional[str] = None):
        self.backend = resolve_backend(backend, ctx)
        self.cache = cache if cache is not None else CompileCache()
        self.name = name or f"session[{self.backend.name}]"
        self._validated: set[tuple[str, int]] = set()

    def __repr__(self) -> str:
        return (f"DramSession(backend={self.backend.name!r}, "
                f"cache={len(self.cache)} schedules)")

    @property
    def ctx(self) -> ExecutionContext:
        return self.backend.ctx

    # ------------------------------------------------- typed construction
    def program(self, rows: Optional[int] = None,
                name: Optional[str] = None) -> SessionProgram:
        """Open a typed program builder against a fresh row allocator."""
        return SessionProgram(self, rows=rows,
                              name=name or f"{self.name}/program")

    # ------------------------------------------------- program execution
    def _validate(self, program: Program, state, key: str) -> None:
        n_rows = int(np.shape(state)[0])
        if (key, n_rows) in self._validated:
            return
        check_program(program, n_rows, where=self.name)
        if len(self._validated) >= _MAX_VALIDATED:
            self._validated.clear()
        self._validated.add((key, n_rows))

    def schedule_for(self, program: Program) -> Schedule:
        """The program's fused schedule, through the compile cache."""
        return self.cache.schedule_for(program)

    def run(self, program: Program, state) -> jax.Array:
        """Per-op interpretation, validated up front."""
        self._validate(program, state, program_key(program))
        return self.backend.run(program, state)

    def run_fused(self, program: Program, state, *,
                  mode: str = "fused") -> jax.Array:
        """Fused execution: validate, resolve the cached schedule, run.

        Bit-identical to :meth:`run` on every backend; batch-native
        backends execute one kernel dispatch per schedule group — or,
        with ``mode="megakernel"``, ONE dispatch for the whole program
        (backends that don't advertise the capability fall back to
        their exact path, see ``Backend.run_fused``).  A repeated
        program is a cache hit — no re-scheduling; in megakernel mode
        the lowered level tables cache under the same content key (with
        their own ``cache.lowering_stats`` window, so schedule-cache
        accounting is mode-independent).

        Unless ``ctx.certify`` is False, the resolved artifacts are
        also statically certified (race / liveness / equivalence, see
        :mod:`repro.analyze`) through the cache's certificate store —
        one analysis per program content, raising
        :class:`~repro.analyze.cert.CertificationError` if the compiled
        schedule or level tables ever diverge from program dataflow.
        """
        key = program_key(program)
        self._validate(program, state, key)
        sched = self.cache.schedule_for(program, key=key)
        lowering = None
        if mode == "megakernel" and self.capabilities().megakernel:
            lowering = self.cache.lowering_for(program, key=key,
                                               sched=sched)
        if self.ctx.certify:
            # Static race/liveness/equivalence certification of the
            # exact artifacts about to execute; content-cached, so a
            # repeated program is a dictionary hit, not a re-analysis.
            self.cache.certificate_for(program, key=key, sched=sched,
                                       lowering=lowering)
        return self.backend.run_fused(program, state, sched=sched,
                                      mode=mode, lowering=lowering)

    # --------------------------------------------- §8.1 compiled arithmetic
    def elementwise(self, op: str, a, b, tier: Optional[int] = None,
                    n_act: Optional[int] = None):
        """Run a §8.1 microbenchmark with this session as the executor.

        Batch-native backends take the fused path through
        :meth:`run_fused` — i.e. through the compile cache."""
        from repro.pud.arith import run_elementwise

        return run_elementwise(
            op, a, b, tier=tier or self.ctx.tier,
            n_act=n_act or self.ctx.n_act, executor=self)

    # ------------------------------------------------------ bulk delegation
    def capabilities(self):
        return self.backend.capabilities()

    def majx(self, planes: jax.Array, x: Optional[int] = None,
             n_act: Optional[int] = None) -> jax.Array:
        return self.backend.majx(planes, x=x, n_act=n_act)

    def majx_batch(self, planes: jax.Array) -> jax.Array:
        return self.backend.majx_batch(planes)

    def rowcopy(self, src: jax.Array, n_dst: int) -> jax.Array:
        return self.backend.rowcopy(src, n_dst)

    def mismatch(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return self.backend.mismatch(a, b)

    def add_planes(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return self.backend.add_planes(a, b)

    def success_rate(self, got: jax.Array, want: jax.Array,
                     n_bits: Optional[int] = None) -> float:
        return self.backend.success_rate(got, want, n_bits=n_bits)

    # GateExecutor protocol (repro.pud.arith) ---------------------------
    def gate_maj(self, planes: Sequence[jax.Array], x: int,
                 n_act: int) -> jax.Array:
        return self.backend.gate_maj(planes, x, n_act)

    def gate_not(self, p: jax.Array) -> jax.Array:
        return self.backend.gate_not(p)

    # ------------------------------------------------- dispatch counters
    @property
    def dispatch_count(self) -> int:
        return self.backend.dispatch_count

    def reset_dispatches(self) -> None:
        self.backend.reset_dispatches()

    def count_dispatches(self):
        """Scoped kernel-launch counting (see Backend.count_dispatches)."""
        return self.backend.count_dispatches()
