"""Typed subarray row handles: allocation as an API, not an integer.

The paper's programs are *compositions over subarray rows* — MAJX reads
X operand rows, Multi-RowCopy fans one row out to N destinations, the
§8.1 bit-serial programs stream through dozens of scratch rows.  Hand
-assembled integer addresses fail late (a bad index scatters into the
wrong row inside a kernel, bit-exactness silently breaks); this module
makes rows *handles* handed out by an allocator, so range and aliasing
mistakes are caught when the program is built, with the subarray context
in the message.

:class:`Row` is one subarray row; :class:`PlaneGroup` an ordered group
of rows (operand planes of a MAJX stack, destinations of a Multi-RowCopy
fan-out).  Handles remember their allocator, so an op that mixes rows
from two different programs is rejected instead of aliasing by index
coincidence.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional


class SessionError(ValueError):
    """Base error of the session layer (build-time, never kernel-side)."""


class RowAllocationError(SessionError):
    """Subarray row budget exceeded at allocation time."""


@dataclasses.dataclass(frozen=True)
class Row:
    """A handle to one subarray row.

    ``index`` is the row address an executing backend sees; ``tag`` is
    provenance for error messages and recorded ops.  Handles compare by
    (index, tag) but belong to exactly one allocator — ops validate
    ownership so handles never alias across programs.
    """

    index: int
    tag: str = ""
    allocator: Optional["RowAllocator"] = dataclasses.field(
        default=None, repr=False, compare=False)


@dataclasses.dataclass(frozen=True)
class PlaneGroup:
    """An ordered group of :class:`Row` handles.

    What MAJX operand stacks, Multi-RowCopy destination fans, and
    bound input tiles are made of.  Indexing returns a :class:`Row`
    (or a sub-:class:`PlaneGroup` for slices).
    """

    rows: tuple[Row, ...]

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self) -> Iterator[Row]:
        return iter(self.rows)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return PlaneGroup(self.rows[i])
        return self.rows[i]

    @property
    def indices(self) -> tuple[int, ...]:
        return tuple(r.index for r in self.rows)


class RowAllocator:
    """Bump allocator (with reuse) over one subarray image's row space.

    ``capacity=None`` is unbounded (the executing image is sized by
    :meth:`n_rows` at build time); with a capacity, exceeding the row
    budget raises :class:`RowAllocationError` naming the subarray and
    the rows in use — the build-time analogue of running off the end of
    a physical subarray.

    Program builders allocate monotonically and never release, so their
    row addresses stay append-ordered.  Long-lived *arenas* (the serve
    layer's per-tenant row budgets) additionally :meth:`free` completed
    reservations: freed indices are reused by later allocations, which
    is what lets a bounded tenant budget admit an unbounded request
    stream.  Freeing invalidates the released handles — the arena owner
    must drop them; a retained stale handle aliases whichever
    reservation is handed the index next.
    """

    def __init__(self, capacity: Optional[int] = None,
                 name: str = "subarray"):
        self.capacity = capacity
        self.name = name
        self._next = 0
        self._free: list[int] = []

    @property
    def n_rows(self) -> int:
        """High-water mark == the executing image's row count."""
        return self._next

    @property
    def in_use(self) -> int:
        """Rows currently reserved (allocated and not freed)."""
        return self._next - len(self._free)

    @property
    def free_rows(self) -> tuple[int, ...]:
        """Indices currently on the free list.

        A program referencing any of these is using a stale handle —
        the index will alias the next reservation.  This is what
        :func:`repro.analyze.liveness.allocator_findings` audits.
        """
        return tuple(self._free)

    def alloc_row(self, tag: str = "") -> Row:
        return self.alloc(1, tag=tag)[0]

    def alloc(self, n: int, tag: str = "") -> PlaneGroup:
        if n < 1:
            raise RowAllocationError(
                f"{self.name}: cannot allocate {n} rows (tag {tag!r})")
        if self.capacity is not None and self.in_use + n > self.capacity:
            raise RowAllocationError(
                f"{self.name}: out of rows allocating {n} more "
                f"(tag {tag!r}): {self.in_use}/{self.capacity} in use")
        indices = [self._free.pop() for _ in range(min(n, len(self._free)))]
        fresh = n - len(indices)
        indices.extend(range(self._next, self._next + fresh))
        self._next += fresh
        rows = tuple(Row(i, tag=tag, allocator=self) for i in indices)
        return PlaneGroup(rows)

    def free(self, rows) -> None:
        """Release a :class:`Row`/:class:`PlaneGroup` back to the pool.

        Ownership is validated; double-frees raise.  See the class
        docstring for the handle-invalidation contract.
        """
        rows = (rows,) if isinstance(rows, Row) else tuple(rows)
        for row in rows:
            if not self.owns(row):
                raise RowAllocationError(
                    f"{self.name}: cannot free row "
                    f"{getattr(row, 'index', row)!r}: not allocated here")
            if row.index in self._free or row.index >= self._next:
                raise RowAllocationError(
                    f"{self.name}: double free of row {row.index} "
                    f"(tag {row.tag!r})")
        self._free.extend(row.index for row in rows)

    def owns(self, row: Row) -> bool:
        return isinstance(row, Row) and row.allocator is self
