"""Typed subarray row handles: allocation as an API, not an integer.

The paper's programs are *compositions over subarray rows* — MAJX reads
X operand rows, Multi-RowCopy fans one row out to N destinations, the
§8.1 bit-serial programs stream through dozens of scratch rows.  Hand
-assembled integer addresses fail late (a bad index scatters into the
wrong row inside a kernel, bit-exactness silently breaks); this module
makes rows *handles* handed out by an allocator, so range and aliasing
mistakes are caught when the program is built, with the subarray context
in the message.

:class:`Row` is one subarray row; :class:`PlaneGroup` an ordered group
of rows (operand planes of a MAJX stack, destinations of a Multi-RowCopy
fan-out).  Handles remember their allocator, so an op that mixes rows
from two different programs is rejected instead of aliasing by index
coincidence.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional


class SessionError(ValueError):
    """Base error of the session layer (build-time, never kernel-side)."""


class RowAllocationError(SessionError):
    """Subarray row budget exceeded at allocation time."""


@dataclasses.dataclass(frozen=True)
class Row:
    """A handle to one subarray row.

    ``index`` is the row address an executing backend sees; ``tag`` is
    provenance for error messages and recorded ops.  Handles compare by
    (index, tag) but belong to exactly one allocator — ops validate
    ownership so handles never alias across programs.
    """

    index: int
    tag: str = ""
    allocator: Optional["RowAllocator"] = dataclasses.field(
        default=None, repr=False, compare=False)


@dataclasses.dataclass(frozen=True)
class PlaneGroup:
    """An ordered group of :class:`Row` handles.

    What MAJX operand stacks, Multi-RowCopy destination fans, and
    bound input tiles are made of.  Indexing returns a :class:`Row`
    (or a sub-:class:`PlaneGroup` for slices).
    """

    rows: tuple[Row, ...]

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self) -> Iterator[Row]:
        return iter(self.rows)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return PlaneGroup(self.rows[i])
        return self.rows[i]

    @property
    def indices(self) -> tuple[int, ...]:
        return tuple(r.index for r in self.rows)


class RowAllocator:
    """Bump allocator over one subarray image's row space.

    ``capacity=None`` is unbounded (the executing image is sized by
    :meth:`n_rows` at build time); with a capacity, exceeding the row
    budget raises :class:`RowAllocationError` naming the subarray and
    the rows in use — the build-time analogue of running off the end of
    a physical subarray.
    """

    def __init__(self, capacity: Optional[int] = None,
                 name: str = "subarray"):
        self.capacity = capacity
        self.name = name
        self._next = 0

    @property
    def n_rows(self) -> int:
        """Rows handed out so far == the executing image's row count."""
        return self._next

    def alloc_row(self, tag: str = "") -> Row:
        return self.alloc(1, tag=tag)[0]

    def alloc(self, n: int, tag: str = "") -> PlaneGroup:
        if n < 1:
            raise RowAllocationError(
                f"{self.name}: cannot allocate {n} rows (tag {tag!r})")
        if self.capacity is not None and self._next + n > self.capacity:
            raise RowAllocationError(
                f"{self.name}: out of rows allocating {n} more "
                f"(tag {tag!r}): {self._next}/{self.capacity} in use")
        rows = tuple(Row(self._next + i, tag=tag, allocator=self)
                     for i in range(n))
        self._next += n
        return PlaneGroup(rows)

    def owns(self, row: Row) -> bool:
        return isinstance(row, Row) and row.allocator is self
