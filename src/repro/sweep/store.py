"""Resumable per-campaign record store, keyed by the spec's content hash.

The store is split in two layers:

* :class:`RecordStore` — the campaign-level API the planner/runner/
  aggregation layers talk to (``put`` / ``completed`` / ``records``),
  keyed by the spec's content hash so different specs can never share
  records;
* a :class:`RecordStoreBackend` — where the bytes live.  The default
  :class:`LocalDirBackend` is the original one-directory-per-campaign
  layout below; :class:`MemoryBackend` keeps everything in-process
  (tests, ephemeral campaigns).  A sharded / object-store backend for
  million-point campaigns only needs to implement the same four-method
  protocol.

Local-dir layout (one directory per campaign):

.. code-block:: text

    <root>/<name>-<spec_hash>/
        spec.json                     # the full SweepSpec, for audit
        chunks/chunk-000000-000007.json
        chunks/chunk-000008-000015.json
        ...

Each chunk file holds the records of one planned :class:`~repro.sweep.
planner.Chunk` and is written atomically (temp file + ``os.replace``),
so a killed sweep leaves either a complete chunk or no chunk — never a
torn one.  Completion is the existence of the chunk file; a restarted
run lists ``chunks/`` and skips everything already present, which is
the whole resume protocol.  Atomic last-write-wins chunk files also
make *duplicate* execution harmless: two workers racing on the same
re-dispatched chunk replace the file with byte-identical content (see
:func:`repro.sweep.runner.run_sweep_ft`).  Different specs hash to
different directories, so stale records can never satisfy a changed
campaign.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Iterator, Optional, Protocol, runtime_checkable

from repro.sweep.planner import Chunk
from repro.sweep.spec import SweepSpec


@runtime_checkable
class RecordStoreBackend(Protocol):
    """Storage protocol behind a :class:`RecordStore`.

    Implementations must make :meth:`put_chunk` atomic per key (a
    reader never sees a torn chunk) and idempotent under duplicate
    writes of identical content — the fault-tolerant runner relies on
    last-write-wins semantics.  ``location`` is a human-readable
    address used in summaries (a path for the local backend).
    """

    location: str

    def ensure(self) -> None:
        """Create whatever the backend needs before the first write."""
        ...

    def put_chunk(self, key: str, payload: dict) -> None:
        """Persist one chunk payload atomically under ``key``."""
        ...

    def completed(self) -> set[str]:
        """Keys of chunks already stored (the resume set)."""
        ...

    def chunk_payloads(self) -> Iterator[dict]:
        """Every stored chunk payload, in stable key order."""
        ...

    def read_spec(self) -> Optional[str]:
        """The stored spec JSON, or ``None`` if not written yet."""
        ...

    def write_spec(self, text: str) -> None:
        ...


class LocalDirBackend:
    """The default backend: one directory per campaign (see module doc).

    Construction never touches the filesystem (read-only bindings to
    legacy stores must not mkdir); :meth:`ensure` creates the layout.
    """

    def __init__(self, path: str):
        self.location = path
        self._chunk_dir = os.path.join(path, "chunks")
        self._spec_path = os.path.join(path, "spec.json")

    def ensure(self) -> None:
        os.makedirs(self._chunk_dir, exist_ok=True)

    @staticmethod
    def _atomic_write(path: str, text: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def put_chunk(self, key: str, payload: dict) -> None:
        self._atomic_write(os.path.join(self._chunk_dir, key + ".json"),
                           json.dumps(payload))

    def completed(self) -> set[str]:
        if not os.path.isdir(self._chunk_dir):
            return set()
        return {f[:-len(".json")] for f in os.listdir(self._chunk_dir)
                if f.endswith(".json")}

    def chunk_payloads(self) -> Iterator[dict]:
        if not os.path.isdir(self._chunk_dir):
            return
        for f in sorted(os.listdir(self._chunk_dir)):
            if not f.endswith(".json"):
                continue
            with open(os.path.join(self._chunk_dir, f)) as fh:
                yield json.load(fh)

    def read_spec(self) -> Optional[str]:
        if not os.path.exists(self._spec_path):
            return None
        with open(self._spec_path) as f:
            return f.read()

    def write_spec(self, text: str) -> None:
        self._atomic_write(self._spec_path, text)


class MemoryBackend:
    """In-process backend (tests / ephemeral campaigns); thread-safe.

    Payloads round-trip through JSON so records are byte-for-byte what
    the local backend would have stored — parity tests can swap
    backends without losing the serialization boundary.
    """

    def __init__(self, name: str = "anon"):
        self.location = f"memory://{name}"
        self._lock = threading.Lock()
        self._chunks: dict[str, str] = {}
        self._spec: Optional[str] = None

    def ensure(self) -> None:
        pass

    def put_chunk(self, key: str, payload: dict) -> None:
        text = json.dumps(payload)
        with self._lock:
            self._chunks[key] = text

    def completed(self) -> set[str]:
        with self._lock:
            return set(self._chunks)

    def chunk_payloads(self) -> Iterator[dict]:
        with self._lock:
            items = sorted(self._chunks.items())
        for _, text in items:
            yield json.loads(text)

    def read_spec(self) -> Optional[str]:
        with self._lock:
            return self._spec

    def write_spec(self, text: str) -> None:
        with self._lock:
            self._spec = text


class RecordStore:
    """Append-only per-campaign store of per-point success records."""

    def __init__(self, root: str, spec: SweepSpec,
                 backend: Optional[RecordStoreBackend] = None):
        self.spec = spec
        if backend is None:
            backend = LocalDirBackend(os.path.join(root, spec.store_name()))
        self.backend = backend
        self.path = backend.location
        backend.ensure()
        if backend.read_spec() is None:
            backend.write_spec(spec.to_json())

    @classmethod
    def bound(cls, path: str, spec: SweepSpec) -> "RecordStore":
        """Read-only binding to an *existing* campaign directory.

        Unlike the constructor it neither creates directories nor
        re-derives the path from the spec hash, so discovery keeps
        working on stores written under an older physics fingerprint.
        """
        obj = object.__new__(cls)
        obj.spec = spec
        obj.backend = LocalDirBackend(path)
        obj.path = path
        return obj

    # ------------------------------------------------------------ writing
    def put(self, chunk: Chunk, records: list[dict]) -> None:
        """Persist one completed chunk (atomic; marks it done)."""
        payload = {"key": chunk.key, "backend": chunk.backend,
                   "indices": list(chunk.indices), "records": records}
        self.backend.put_chunk(chunk.key, payload)

    # ------------------------------------------------------------ reading
    def completed(self) -> set[str]:
        """Keys of chunks already stored (the resume set)."""
        return self.backend.completed()

    def records(self) -> list[dict]:
        """All stored records, ordered by grid-point index."""
        out: list[dict] = []
        for payload in self.backend.chunk_payloads():
            out.extend(payload["records"])
        out.sort(key=lambda r: r["index"])
        return out

    def n_completed_points(self) -> int:
        return len(self.records())


def discover(root: str) -> Iterator[tuple[SweepSpec, "RecordStore"]]:
    """Iterate every campaign stored under ``root`` (for reporting).

    Binds each store to the directory it was found in (read-only) and
    skips campaigns whose spec no longer parses under the current
    schema, so reporting never crashes on — or mkdirs next to — legacy
    stores.
    """
    if not os.path.isdir(root):
        return
    for name in sorted(os.listdir(root)):
        path = os.path.join(root, name)
        spec_path = os.path.join(path, "spec.json")
        if not os.path.exists(spec_path):
            continue
        try:
            with open(spec_path) as f:
                spec = SweepSpec.from_json(f.read())
        except (TypeError, ValueError):
            continue  # written under an older spec schema
        yield spec, RecordStore.bound(path, spec)


def default_root(explicit: Optional[str] = None) -> str:
    """Resolve the record-store root: explicit > ``$REPRO_SWEEP_ROOT`` >
    ``<repo>/results/sweeps``.

    The fallback is repo-relative (not CWD-relative), so the CLI, the
    figure benchmarks, and ``results/make_tables.py`` all see the same
    stores no matter where they are invoked from.  The precedence is
    documented once, in ``docs/SWEEPS.md``.
    """
    if explicit:
        return explicit
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))  # src/repro/sweep/..
    return os.environ.get("REPRO_SWEEP_ROOT",
                          os.path.join(repo, "results", "sweeps"))
