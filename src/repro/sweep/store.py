"""Resumable on-disk record store, keyed by the spec's content hash.

Layout (one directory per campaign):

.. code-block:: text

    <root>/<name>-<spec_hash>/
        spec.json                     # the full SweepSpec, for audit
        chunks/chunk-000000-000007.json
        chunks/chunk-000008-000015.json
        ...

Each chunk file holds the records of one planned :class:`~repro.sweep.
planner.Chunk` and is written atomically (temp file + ``os.replace``),
so a killed sweep leaves either a complete chunk or no chunk — never a
torn one.  Completion is the existence of the chunk file; a restarted
run lists ``chunks/`` and skips everything already present, which is
the whole resume protocol.  Different specs hash to different
directories, so stale records can never satisfy a changed campaign.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Iterator, Optional

from repro.sweep.planner import Chunk
from repro.sweep.spec import SweepSpec


class RecordStore:
    """Append-only per-campaign store of per-point success records."""

    def __init__(self, root: str, spec: SweepSpec):
        self.spec = spec
        self.path = os.path.join(root, spec.store_name())
        self._chunk_dir = os.path.join(self.path, "chunks")
        os.makedirs(self._chunk_dir, exist_ok=True)
        spec_path = os.path.join(self.path, "spec.json")
        if not os.path.exists(spec_path):
            self._atomic_write(spec_path, spec.to_json())

    @classmethod
    def bound(cls, path: str, spec: SweepSpec) -> "RecordStore":
        """Read-only binding to an *existing* campaign directory.

        Unlike the constructor it neither creates directories nor
        re-derives the path from the spec hash, so discovery keeps
        working on stores written under an older physics fingerprint.
        """
        obj = object.__new__(cls)
        obj.spec = spec
        obj.path = path
        obj._chunk_dir = os.path.join(path, "chunks")
        return obj

    # ------------------------------------------------------------ writing
    @staticmethod
    def _atomic_write(path: str, text: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def put(self, chunk: Chunk, records: list[dict]) -> None:
        """Persist one completed chunk (atomic; marks it done)."""
        payload = {"key": chunk.key, "backend": chunk.backend,
                   "indices": list(chunk.indices), "records": records}
        self._atomic_write(os.path.join(self._chunk_dir, chunk.key + ".json"),
                           json.dumps(payload))

    # ------------------------------------------------------------ reading
    def completed(self) -> set[str]:
        """Keys of chunks already on disk (the resume set)."""
        if not os.path.isdir(self._chunk_dir):
            return set()
        return {f[:-len(".json")] for f in os.listdir(self._chunk_dir)
                if f.endswith(".json")}

    def records(self) -> list[dict]:
        """All stored records, ordered by grid-point index."""
        out: list[dict] = []
        if not os.path.isdir(self._chunk_dir):
            return out
        for f in sorted(os.listdir(self._chunk_dir)):
            if not f.endswith(".json"):
                continue
            with open(os.path.join(self._chunk_dir, f)) as fh:
                out.extend(json.load(fh)["records"])
        out.sort(key=lambda r: r["index"])
        return out

    def n_completed_points(self) -> int:
        return len(self.records())


def discover(root: str) -> Iterator[tuple[SweepSpec, "RecordStore"]]:
    """Iterate every campaign stored under ``root`` (for reporting).

    Binds each store to the directory it was found in (read-only) and
    skips campaigns whose spec no longer parses under the current
    schema, so reporting never crashes on — or mkdirs next to — legacy
    stores.
    """
    if not os.path.isdir(root):
        return
    for name in sorted(os.listdir(root)):
        path = os.path.join(root, name)
        spec_path = os.path.join(path, "spec.json")
        if not os.path.exists(spec_path):
            continue
        try:
            with open(spec_path) as f:
                spec = SweepSpec.from_json(f.read())
        except (TypeError, ValueError):
            continue  # written under an older spec schema
        yield spec, RecordStore.bound(path, spec)


def default_root(explicit: Optional[str] = None) -> str:
    """Resolve the record-store root: explicit > $REPRO_SWEEP_ROOT >
    ``<repo>/results/sweeps``.

    Repo-relative (not CWD-relative), so the CLI, the figure benchmarks,
    and ``results/make_tables.py`` all see the same stores no matter
    where they are invoked from.
    """
    if explicit:
        return explicit
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))  # src/repro/sweep/..
    return os.environ.get("REPRO_SWEEP_ROOT",
                          os.path.join(repo, "results", "sweeps"))
