"""Characterization-campaign engine: declarative, sharded, resumable.

The paper's central artifact is not one kernel call but a *campaign*:
success-rate surfaces swept over simultaneous-activation count, MAJ
arity, data pattern, violated timings, temperature, and voltage across
120 chips.  This package reproduces that shape over the unified
:mod:`repro.backends` executor API:

>>> from repro.sweep import SweepSpec, run_sweep, aggregate
>>> spec = SweepSpec(name="demo", op="majx", backends=("sim",),
...                  x_values=(3,), n_act=(4, 32))
>>> result = run_sweep(spec, root="results/sweeps")
>>> aggregate.replication_delta(result.records)   # Obs 6 headline
0.3...

Pipeline: :class:`~repro.sweep.spec.SweepSpec` (the grid, content-hashed)
-> :mod:`~repro.sweep.planner` (backend-native batches / chunks)
-> :mod:`~repro.sweep.runner` (execute; shard across workers and the
device mesh, or fault-tolerantly with :func:`run_sweep_ft`'s elastic
worker pool) -> :mod:`~repro.sweep.store` (atomic per-chunk files on a
pluggable backend; restart skips completed chunks) ->
:mod:`~repro.sweep.aggregate` (headline tables).
:mod:`~repro.sweep.adaptive` replaces the dense grid with a boundary
search over the same points/store when only the failure cliff matters.
``python -m repro.sweep.run --smoke`` exercises the whole pipeline in
seconds; see ``docs/SWEEPS.md``.
"""

from repro.sweep import aggregate, presets  # noqa: F401
from repro.sweep.adaptive import (AdaptiveResult, AdaptiveSpec,  # noqa: F401
                                  Crossing, run_adaptive)
from repro.sweep.planner import (Chunk, chunks_by_point, plan,  # noqa: F401
                                 shard)
from repro.sweep.runner import (FtSweepResult, SweepResult,  # noqa: F401
                                records_for, run_sweep, run_sweep_ft)
from repro.sweep.spec import (ANALYTIC, SEARCH_AXES, GridPoint,  # noqa: F401
                              SweepSpec, load_spec)
from repro.sweep.store import (LocalDirBackend, MemoryBackend,  # noqa: F401
                               RecordStore, RecordStoreBackend,
                               default_root, discover)

__all__ = [
    "ANALYTIC", "AdaptiveResult", "AdaptiveSpec", "Chunk", "Crossing",
    "FtSweepResult", "GridPoint", "LocalDirBackend", "MemoryBackend",
    "RecordStore", "RecordStoreBackend", "SEARCH_AXES", "SweepResult",
    "SweepSpec", "aggregate", "chunks_by_point", "default_root", "discover",
    "load_spec", "plan", "presets", "records_for", "run_adaptive",
    "run_sweep", "run_sweep_ft", "shard",
]
