"""Characterization-campaign engine: declarative, sharded, resumable.

The paper's central artifact is not one kernel call but a *campaign*:
success-rate surfaces swept over simultaneous-activation count, MAJ
arity, data pattern, violated timings, temperature, and voltage across
120 chips.  This package reproduces that shape over the unified
:mod:`repro.backends` executor API:

>>> from repro.sweep import SweepSpec, run_sweep, aggregate
>>> spec = SweepSpec(name="demo", op="majx", backends=("sim",),
...                  x_values=(3,), n_act=(4, 32))
>>> result = run_sweep(spec, root="results/sweeps")
>>> aggregate.replication_delta(result.records)   # Obs 6 headline
0.3...

Pipeline: :class:`~repro.sweep.spec.SweepSpec` (the grid, content-hashed)
-> :mod:`~repro.sweep.planner` (backend-native batches / chunks)
-> :mod:`~repro.sweep.runner` (execute; shard across workers and the
device mesh) -> :mod:`~repro.sweep.store` (atomic per-chunk files;
restart skips completed chunks) -> :mod:`~repro.sweep.aggregate`
(headline tables).  ``python -m repro.sweep.run --smoke`` exercises the
whole pipeline in seconds; see ``docs/SWEEPS.md``.
"""

from repro.sweep import aggregate, presets  # noqa: F401
from repro.sweep.planner import Chunk, plan, shard  # noqa: F401
from repro.sweep.runner import (SweepResult, records_for,  # noqa: F401
                                run_sweep)
from repro.sweep.spec import (ANALYTIC, GridPoint, SweepSpec,  # noqa: F401
                              load_spec)
from repro.sweep.store import RecordStore, default_root, discover  # noqa: F401

__all__ = [
    "ANALYTIC", "Chunk", "GridPoint", "RecordStore", "SweepResult",
    "SweepSpec", "aggregate", "default_root", "discover", "load_spec",
    "plan", "presets", "records_for", "run_sweep", "shard",
]
