"""Preset sweeps: every paper figure's grid as a :class:`SweepSpec`.

Each ``figN_spec()`` is the exact characterization grid behind that
figure of the SiMRA-DRAM paper, expressed declaratively.  The figure
presets use the ``analytic`` pseudo-backend (direct evaluation of the
calibrated :class:`~repro.core.errormodel.ErrorModel` surface), which
is exact at every paper anchor; for the MAJX/MRC grids, swap
``backends=("sim",)`` to measure the same grid behaviourally through
the Subarray command model, or add ``"pallas"`` for a digital-parity
column (the SiMRA grids are analytic-only: raw activation success has
no executable digital analogue, and the spec enforces that).  ``benchmarks/paper_figures.py``
formats these specs' records into its CSV rows, and
:func:`FIGURE_SPECS` is the CLI's ``--figure`` registry.
"""

from __future__ import annotations

from repro.core import calibration as cal
from repro.sweep.spec import ANALYTIC, SweepSpec


def fig3_spec() -> SweepSpec:
    """Fig 3: SiMRA success vs (t1, t2) x activation count."""
    return SweepSpec(name="fig3-simra-timing", op="simra",
                     backends=(ANALYTIC,), n_act=cal.N_ACT_LEVELS,
                     timings=((1.5, 1.5), (1.5, 3.0), (3.0, 1.5), (3.0, 3.0)))


def fig4_spec() -> SweepSpec:
    """Fig 4: SiMRA@32 across temperature and wordline voltage."""
    return SweepSpec(name="fig4-simra-env", op="simra", backends=(ANALYTIC,),
                     n_act=(32,), temps_c=cal.TEMPERATURES_C,
                     vpps_v=cal.VPP_LEVELS_V)


def fig6_spec() -> SweepSpec:
    """Fig 6: MAJ3 success vs timing x activation count (Obs 6/7)."""
    return SweepSpec(name="fig6-maj3-timing", op="majx", backends=(ANALYTIC,),
                     x_values=(3,), n_act=(4, 8, 16, 32),
                     timings=((1.5, 3.0), (3.0, 3.0), (4.5, 3.0), (1.5, 1.5)))


def fig7_spec() -> SweepSpec:
    """Fig 7: MAJX@32 across data patterns (Obs 8/9)."""
    return SweepSpec(name="fig7-majx-patterns", op="majx",
                     backends=(ANALYTIC,), x_values=(3, 5, 7, 9),
                     n_act=(32,), patterns=cal.DATA_PATTERNS)


def fig8_spec() -> SweepSpec:
    """Fig 8: MAJX across temperature, at min and 32-row act (Obs 11/12)."""
    return SweepSpec(name="fig8-majx-temp", op="majx", backends=(ANALYTIC,),
                     x_values=(3, 5, 7, 9), n_act=(4, 8, 16, 32),
                     temps_c=cal.TEMPERATURES_C)


def fig9_spec() -> SweepSpec:
    """Fig 9: MAJX@32 across wordline voltage (Obs 13)."""
    return SweepSpec(name="fig9-majx-vpp", op="majx", backends=(ANALYTIC,),
                     x_values=(3, 5, 7, 9), n_act=(32,),
                     vpps_v=cal.VPP_LEVELS_V)


def fig10_spec() -> SweepSpec:
    """Fig 10: Multi-RowCopy success vs t1 x destination count (Obs 14/15)."""
    return SweepSpec(name="fig10-mrc-timing", op="mrc", backends=(ANALYTIC,),
                     n_act=cal.N_ACT_LEVELS,
                     timings=((1.5, 3.0), (3.0, 3.0), (6.0, 3.0),
                              (9.0, 3.0), (36.0, 3.0)))


def fig11_spec() -> SweepSpec:
    """Fig 11: Multi-RowCopy across data patterns (Obs 16)."""
    return SweepSpec(name="fig11-mrc-patterns", op="mrc",
                     backends=(ANALYTIC,), n_act=cal.N_ACT_LEVELS,
                     patterns=("0x00", "0xFF", "random"))


def fig12_spec() -> SweepSpec:
    """Fig 12: Multi-RowCopy(31) across temperature and voltage (Obs 17/18)."""
    return SweepSpec(name="fig12-mrc-env", op="mrc", backends=(ANALYTIC,),
                     n_act=(32,), temps_c=cal.TEMPERATURES_C,
                     vpps_v=cal.VPP_LEVELS_V)


FIGURE_SPECS = {
    "fig3": fig3_spec, "fig4": fig4_spec, "fig6": fig6_spec,
    "fig7": fig7_spec, "fig8": fig8_spec, "fig9": fig9_spec,
    "fig10": fig10_spec, "fig11": fig11_spec, "fig12": fig12_spec,
}


# ------------------------------------------------------- executable presets


def smoke_spec(backends: tuple[str, ...] = ("sim", "pallas")) -> SweepSpec:
    """A <=16-point executable grid (the CLI ``--smoke`` / CI spec).

    Ideal contexts (no error injection) so every backend must agree with
    the oracle bit-exactly — this doubles as a cross-backend parity
    check whenever it runs.
    """
    return SweepSpec(name="smoke", op="majx", backends=tuple(backends),
                     x_values=(3,), n_act=(4, 32),
                     patterns=("random", "0x00/0xFF"),
                     ideal=True, rows=2, words=16, chunk=4)


def adaptive_smoke_spec() -> "AdaptiveSpec":
    """The adaptive-smoke campaign: MAJ3@32 success vs a t1 ladder.

    A 20-step t1 ladder (t2 pinned at the 3 ns optimum) on the analytic
    backend: success decays from ~0.98 through the Obs 7 charge-sharing
    cliff, crossing 0.9 almost immediately and 0.5 a few steps later.
    ``chunk=1`` so every probe is one point — the CI gate asserts the
    boundary search executes <= 40 % of the dense ladder while locating
    the same cliff bracket (``scripts/ci.sh``).
    """
    from repro.sweep.adaptive import AdaptiveSpec

    ladder = tuple((1.5 + 1.5 * k, 3.0) for k in range(20))
    base = SweepSpec(name="adaptive-smoke", op="majx", backends=(ANALYTIC,),
                     x_values=(3,), n_act=(32,), timings=ladder, chunk=1)
    return AdaptiveSpec(base=base, thresholds=(0.5, 0.9))


def preflight_specs(backend: str) -> tuple[SweepSpec, SweepSpec]:
    """Tiny MAJX + MRC parity sweeps for one backend (run_all_cells)."""
    majx = SweepSpec(name=f"preflight-majx-{backend}", op="majx",
                     backends=(backend,), x_values=(3, 5), n_act=(32,),
                     ideal=True, rows=2, words=16, chunk=4)
    mrc = SweepSpec(name=f"preflight-mrc-{backend}", op="mrc",
                    backends=(backend,), n_act=(8, 32),
                    ideal=True, words=16, chunk=4)
    return majx, mrc
