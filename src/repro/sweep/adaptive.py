"""Adaptive boundary-search characterization: probe the cliff, skip the
plateau.

The paper's characterization surfaces (success rate vs. timing delay,
activation count, temperature, V_PP — Figs 5-12, Obs 6/9/11-18) are
smooth plateaus with sharp failure cliffs, so a dense grid wastes most
of its points far from the cliff.  :class:`AdaptiveSpec` wraps an
ordinary dense :class:`~repro.sweep.spec.SweepSpec` and, per
(backend, mfr, arity, pattern, environment, seed) *slice*, bisects each
swept axis (``timings``, ``n_act``, ``temp_c``, ``vpp_v`` — see
:data:`repro.sweep.spec.SEARCH_AXES`) for the success-rate threshold
crossings (e.g. 50 % and 90 %), then refines locally around each
bracket to ``refine_radius`` grid steps.

The crucial invariant: the adaptive mode never invents operating
points.  Every probe is a grid point of the wrapped dense spec,
executed as its ordinary planned chunk
(:func:`repro.sweep.planner.chunks_by_point`) and persisted through the
*same* content-hashed :class:`~repro.sweep.store.RecordStore` the dense
grid would use.  Consequences:

* records on points both modes touch are **byte-identical** (same
  chunk, same pure ``(spec, chunk) -> records`` executor, same
  serialization), so aggregates over overlapping points are provably
  identical between modes;
* an adaptive campaign kills/resumes exactly like a grid one: the
  search is deterministic, so a restart replays the same probe
  sequence, finds the already-stored chunks, and executes only what is
  missing;
* grid and adaptive runs of the same spec share one store — an
  adaptive pass is simply a cheap prefix of the dense campaign, and a
  later dense run fills in the rest without recomputing the cliff.

Point economy comes from the chunk granularity: set ``chunk=1`` (or
small) in the wrapped spec so a probe executes one point, not a stripe
of the grid.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.sweep import planner
from repro.sweep.runner import _Executor
from repro.sweep.spec import SEARCH_AXES, SweepSpec
from repro.sweep.store import RecordStore, default_root

#: GridPoint fields that identify a search slice (everything but the
#: searched axis, whose fields come from SEARCH_AXES, and the dense
#: ``index``).
_POINT_FIELDS = ("op", "backend", "mfr", "x", "n_act", "n_dest", "pattern",
                 "t1", "t2", "temp_c", "vpp_v", "seed")


@dataclasses.dataclass(frozen=True)
class AdaptiveSpec:
    """An adaptive campaign: a dense grid plus a boundary-search policy.

    ``thresholds`` are the success-rate levels whose crossings are
    located (paper-style: 0.5 = the cliff, 0.9 = the usable edge);
    ``axes`` restricts the search to specific swept axes (default:
    every axis of the base spec with more than one value);
    ``refine_radius`` probes that many extra grid steps on each side of
    a located bracket, mapping the local cliff shape; ``metric`` is the
    record field driving decisions (``success``, or ``expected`` to
    search the calibrated surface under a behavioural backend).
    """

    base: SweepSpec
    thresholds: tuple[float, ...] = (0.5, 0.9)
    axes: tuple[str, ...] = ()
    refine_radius: int = 1
    metric: str = "success"

    def __post_init__(self):
        if not self.thresholds:
            raise ValueError("need at least one threshold")
        for t in self.thresholds:
            if not 0.0 < t < 1.0:
                raise ValueError(f"thresholds must be in (0, 1), got {t}")
        for a in self.axes:
            if a not in SEARCH_AXES:
                raise ValueError(f"unknown search axis {a!r}; "
                                 f"expected one of {tuple(SEARCH_AXES)}")
            if len(self.base.axis_values(a)) < 2:
                raise ValueError(f"axis {a!r} is not swept by spec "
                                 f"{self.base.name!r} (needs >= 2 values)")
        if self.refine_radius < 0:
            raise ValueError("refine_radius must be >= 0")
        if self.metric not in ("success", "expected"):
            raise ValueError(f"metric must be 'success' or 'expected', "
                             f"got {self.metric!r}")

    def search_axes(self) -> tuple[str, ...]:
        return self.axes or self.base.searchable_axes()


@dataclasses.dataclass(frozen=True)
class Crossing:
    """One located threshold crossing (or its absence) on one slice.

    ``lo_index``/``hi_index`` are dense grid-point indices of the
    adjacent ladder positions bracketing the crossing (``lo`` earlier
    on the declared axis order); ``direction`` is ``"falling"`` when
    the metric drops below the threshold along the axis, ``"rising"``
    when it climbs above it, and ``None`` when the whole slice sits on
    one side (``crossed=False``).
    """

    axis: str
    threshold: float
    slice_key: tuple[tuple[str, object], ...]
    crossed: bool
    direction: Optional[str] = None
    lo_index: Optional[int] = None
    hi_index: Optional[int] = None
    lo_value: Optional[object] = None
    hi_value: Optional[object] = None

    def describe(self) -> str:
        if not self.crossed:
            return (f"{self.axis}@{self.threshold:g}: no crossing")
        return (f"{self.axis}@{self.threshold:g}: {self.direction} between "
                f"{self.lo_value} and {self.hi_value} "
                f"(points {self.lo_index}/{self.hi_index})")


@dataclasses.dataclass
class AdaptiveResult:
    """What one :func:`run_adaptive` invocation did and produced.

    ``n_probed`` counts distinct grid points the search consulted;
    ``points_covered`` counts points with records in the store after
    the run (>= ``n_probed`` when chunks hold several points, or when
    the store already held dense records).  ``complete`` is False when
    ``max_chunks`` exhausted the execution budget mid-search — re-run
    to resume with zero recomputation.
    """

    spec: AdaptiveSpec
    store_path: str
    n_grid_points: int
    n_probed: int
    points_covered: int
    executed_chunks: int
    cached_chunks: int
    crossings: list[Crossing]
    complete: bool
    records: list[dict]

    def summary(self) -> str:
        base = self.spec.base
        state = "" if self.complete else " [budget exhausted; resumable]"
        return (f"adaptive '{base.name}' [{base.spec_hash()}]: probed "
                f"{self.n_probed}/{self.n_grid_points} points "
                f"({self.executed_chunks} chunks executed, "
                f"{self.cached_chunks} cached), {len(self.crossings)} "
                f"crossings{state} at {self.store_path}")


class _Budget(Exception):
    """Internal: the max_chunks execution budget is exhausted."""


class _Prober:
    """Executes/loads grid points on demand through the shared store."""

    def __init__(self, aspec: AdaptiveSpec, store: RecordStore, mesh,
                 max_chunks: Optional[int]):
        self.metric = aspec.metric
        self.store = store
        self.chunks = planner.plan(aspec.base)
        self.by_point = planner.chunks_by_point(self.chunks)
        self.executor = _Executor(aspec.base, mesh=mesh)
        self.max_chunks = max_chunks
        self.executed = 0
        self.probed: set[int] = set()
        # Resume: everything already in the store is a free probe.
        self.recs: dict[int, dict] = {r["index"]: r
                                      for r in self.store.records()}
        self.cached0 = len(self.store.completed())

    def probe(self, index: int) -> float:
        """Metric value at one dense grid point, executing its planned
        chunk if (and only if) the store does not hold it yet."""
        self.probed.add(index)
        if index not in self.recs:
            if (self.max_chunks is not None
                    and self.executed >= self.max_chunks):
                raise _Budget()
            chunk = self.by_point[index]
            records = self.executor.execute(chunk)
            self.store.put(chunk, records)
            self.executed += 1
            for r in records:
                self.recs[r["index"]] = r
        return float(self.recs[index][self.metric])


def _slices(spec: SweepSpec, axis: str
            ) -> dict[tuple, list[tuple[object, int]]]:
    """Per-slice ladders: slice key -> ordered [(axis value, index)].

    The ladder order is the spec's declared axis order (see
    :meth:`SweepSpec.axis_values`); positions the validity filter
    dropped (e.g. MAJ5 below its minimum activation) are simply absent.
    """
    fields = SEARCH_AXES[axis]
    values = list(spec.axis_values(axis))
    pos = {v: i for i, v in enumerate(values)}
    out: dict[tuple, list] = {}
    for p in spec.points():
        key = tuple((f, getattr(p, f)) for f in _POINT_FIELDS
                    if f not in fields)
        if axis == "timings":
            val = (p.t1, p.t2)
        elif axis == "n_act":
            val = p.n_act
        else:
            val = getattr(p, fields[0])
        out.setdefault(key, []).append((pos[val], val, p.index))
    return {k: [(v, i) for _, v, i in sorted(entries)]
            for k, entries in out.items()}


def _search_slice(prober: _Prober, aspec: AdaptiveSpec, axis: str,
                  slice_key: tuple, ladder: list[tuple[object, int]]
                  ) -> list[Crossing]:
    """Bisect one slice's ladder for every threshold crossing.

    Assumes the paper's plateau-cliff shape: the metric is treated as
    monotone along the axis between the endpoints, so bisection finds
    *the* crossing (on a non-monotone surface it finds *a* crossing).
    """
    m = len(ladder)
    s_first = prober.probe(ladder[0][1])
    s_last = prober.probe(ladder[-1][1])
    out = []
    for theta in aspec.thresholds:
        pred_first, pred_last = s_first >= theta, s_last >= theta
        if pred_first == pred_last:
            out.append(Crossing(axis=axis, threshold=theta,
                                slice_key=slice_key, crossed=False))
            continue
        lo, hi = 0, m - 1
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if (prober.probe(ladder[mid][1]) >= theta) == pred_first:
                lo = mid
            else:
                hi = mid
        # Local refinement: map the cliff shape around the bracket.
        for k in range(max(0, lo - aspec.refine_radius),
                       min(m, hi + 1 + aspec.refine_radius)):
            prober.probe(ladder[k][1])
        out.append(Crossing(
            axis=axis, threshold=theta, slice_key=slice_key, crossed=True,
            direction="falling" if pred_first else "rising",
            lo_index=ladder[lo][1], hi_index=ladder[hi][1],
            lo_value=ladder[lo][0], hi_value=ladder[hi][0]))
    return out


def run_adaptive(aspec: AdaptiveSpec, root: Optional[str] = None, *,
                 max_chunks: Optional[int] = None, mesh=None,
                 store: Optional[RecordStore] = None,
                 progress: bool = False) -> AdaptiveResult:
    """Run (or resume) an adaptive boundary-search campaign.

    The store is the wrapped dense spec's ordinary record store —
    adaptive and grid runs of the same spec are interchangeable
    consumers of it.  ``max_chunks`` bounds this invocation's chunk
    executions (kill simulation): the search stops mid-bisection and
    returns ``complete=False``; re-running resumes deterministically
    with zero recomputation.
    """
    spec = aspec.base
    if store is None:
        store = RecordStore(default_root(root), spec)
    prober = _Prober(aspec, store, mesh, max_chunks)
    crossings: list[Crossing] = []
    complete = True
    try:
        for axis in aspec.search_axes():
            for slice_key, ladder in _slices(spec, axis).items():
                if len(ladder) < 2:
                    continue  # nothing to bisect on this slice
                found = _search_slice(prober, aspec, axis, slice_key, ladder)
                crossings.extend(found)
                if progress:
                    for c in found:
                        print(f"[adaptive {spec.name}] {c.describe()}",
                              flush=True)
    except _Budget:
        complete = False

    return AdaptiveResult(
        spec=aspec, store_path=store.path, n_grid_points=spec.n_points(),
        n_probed=len(prober.probed), points_covered=len(prober.recs),
        executed_chunks=prober.executed, cached_chunks=prober.cached0,
        crossings=crossings, complete=complete,
        records=store.records())
