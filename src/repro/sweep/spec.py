"""Declarative sweep specifications: the paper's campaigns as data.

A characterization campaign in the SiMRA-DRAM paper is a cartesian grid:
operation x activation count x MAJ arity x data pattern x violated
timings x temperature x wordline voltage, repeated per chip (here: per
RNG seed / row-group identity) and — in this reproduction — per
execution backend.  :class:`SweepSpec` captures that grid declaratively;
everything downstream (planning, execution, storage, aggregation) is
derived from it, and the spec's content hash names the on-disk record
store so a restarted campaign resumes instead of recomputing.

Grid points that are physically invalid (e.g. MAJ5 with a 4-row
activation, which cannot hold five operands) are excluded at grid
construction time, mirroring the paper's own reachable-configuration
filtering (§4 Limitation 2).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Iterator

from repro.core import calibration as cal

#: Operations a sweep can characterize.
OPS = ("majx", "mrc", "simra")

#: The pseudo-backend that evaluates the calibrated ErrorModel surface
#: directly instead of executing data through an executor — exact at the
#: paper's anchors and cheap enough for full figure grids.
ANALYTIC = "analytic"

#: Data patterns each op accepts (§3.1; MRC uses single-row patterns).
MAJX_PATTERNS = cal.DATA_PATTERNS
MRC_PATTERNS = ("random", "0x00", "0xFF")

_BEST_TIMINGS = {
    "majx": (cal.MAJX_BEST_T1_NS, cal.MAJX_BEST_T2_NS),
    "mrc": (cal.MRC_BEST_T1_NS, cal.MRC_BEST_T2_NS),
    "simra": (cal.SIMRA_BEST_T1_NS, cal.SIMRA_BEST_T2_NS),
}

#: Axes the adaptive boundary search (:mod:`repro.sweep.adaptive`) can
#: bisect, mapped to the :class:`GridPoint` fields carrying their value.
#: ``timings`` is a joint (t1, t2) axis — one ladder position per pair —
#: and ``n_act`` also fixes the derived ``n_dest`` for ``mrc`` sweeps.
SEARCH_AXES = {
    "n_act": ("n_act", "n_dest"),
    "timings": ("t1", "t2"),
    "temp_c": ("temp_c",),
    "vpp_v": ("vpp_v",),
}


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One fully-resolved operating point of a sweep grid."""

    index: int
    op: str
    backend: str
    mfr: str
    x: int            # MAJ arity (0 for mrc/simra)
    n_act: int        # simultaneous-activation count
    n_dest: int       # Multi-RowCopy destinations (0 for majx/simra)
    pattern: str
    t1: float
    t2: float
    temp_c: float
    vpp_v: float
    seed: int

    def record_base(self) -> dict:
        """The point's identity as a flat JSON-able record prefix."""
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A declarative characterization campaign (see module docstring).

    Axes (each a tuple; the grid is their cartesian product, filtered
    for physical validity):

    * ``backends`` — executor names from :mod:`repro.backends`, or
      ``"analytic"`` for direct ErrorModel surface evaluation;
    * ``mfrs`` — manufacturer profiles (Table 1: "H"/"M"/"S");
    * ``x_values`` — MAJ arities (``majx`` only; ignored otherwise);
    * ``n_act`` — simultaneous-activation counts (``mrc`` copies to
      ``n_act - 1`` destinations, the paper's 1-source layout);
    * ``patterns`` — data patterns (op-specific vocabulary);
    * ``timings`` — (t1, t2) ns pairs; empty means the op's best point;
    * ``temps_c`` / ``vpps_v`` — environment;
    * ``seeds`` — chip / row-group identities (independent stable-cell
      masks in the ``sim`` backend).

    Trial geometry: each measured point executes ``rows`` independent
    row images of ``words`` uint32 words (``words * 32`` cells), the
    unit the per-point success rate is averaged over.
    """

    name: str
    op: str = "majx"
    backends: tuple[str, ...] = ("sim",)
    mfrs: tuple[str, ...] = ("H",)
    x_values: tuple[int, ...] = (3,)
    n_act: tuple[int, ...] = (32,)
    patterns: tuple[str, ...] = ("random",)
    timings: tuple[tuple[float, float], ...] = ()
    temps_c: tuple[float, ...] = (50.0,)
    vpps_v: tuple[float, ...] = (2.5,)
    seeds: tuple[int, ...] = (0,)

    rows: int = 2
    words: int = 16
    ideal: bool = False
    interpret: bool = True
    #: grid points per resumable execution chunk (the planner's unit).
    chunk: int = 8

    # ------------------------------------------------------------ validity
    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; expected one of {OPS}")
        vocab = MAJX_PATTERNS if self.op == "majx" else MRC_PATTERNS
        if self.op != "simra":
            bad = [p for p in self.patterns if p not in vocab]
            if bad:
                raise ValueError(f"invalid {self.op} patterns {bad}; "
                                 f"allowed: {vocab}")
        if self.op == "majx":
            for x in self.x_values:
                if x < 3 or x % 2 == 0:
                    raise ValueError(f"MAJX arity must be odd >= 3, got {x}")
        if self.op == "simra" and set(self.backends) != {ANALYTIC}:
            # Raw activation success has no executable digital analogue;
            # records must never claim a behavioural measurement here.
            raise ValueError(f"op='simra' is analytic-only; use "
                             f"backends=({ANALYTIC!r},)")
        from repro.backends import available_backends  # deferred: no cycle
        known = set(available_backends()) | {ANALYTIC}
        bad_be = [b for b in self.backends if b not in known]
        if bad_be:
            raise ValueError(f"unknown backends {bad_be}; "
                             f"available: {sorted(known)}")
        for n in self.n_act:
            if n not in cal.N_ACT_LEVELS:
                raise ValueError(f"n_act={n} not reachable "
                                 f"(Limitation 2; levels {cal.N_ACT_LEVELS})")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    # ---------------------------------------------------------------- grid
    def _timings(self) -> tuple[tuple[float, float], ...]:
        return self.timings or (_BEST_TIMINGS[self.op],)

    def points(self) -> Iterator[GridPoint]:
        """Yield the valid grid points in a stable, documented order.

        Axis nesting (outer to inner): backend, mfr, x, n_act, pattern,
        timing, temp, vpp, seed.  Indices are assigned *after* validity
        filtering, so they are dense and stable for a given spec.
        """
        xs = self.x_values if self.op == "majx" else (0,)
        pats = self.patterns if self.op != "simra" else ("random",)
        idx = 0
        for be, mfr, x, n, pat, (t1, t2), tc, vv, sd in itertools.product(
                self.backends, self.mfrs, xs, self.n_act, pats,
                self._timings(), self.temps_c, self.vpps_v, self.seeds):
            if self.op == "majx" and n < cal.min_activation_for(x):
                continue  # cannot hold X operands (§3.3)
            n_dest = n - 1 if self.op == "mrc" else 0
            yield GridPoint(idx, self.op, be, mfr, x, n, n_dest, pat,
                            t1, t2, tc, vv, sd)
            idx += 1

    def n_points(self) -> int:
        return sum(1 for _ in self.points())

    def axis_values(self, axis: str) -> tuple:
        """The declared value ladder of one searchable axis, in spec
        order (the order the author arranged — by convention increasing
        stress / activation count; see :data:`SEARCH_AXES`)."""
        if axis == "n_act":
            return self.n_act
        if axis == "timings":
            return self._timings()
        if axis == "temp_c":
            return self.temps_c
        if axis == "vpp_v":
            return self.vpps_v
        raise ValueError(f"unknown search axis {axis!r}; "
                         f"expected one of {tuple(SEARCH_AXES)}")

    def searchable_axes(self) -> tuple[str, ...]:
        """Axes with more than one declared value (boundary-searchable)."""
        return tuple(a for a in SEARCH_AXES
                     if len(self.axis_values(a)) > 1)

    # ------------------------------------------------------------ identity
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SweepSpec":
        raw = json.loads(text)
        for k, v in raw.items():
            if isinstance(v, list):
                raw[k] = tuple(tuple(e) if isinstance(e, list) else e
                               for e in v)
        return cls(**raw)

    def spec_hash(self) -> str:
        """Content hash naming the record store (12 hex chars).

        Covers the grid *and* the calibrated physics: the fingerprint of
        :mod:`repro.core.calibration` + :mod:`repro.core.errormodel` is
        folded in, so editing an anchor or a surface invalidates every
        cached campaign instead of silently serving pre-change records.
        """
        payload = self.to_json() + "|model:" + _model_fingerprint()
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    def store_name(self) -> str:
        return f"{self.name}-{self.spec_hash()}"

    def replace(self, **kw) -> "SweepSpec":
        return dataclasses.replace(self, **kw)


def _model_fingerprint() -> str:
    """Hash of the calibrated-physics sources records depend on."""
    import inspect

    from repro.core import calibration, errormodel
    src = inspect.getsource(calibration) + inspect.getsource(errormodel)
    return hashlib.sha256(src.encode()).hexdigest()[:8]


def load_spec(path: str) -> SweepSpec:
    """Read a SweepSpec from a JSON file (the CLI's ``--spec``)."""
    with open(path) as f:
        return SweepSpec.from_json(f.read())
