"""Sweep runner: execute planned chunks and stream records to the store.

Execution model:

* chunks already present in the :class:`~repro.sweep.store.RecordStore`
  are skipped (resume); the remainder is optionally partitioned across
  workers with ``num_shards`` / ``shard_index`` (disjoint by
  construction, see :func:`repro.sweep.planner.shard`);
* chunks execute through per-regime :class:`~repro.session.DramSession`
  instances; a chunk whose backend reports ``native_batch`` (``pallas``)
  lowers to an addressed single-level Program and executes through the
  session's compile-cached ``run_fused`` as one batched kernel dispatch
  (same-shaped chunks share one schedule); when a device mesh is supplied
  the stacked ``(B, X, R, C)`` batch instead goes through the vmapped
  ``majx_batch`` path placed with
  :func:`repro.dist.sharding.sharding_for` over the mesh's data axis,
  so the B grid points of the chunk spread across local devices;
* other backends execute point-by-point through the same bulk API;
* the ``analytic`` pseudo-backend evaluates the calibrated
  :class:`~repro.core.errormodel.ErrorModel` surface — exact at every
  paper anchor, no data movement.

Every record carries both the *measured* success rate (bit-compare
against the oracle reference, the paper's §3.1 metric) and the
*expected* success from the calibrated surface at the same operating
point, so aggregation can diff behaviour against calibration.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.backends import ExecutionContext, Timings
from repro.core.errormodel import ErrorModel
from repro.ft.elastic import ElasticMembership
from repro.ft.failures import WorkerLost
from repro.ft.straggler import StragglerDetector
from repro.session import CompileCache, DramSession
from repro.sweep import planner
from repro.sweep.spec import ANALYTIC, GridPoint, SweepSpec
from repro.sweep.store import RecordStore, default_root

#: Word values for the fixed data patterns of §3.1 (pairs alternate
#: across operand planes; single-valued patterns fill the row).
_PATTERN_WORDS = {
    "0x00/0xFF": (0x00000000, 0xFFFFFFFF),
    "0xAA/0x55": (0xAAAAAAAA, 0x55555555),
    "0xCC/0x33": (0xCCCCCCCC, 0x33333333),
    "0x66/0x99": (0x66666666, 0x99999999),
    "0x00": (0x00000000, 0x00000000),
    "0xFF": (0xFFFFFFFF, 0xFFFFFFFF),
}


def _rng(spec: SweepSpec, p: GridPoint) -> np.random.Generator:
    """Data generator keyed by everything *except* backend/environment.

    Two backends measuring the same logical point see identical input
    data, which is what makes cross-backend record parity meaningful.
    """
    return np.random.default_rng(
        [p.seed, p.x, p.n_act, spec.rows, spec.words, 0x51338A])


def _planes(pattern: str, shape: tuple[int, ...],
            rng: np.random.Generator) -> np.ndarray:
    if pattern == "random":
        return rng.integers(0, 2 ** 32, shape, dtype=np.uint32)
    a, b = _PATTERN_WORDS[pattern]
    out = np.empty(shape, dtype=np.uint32)
    # Alternate the pair along axis 0: across operand planes for MAJX
    # stacks, across words for a single MRC source row.
    out[0::2], out[1::2] = a, b
    return out


def _success(got, want) -> tuple[float, int]:
    got = np.asarray(got, np.uint32)
    want = np.asarray(want, np.uint32)
    n_bits = got.size * 32
    bad = int(np.unpackbits((got ^ want).view(np.uint8)).sum())
    return 1.0 - bad / n_bits, n_bits


def _context(spec: SweepSpec, p: GridPoint) -> ExecutionContext:
    timings = {"majx": dict(majx_t1=p.t1, majx_t2=p.t2),
               "mrc": dict(mrc_t1=p.t1, mrc_t2=p.t2),
               "simra": dict(simra_t1=p.t1, simra_t2=p.t2)}[p.op]
    return ExecutionContext(
        mfr=p.mfr, timings=Timings(**timings), temp_c=p.temp_c,
        vpp_v=p.vpp_v, pattern=p.pattern if p.op == "majx" else "random",
        ideal=spec.ideal, n_act=p.n_act, interpret=spec.interpret,
        seed=p.seed)


def _expected(p: GridPoint) -> float:
    em = ErrorModel(p.mfr)
    if p.op == "majx":
        return em.majx_success(p.x, p.n_act, t1=p.t1, t2=p.t2,
                               pattern=p.pattern, temp_c=p.temp_c,
                               vpp_v=p.vpp_v)
    if p.op == "mrc":
        return em.mrc_success(p.n_dest, t1=p.t1, t2=p.t2, pattern=p.pattern,
                              temp_c=p.temp_c, vpp_v=p.vpp_v)
    return em.simra_success(p.n_act, t1=p.t1, t2=p.t2, temp_c=p.temp_c,
                            vpp_v=p.vpp_v)


@dataclasses.dataclass
class SweepResult:
    """What one :func:`run_sweep` invocation did and produced.

    ``executed_chunks`` ran in this invocation; ``cached_chunks`` were
    already complete in the store; ``pending_chunks`` belong to other
    shards or fell past ``max_chunks`` — they are *not* done yet.
    """

    spec: SweepSpec
    store_path: str
    n_points: int
    executed_chunks: int
    cached_chunks: int
    pending_chunks: int
    records: list[dict]

    def summary(self) -> str:
        pending = (f", {self.pending_chunks} pending"
                   if self.pending_chunks else "")
        return (f"sweep '{self.spec.name}' [{self.spec.spec_hash()}]: "
                f"{self.n_points} points, {self.executed_chunks} chunks "
                f"executed, {self.cached_chunks} cached{pending} -> "
                f"{len(self.records)} records at {self.store_path}")


class _Executor:
    """Measurement engine for one sweep.

    Sessions (and the backend instances under them) are cached *per
    chunk* (see :meth:`execute`): a chunk's records must be a pure
    function of (spec, chunk) so that kill/resume and worker sharding —
    which change *which process* executes a chunk, and in what order —
    can never change measured values.  A process-lifetime cache would
    leak mutable backend state (e.g. the ``sim`` backend's round-robin
    subarray cursor) across chunks and break that guarantee.  The
    *compile* cache is the exception and is deliberately process-wide:
    a schedule is a pure function of program content, so same-shaped
    chunks across the whole campaign share one fused schedule.
    """

    def __init__(self, spec: SweepSpec, mesh=None,
                 cache: Optional[CompileCache] = None):
        self.spec = spec
        self.mesh = mesh
        self._sessions: dict[tuple, DramSession] = {}
        # The compile cache is thread-safe and content-pure, so the
        # fault-tolerant runner shares ONE across its worker executors.
        self._compile_cache = cache if cache is not None else CompileCache()
        self._oracle = DramSession("oracle", name="sweep-oracle")

    def session(self, p: GridPoint) -> DramSession:
        ctx = _context(self.spec, p)
        key = (p.backend, ctx)
        if key not in self._sessions:
            self._sessions[key] = DramSession(
                p.backend, ctx, cache=self._compile_cache,
                name=f"sweep-{p.backend}")
        return self._sessions[key]

    # ---------------------------------------------------------- per point
    def _measure_majx(self, p: GridPoint) -> dict:
        shape = (p.x, self.spec.rows, self.spec.words)
        planes = _planes(p.pattern, shape, _rng(self.spec, p))
        want = np.asarray(self._oracle.majx(planes))
        got = self.session(p).majx(planes, x=p.x, n_act=p.n_act)
        success, n_bits = _success(got, want)
        return dict(p.record_base(), success=success,
                    expected=_expected(p), n_bits=n_bits)

    def _measure_mrc(self, p: GridPoint) -> dict:
        src = _planes(p.pattern, (self.spec.words,), _rng(self.spec, p))
        want = np.asarray(self._oracle.rowcopy(src, p.n_dest))
        got = self.session(p).rowcopy(src, p.n_dest)
        success, n_bits = _success(got, want)
        return dict(p.record_base(), success=success,
                    expected=_expected(p), n_bits=n_bits)

    def _analytic(self, p: GridPoint) -> dict:
        s = _expected(p)
        return dict(p.record_base(), success=s, expected=s, n_bits=0)

    # --------------------------------------------------------- per chunk
    def _majx_batched(self, chunk: planner.Chunk) -> list[dict]:
        """One fused kernel dispatch for the whole chunk (pallas).

        The chunk lowers to an addressed single-level Program
        (:func:`repro.sweep.planner.fused_majx_program`) executed via
        the session's compile-cached ``run_fused`` — the same fusion
        engine the §8.1 programs use, and every same-shaped chunk after
        the first is a schedule-cache hit.  Under a device mesh the
        stacked batch instead goes through the sharded ``majx_batch``
        path, which places the B grid points across local devices
        (still one vmapped dispatch).
        """
        import jax

        pts = chunk.points
        rows, words = self.spec.rows, self.spec.words
        batch = np.stack([
            _planes(p.pattern, (p.x, rows, words),
                    _rng(self.spec, p)) for p in pts])  # (B, X, R, C)
        sess = self.session(pts[0])
        if self.mesh is not None:
            from repro.dist.sharding import sharding_for
            placed = jax.device_put(batch, sharding_for(
                batch.shape, ("batch", None, None, None), self.mesh))
            got = np.asarray(sess.majx_batch(placed))    # (B, R, C)
        else:
            prog, out_base = planner.fused_majx_program(pts, rows)
            state = np.concatenate([
                batch.reshape(-1, words),
                np.zeros((len(pts) * rows, words), np.uint32)])
            final = np.asarray(sess.run_fused(prog, state))
            got = final[out_base:].reshape(len(pts), rows, words)
        # Same reference source as the per-point path: the oracle backend.
        want = np.asarray(self._oracle.majx_batch(np.asarray(batch)))
        out = []
        for i, p in enumerate(pts):
            success, n_bits = _success(got[i], want[i])
            out.append(dict(p.record_base(), success=success,
                            expected=_expected(p), n_bits=n_bits))
        return out

    def execute(self, chunk: planner.Chunk) -> list[dict]:
        # Fresh sessions (and backends) per chunk: records depend only
        # on (spec, chunk), never on which chunks this process ran
        # before.  The shared compile cache survives — schedules are
        # content-pure.
        self._sessions.clear()
        if chunk.backend == ANALYTIC or self.spec.op == "simra":
            return [self._analytic(p) for p in chunk.points]
        if self.spec.op == "majx":
            caps = self.session(chunk.points[0]).capabilities()
            # The fused batch path runs the whole chunk under one
            # ExecutionContext, so it is only valid for backends whose
            # results are regime-insensitive (digital: no error
            # injection, no device model).  Regime-sensitive executors
            # fall back to per-point contexts — correct, just unfused.
            if (caps.native_batch and len(chunk.points) > 1
                    and not caps.stochastic and not caps.device_model
                    and len({p.x for p in chunk.points}) == 1):
                return self._majx_batched(chunk)
            return [self._measure_majx(p) for p in chunk.points]
        return [self._measure_mrc(p) for p in chunk.points]


def run_sweep(spec: SweepSpec, root: Optional[str] = None, *,
              num_shards: int = 1, shard_index: int = 0,
              max_chunks: Optional[int] = None, mesh=None,
              store: Optional[RecordStore] = None,
              progress: bool = False) -> SweepResult:
    """Execute (the missing part of) a sweep and return all records.

    Resume semantics: chunks whose files already exist in the store are
    never re-executed; a run over a fully-populated store performs zero
    executions.  ``max_chunks`` bounds this invocation's work (used by
    tests to simulate a mid-campaign kill); ``num_shards``/``shard_index``
    restrict this worker to its deterministic share of the plan.  Pass
    ``store=`` to supply a pre-bound :class:`RecordStore` (e.g. one on a
    non-default :class:`~repro.sweep.store.RecordStoreBackend`);
    ``root`` is ignored in that case.
    """
    if store is None:
        store = RecordStore(default_root(root), spec)
    chunks = planner.plan(spec)
    done = store.completed()
    todo = [c for c in planner.shard(chunks, num_shards, shard_index)
            if c.key not in done]
    if max_chunks is not None:
        todo = todo[:max_chunks]

    ex = _Executor(spec, mesh=mesh)
    for i, chunk in enumerate(todo):
        records = ex.execute(chunk)
        store.put(chunk, records)
        if progress:
            print(f"[sweep {spec.name}] {chunk.key} "
                  f"({i + 1}/{len(todo)}, {len(records)} points)",
                  flush=True)

    cached = sum(1 for c in chunks if c.key in done)
    return SweepResult(
        spec=spec, store_path=store.path, n_points=spec.n_points(),
        executed_chunks=len(todo), cached_chunks=cached,
        pending_chunks=len(chunks) - cached - len(todo),
        records=store.records())


def records_for(spec: SweepSpec, root: Optional[str] = None,
                **run_kw) -> list[dict]:
    """Records of a sweep, running whatever the store is missing."""
    return run_sweep(spec, root, **run_kw).records


# --------------------------------------------------------------------------
# fault-tolerant multi-worker driver
# --------------------------------------------------------------------------


@dataclasses.dataclass
class FtSweepResult:
    """What one :func:`run_sweep_ft` invocation did and produced.

    ``executed_chunks`` counts chunk executions (a re-dispatched chunk
    that both the straggler and the rescuer finish counts twice — the
    store keeps one copy, last ``os.replace`` wins with identical
    content); ``re_dispatched`` counts chunks stolen from flagged
    stragglers; ``lost_workers`` are workers that left the elastic
    membership mid-run.
    """

    spec: SweepSpec
    store_path: str
    n_points: int
    executed_chunks: int
    cached_chunks: int
    re_dispatched: int
    lost_workers: list[int]
    worker_chunks: dict[int, int]
    fleet_slowdown: float
    records: list[dict]

    def summary(self) -> str:
        lost = (f", lost workers {self.lost_workers}"
                if self.lost_workers else "")
        redisp = (f", {self.re_dispatched} re-dispatched"
                  if self.re_dispatched else "")
        return (f"ft-sweep '{self.spec.name}' [{self.spec.spec_hash()}]: "
                f"{self.n_points} points, {self.executed_chunks} chunks "
                f"executed across {len(self.worker_chunks)} workers, "
                f"{self.cached_chunks} cached{redisp}{lost} -> "
                f"{len(self.records)} records at {self.store_path}")


class _FtState:
    """Lock-guarded shared state of one fault-tolerant run."""

    def __init__(self, todo: list[planner.Chunk], n_workers: int,
                 threshold: float):
        self.lock = threading.Lock()
        self.todo = todo
        self.todo_keys = {c.key for c in todo}
        self.done: set[str] = set()
        self.claimed: dict[str, int] = {}
        self.inflight: dict[int, tuple[planner.Chunk, float]] = {}
        self.stolen: collections.deque[planner.Chunk] = collections.deque()
        self.redispatched: set[str] = set()
        self.executed_by: dict[int, int] = {w: 0 for w in range(n_workers)}
        self.membership = ElasticMembership(n_workers)
        self.detector = StragglerDetector(n_workers, threshold=threshold)
        self.error: Optional[BaseException] = None

    # Callers hold self.lock for every method below.
    def pick(self, worker: int) -> Optional[planner.Chunk]:
        """Next chunk for ``worker``: stolen work first, then its own
        share of the elastic partition over unclaimed pending chunks."""
        while self.stolen:
            chunk = self.stolen.popleft()
            if chunk.key not in self.done:
                self.claimed[chunk.key] = worker
                self.inflight[worker] = (chunk, time.monotonic())
                return chunk
        pending = [c for c in self.todo if c.key not in self.done
                   and c.key not in self.claimed]
        mine = self.membership.share(pending, worker)
        if not mine:
            return None
        chunk = mine[0]
        self.claimed[chunk.key] = worker
        self.inflight[worker] = (chunk, time.monotonic())
        return chunk

    def all_done(self) -> bool:
        return self.done >= self.todo_keys

    def flagged_stragglers(self, now: float) -> set[int]:
        """Workers the detector flags, counting in-flight elapsed time
        as a provisional sample — so a worker stuck on its *first*
        chunk (no completed sample yet) is still caught."""
        trial = StragglerDetector(
            self.detector.n_workers, alpha=self.detector.alpha,
            threshold=self.detector.threshold, ema=self.detector.ema.copy(),
            n_samples=self.detector.n_samples.copy())
        for wid, (_, t0) in self.inflight.items():
            trial.record(wid, now - t0)
        return set(trial.stragglers())


def _ft_worker(wid: int, spec: SweepSpec, store: RecordStore, st: _FtState,
               stop: threading.Event, cache: CompileCache, mesh,
               worker_hook, poll_s: float, progress: bool) -> None:
    ex = _Executor(spec, mesh=mesh, cache=cache)
    while not stop.is_set():
        with st.lock:
            if st.all_done():
                return
            chunk = st.pick(wid)
        if chunk is None:
            time.sleep(poll_s)
            continue
        t0 = time.monotonic()
        try:
            if worker_hook is not None:
                worker_hook(wid, chunk)
            records = ex.execute(chunk)
        except WorkerLost:
            with st.lock:
                st.membership.drop(wid)
                st.inflight.pop(wid, None)
                # Release the claim: the survivors' repartition covers it.
                if st.claimed.get(chunk.key) == wid:
                    del st.claimed[chunk.key]
            return
        except BaseException as e:  # surfaced by the monitor
            with st.lock:
                st.error = st.error or e
                st.membership.drop(wid)
                st.inflight.pop(wid, None)
                if st.claimed.get(chunk.key) == wid:
                    del st.claimed[chunk.key]
            return
        if stop.is_set():
            return  # run already complete; drop redundant duplicate work
        store.put(chunk, records)
        with st.lock:
            st.done.add(chunk.key)
            st.inflight.pop(wid, None)
            st.executed_by[wid] += 1
            st.detector.record(wid, max(time.monotonic() - t0, 1e-9))
        if progress:
            print(f"[ft-sweep {spec.name}] worker {wid} {chunk.key} "
                  f"({len(records)} points)", flush=True)


def run_sweep_ft(spec: SweepSpec, root: Optional[str] = None, *,
                 n_workers: int = 2,
                 worker_hook: Optional[Callable[[int, planner.Chunk],
                                               None]] = None,
                 straggler_threshold: float = 1.5,
                 straggler_timeout_s: float = 5.0,
                 poll_s: float = 0.02, mesh=None,
                 store: Optional[RecordStore] = None,
                 progress: bool = False) -> FtSweepResult:
    """Multi-worker :func:`run_sweep` with elastic membership and
    straggler re-dispatch (the ``repro.ft`` consumer).

    ``n_workers`` threads share one :class:`RecordStore` and one
    thread-safe compile cache; pending chunks are partitioned
    round-robin over the *live* worker roster
    (:class:`repro.ft.elastic.ElasticMembership`) and the partition
    replans whenever membership changes.  Per-chunk wall times feed a
    :class:`repro.ft.straggler.StragglerDetector`; a chunk in flight on
    a flagged straggler for longer than ``straggler_timeout_s`` is
    re-dispatched (once) to a healthy worker.  Both may finish — chunk
    files are atomic and records are a pure function of (spec, chunk),
    so the duplicate ``os.replace`` writes identical content and
    last-write wins harmlessly.

    ``worker_hook(worker_id, chunk)`` runs before every execution
    attempt; tests inject failures by raising
    :class:`repro.ft.failures.WorkerLost` (elastic drop) or by
    sleeping (straggler).  Raises ``RuntimeError`` if every worker is
    lost with chunks still pending.
    """
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if store is None:
        store = RecordStore(default_root(root), spec)
    chunks = planner.plan(spec)
    done0 = store.completed()
    todo = [c for c in chunks if c.key not in done0]
    cached = sum(1 for c in chunks if c.key in done0)
    st = _FtState(todo, n_workers, straggler_threshold)
    stop = threading.Event()

    if todo:
        cache = CompileCache()
        threads = [
            threading.Thread(
                target=_ft_worker, name=f"sweep-ft-{w}",
                args=(w, spec, store, st, stop, cache, mesh, worker_hook,
                      poll_s, progress),
                daemon=True)
            for w in range(n_workers)]
        for t in threads:
            t.start()
        try:
            while True:
                with st.lock:
                    if st.error is not None:
                        raise RuntimeError(
                            "sweep worker failed") from st.error
                    if st.all_done():
                        break
                    if not st.membership.live:
                        raise RuntimeError(
                            f"all {n_workers} workers lost with "
                            f"{len(st.todo_keys - st.done)} chunks pending")
                    now = time.monotonic()
                    flagged = st.flagged_stragglers(now)
                    for wid, (chunk, t0) in list(st.inflight.items()):
                        if (wid in flagged
                                and now - t0 > straggler_timeout_s
                                and chunk.key not in st.redispatched
                                and chunk.key not in st.done
                                and len(st.membership.live) > 1):
                            st.stolen.append(chunk)
                            st.redispatched.add(chunk.key)
                            if progress:
                                print(f"[ft-sweep {spec.name}] re-dispatch "
                                      f"{chunk.key} from straggler {wid}",
                                      flush=True)
                time.sleep(poll_s)
        finally:
            stop.set()
        for t in threads:
            t.join(timeout=poll_s * 5)  # stragglers may still be sleeping

    with st.lock:
        return FtSweepResult(
            spec=spec, store_path=store.path, n_points=spec.n_points(),
            executed_chunks=sum(st.executed_by.values()),
            cached_chunks=cached, re_dispatched=len(st.redispatched),
            lost_workers=list(st.membership.dropped),
            worker_chunks=dict(st.executed_by),
            fleet_slowdown=st.detector.fleet_slowdown(),
            records=store.records())
