"""Aggregation: reduce sweep records to the paper's headline numbers.

Records are the flat dicts produced by :mod:`repro.sweep.runner` (one
per grid point: identity fields + ``success`` + ``expected``).  The
reducers here are deliberately generic — group/filter/pivot — with the
paper's headline quantities (replication delta, data-pattern
sensitivity, temperature/voltage resilience) expressed on top of them,
so ``benchmarks/paper_figures.py`` and ``results/make_tables.py`` carry
no per-point loops of their own.

Every reducer accepts any ``Iterable[dict]`` — including one-shot
generators: functions that consume their input more than once
materialize it to a list exactly once at entry, so a generator argument
yields the same result as the equivalent list (regression-tested in
``tests/test_sweep.py``).
"""

from __future__ import annotations

import statistics
from typing import Callable, Iterable, Optional, Sequence


def filter_records(records: Iterable[dict], **eq) -> list[dict]:
    """Records whose fields equal every given keyword (e.g. x=3)."""
    return [r for r in records
            if all(r.get(k) == v for k, v in eq.items())]


def mean_success(records: Iterable[dict], field: str = "success",
                 **eq) -> float:
    """Mean of ``field`` over the matching records."""
    vals = [r[field] for r in filter_records(records, **eq)]
    if not vals:
        raise ValueError(f"no records match {eq}")
    return statistics.fmean(vals)


def group_mean(records: Iterable[dict], keys: Sequence[str],
               field: str = "success") -> dict[tuple, float]:
    """Pivot: mean of ``field`` per distinct tuple of ``keys`` values."""
    buckets: dict[tuple, list[float]] = {}
    for r in records:
        buckets.setdefault(tuple(r[k] for k in keys), []).append(r[field])
    return {k: statistics.fmean(v) for k, v in sorted(buckets.items())}


# ------------------------------------------------------- paper headlines


def replication_delta(records: Iterable[dict], x: int = 3, hi: int = 32,
                      lo: Optional[int] = None, **eq) -> float:
    """Obs 6/10: relative success gain of ``n_act=hi`` over ``n_act=lo``.

    Defaults to the paper's headline MAJ3@32-row vs @4-row comparison;
    returned as a relative fraction (0.3081 means +30.81 %).
    """
    from repro.core import calibration as cal

    records = list(records)  # consumed twice below
    lo = lo if lo is not None else cal.min_activation_for(x)
    s_hi = mean_success(records, x=x, n_act=hi, **eq)
    s_lo = mean_success(records, x=x, n_act=lo, **eq)
    return s_hi / s_lo - 1.0


def pattern_sensitivity(records: Iterable[dict], **eq) -> dict[int, float]:
    """Obs 9: per arity, mean relative effect of fixed patterns vs random."""
    recs = filter_records(records, **eq)
    out: dict[int, float] = {}
    for x in sorted({r["x"] for r in recs}):
        base = mean_success(recs, x=x, pattern="random")
        fixed = [r["success"] for r in filter_records(recs, x=x)
                 if r["pattern"] != "random"]
        if fixed and base > 0:
            out[x] = statistics.fmean(fixed) / base - 1.0
    return out


def env_resilience(records: Iterable[dict], field: str,
                   baseline: float, **eq) -> float:
    """Obs 3/4/11-13/17/18: max relative success variation across an
    environment axis (``temp_c`` or ``vpp_v``) vs its nominal value.

    Groups with no record at the nominal ``baseline`` value are skipped
    (their variation is undefined).  A group whose baseline success is
    exactly ``0.0`` is *not* skipped: if it succeeds anywhere else on
    the axis its relative variation is unbounded and the function
    returns ``inf``; if it fails everywhere it contributes 0 variation.
    """
    recs = filter_records(records, **eq)
    groups = group_mean(recs, ("x", "n_act", "n_dest"))
    worst = 0.0
    for (x, n_act, n_dest), _ in groups.items():
        sub = filter_records(recs, x=x, n_act=n_act, n_dest=n_dest)
        by_env = group_mean(sub, (field,))
        base = by_env.get((baseline,))
        if base is None:
            continue  # no measurement at nominal conditions
        if base == 0.0:
            if any(v != 0.0 for v in by_env.values()):
                worst = float("inf")
            continue
        for v in by_env.values():
            worst = max(worst, abs(v / base - 1.0))
    return worst


def headline(records: Iterable[dict]) -> dict[str, float]:
    """Every headline quantity computable from the given records."""
    records = list(records)  # consumed once per headline below
    out: dict[str, float] = {}
    xs = {r["x"] for r in records}
    n_acts = {r["n_act"] for r in records}
    pats = {r["pattern"] for r in records}
    try:
        if 3 in xs and {4, 32} <= n_acts:
            out["maj3_32_over_4_rel"] = replication_delta(records)
    except ValueError:
        pass
    if len(pats) > 1 and "random" in pats:
        for x, d in pattern_sensitivity(records).items():
            out[f"pattern_effect_x{x}_rel"] = d
    for field, base, key in (("temp_c", 50.0, "temp_variation_max_rel"),
                             ("vpp_v", 2.5, "vpp_variation_max_rel")):
        if len({r[field] for r in records}) > 1:
            out[key] = env_resilience(records, field, base)
    return out


def success_table(records: Iterable[dict], row_keys: Sequence[str],
                  fmt: Callable[[float], str] = "{:.4f}".format
                  ) -> list[str]:
    """Markdown table of mean success per ``row_keys`` group."""
    lines = ["| " + " | ".join(row_keys) + " | success |",
             "|" + "---|" * (len(row_keys) + 1)]
    for key, s in group_mean(records, row_keys).items():
        cells = " | ".join(str(k) for k in key)
        lines.append(f"| {cells} | {fmt(s)} |")
    return lines
