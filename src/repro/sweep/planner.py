"""Grid planner: carve a sweep grid into backend-native batches.

The runner's unit of work (and of resume) is a :class:`Chunk` — a
contiguous slice of grid points that one backend can execute as a
single batch.  Points are grouped by *batch signature* before chunking:

* ``majx``: (backend, x, rows, words) — every point in the chunk stacks
  to one ``(B, X, R, C)`` tensor, which the ``pallas`` backend dispatches
  as a single vmapped ``majx_batch`` kernel launch and the ``sim`` /
  ``oracle`` backends execute point-by-point;
* ``mrc``: (backend, n_dest) — bulk ``rowcopy`` calls share a fan-out;
* ``simra`` / ``analytic``: (backend,) — vectorized surface evaluation.

Chunk keys are derived from the dense point indices, which are stable
for a given spec (see :meth:`repro.sweep.spec.SweepSpec.points`), so a
restarted campaign maps its chunks onto the completed set exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.pud.isa import Program
from repro.sweep.spec import ANALYTIC, GridPoint, SweepSpec


@dataclasses.dataclass(frozen=True)
class Chunk:
    """A batch of grid points executed and persisted as one unit."""

    key: str
    backend: str
    points: tuple[GridPoint, ...]

    @property
    def indices(self) -> tuple[int, ...]:
        return tuple(p.index for p in self.points)


def _signature(spec: SweepSpec, p: GridPoint) -> tuple:
    if p.backend == ANALYTIC or spec.op == "simra":
        return (p.backend,)
    if spec.op == "majx":
        return (p.backend, p.x, spec.rows, spec.words)
    return (p.backend, p.n_dest)


def _chunk_key(points: Iterable[GridPoint]) -> str:
    idx = [p.index for p in points]
    return f"chunk-{min(idx):06d}-{max(idx):06d}"


def plan(spec: SweepSpec) -> list[Chunk]:
    """All chunks of a sweep, in deterministic execution order."""
    groups: dict[tuple, list[GridPoint]] = {}
    order: list[tuple] = []
    for p in spec.points():
        sig = _signature(spec, p)
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(p)

    chunks: list[Chunk] = []
    for sig in order:
        pts = groups[sig]
        for i in range(0, len(pts), spec.chunk):
            batch = tuple(pts[i:i + spec.chunk])
            chunks.append(Chunk(_chunk_key(batch), batch[0].backend, batch))
    return chunks


def fused_majx_program(points: Sequence[GridPoint], rows: int
                       ) -> tuple[Program, int]:
    """Lower one majx chunk to an addressed Program for ``run_fused``.

    Row layout of the expected state image (width = ``spec.words``):
    operand plane ``i`` of point ``b``'s row-image ``r`` lives at row
    ``(b * x + i) * rows + r``; the chunk's stacked ``(B, X, R, C)``
    data tensor reshapes to exactly this (then ``B * R`` zeroed output
    rows are appended).  Every MAJ op is independent, so the whole chunk
    is one dependency level — one batched kernel dispatch on the
    ``pallas`` backend, the same fusion the §8.1 programs get, instead
    of a planner-private batching path.

    Returns ``(program, out_base)`` with outputs for point ``b`` at rows
    ``out_base + b * rows + r``.
    """
    x = points[0].x
    prog = Program()
    out_base = len(points) * x * rows
    for b, p in enumerate(points):
        for r in range(rows):
            prog.emit(
                "MAJ", x=x, n_act=p.n_act, tag=f"sweep/pt{p.index}[{r}]",
                srcs=tuple((b * x + i) * rows + r for i in range(x)),
                dsts=(out_base + b * rows + r,))
    return prog, out_base


def chunks_by_point(chunks: Iterable[Chunk]) -> dict[int, Chunk]:
    """Map every grid-point index to the chunk that executes it.

    The adaptive boundary search (:mod:`repro.sweep.adaptive`) probes
    individual grid points but executes/persists whole planned chunks,
    so its stores stay interchangeable with grid-mode stores.
    """
    return {p.index: c for c in chunks for p in c.points}


def shard(chunks: list[Chunk], num_shards: int, shard_index: int
          ) -> list[Chunk]:
    """Round-robin partition of chunks across ``num_shards`` workers.

    Deterministic in chunk order, so independent workers given the same
    spec agree on the partition without coordination; each worker writes
    disjoint chunk files into the shared record store.
    """
    if not 0 <= shard_index < num_shards:
        raise ValueError(f"shard_index {shard_index} outside "
                         f"[0, {num_shards})")
    return [c for i, c in enumerate(chunks) if i % num_shards == shard_index]
