"""CLI: run characterization sweeps.

.. code-block:: bash

    # <=16-point executable smoke grid on sim + pallas (interpret):
    python -m repro.sweep.run --smoke

    # one paper figure's grid (see --list-figures):
    python -m repro.sweep.run --figure fig6

    # a custom campaign from a JSON spec, worker 2 of 4:
    python -m repro.sweep.run --spec campaign.json --shards 4 --shard-index 2

    # adaptive boundary search instead of the dense grid:
    python -m repro.sweep.run --adaptive            # the adaptive smoke
    python -m repro.sweep.run --adaptive --figure fig6

    # fault-tolerant multi-worker run (elastic membership, straggler
    # re-dispatch) inside one process:
    python -m repro.sweep.run --smoke --workers 4

Record stores land under ``--root`` (default: ``$REPRO_SWEEP_ROOT`` if
set, else the repo-relative ``results/sweeps`` — see "Resume semantics"
in ``docs/SWEEPS.md`` for the precedence), one directory per spec hash.
Re-running with an unchanged spec executes only missing chunks;
``--expect-cached`` turns "nothing left to execute" into an exit-code
assertion, which is how CI verifies resume semantics for both grid and
adaptive campaigns.
"""

from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.sweep import aggregate, presets
from repro.sweep.adaptive import AdaptiveSpec, run_adaptive
from repro.sweep.runner import run_sweep, run_sweep_ft
from repro.sweep.spec import SweepSpec, load_spec


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.sweep.run",
        description="Run a characterization sweep (see docs/SWEEPS.md).")
    what = p.add_mutually_exclusive_group()
    what.add_argument("--smoke", action="store_true",
                      help="<=16-point executable parity grid")
    what.add_argument("--figure", metavar="NAME",
                      help="a paper-figure preset (--list-figures)")
    what.add_argument("--spec", metavar="FILE",
                      help="JSON SweepSpec file")
    p.add_argument("--list-figures", action="store_true",
                   help="list figure presets and exit")
    p.add_argument("--adaptive", action="store_true",
                   help="boundary-search the grid instead of executing it "
                        "densely (with no --smoke/--figure/--spec: the "
                        "adaptive smoke ladder)")
    p.add_argument("--root", default=None,
                   help="record-store root (default: $REPRO_SWEEP_ROOT, "
                        "else <repo>/results/sweeps; see docs/SWEEPS.md)")
    p.add_argument("--backends", default=None,
                   help="comma-separated backend override, e.g. sim,pallas")
    p.add_argument("--shards", type=int, default=1,
                   help="total cooperating worker *processes* (disjoint "
                        "deterministic partition; dense mode only)")
    p.add_argument("--shard-index", type=int, default=0,
                   help="this worker's index in [0, --shards)")
    p.add_argument("--workers", type=int, default=1,
                   help="in-process fault-tolerant worker threads "
                        "(elastic membership + straggler re-dispatch; "
                        "dense mode only)")
    p.add_argument("--max-chunks", type=int, default=None,
                   help="stop after N chunks (partial run; resumable)")
    p.add_argument("--expect-cached", action="store_true",
                   help="fail if any chunk had to execute (CI resume check)")
    p.add_argument("--quiet", action="store_true",
                   help="suppress per-chunk progress lines")
    return p


def _resolve_spec(args) -> SweepSpec:
    if args.spec:
        spec = load_spec(args.spec)
    elif args.figure:
        try:
            spec = presets.FIGURE_SPECS[args.figure]()
        except KeyError:
            sys.exit(f"unknown figure {args.figure!r}; "
                     f"known: {sorted(presets.FIGURE_SPECS)}")
    elif args.adaptive:  # bare --adaptive runs the adaptive smoke ladder
        return presets.adaptive_smoke_spec().base
    else:  # --smoke is also the default action
        spec = presets.smoke_spec()
    if args.backends:
        try:
            spec = spec.replace(backends=tuple(args.backends.split(",")))
        except ValueError as e:
            sys.exit(str(e))
    return spec


def _print_aggregates(records: list[dict]) -> None:
    if not records:
        return
    head = aggregate.headline(records)
    for k, v in head.items():
        print(f"  {k} = {v:+.4f}")
    by_op = aggregate.group_mean(records, ("op", "backend"))
    for (op, be), s in by_op.items():
        print(f"  mean success [{op}/{be}] = {s:.4f}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_figures:
        for name, builder in presets.FIGURE_SPECS.items():
            print(f"{name:8s} {builder.__doc__.splitlines()[0]}")
        return 0
    if args.adaptive and (args.shards != 1 or args.workers != 1):
        sys.exit("--adaptive is a sequential search; it cannot be combined "
                 "with --shards/--workers")

    spec = _resolve_spec(args)

    if args.adaptive:
        if args.smoke or args.figure or args.spec:
            aspec = AdaptiveSpec(base=spec)
        else:
            aspec = presets.adaptive_smoke_spec()
        result = run_adaptive(aspec, args.root, max_chunks=args.max_chunks,
                              progress=not args.quiet)
        print(result.summary())
        for c in result.crossings:
            print(f"  {c.describe()}")
        _print_aggregates(result.records)
        if args.expect_cached and result.executed_chunks:
            print(f"--expect-cached: {result.executed_chunks} chunks "
                  f"executed (wanted 0)", file=sys.stderr)
            return 1
        return 0

    if args.workers > 1:
        result = run_sweep_ft(spec, args.root, n_workers=args.workers,
                              progress=not args.quiet)
    else:
        result = run_sweep(
            spec, args.root, num_shards=args.shards,
            shard_index=args.shard_index, max_chunks=args.max_chunks,
            progress=not args.quiet)
    print(result.summary())
    _print_aggregates(result.records)

    if args.expect_cached and result.executed_chunks:
        print(f"--expect-cached: {result.executed_chunks} chunks executed "
              f"(wanted 0)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
