"""CLI: run characterization sweeps.

.. code-block:: bash

    # <=16-point executable smoke grid on sim + pallas (interpret):
    python -m repro.sweep.run --smoke

    # one paper figure's grid (see --list-figures):
    python -m repro.sweep.run --figure fig6

    # a custom campaign from a JSON spec, worker 2 of 4:
    python -m repro.sweep.run --spec campaign.json --shards 4 --shard-index 2

Record stores land under ``--root`` (default ``$REPRO_SWEEP_ROOT`` or
``./results/sweeps``), one directory per spec hash.  Re-running with an
unchanged spec executes only missing chunks; ``--expect-cached`` turns
"nothing left to execute" into an exit-code assertion, which is how CI
verifies resume semantics.
"""

from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.sweep import aggregate, presets
from repro.sweep.runner import run_sweep
from repro.sweep.spec import SweepSpec, load_spec


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.sweep.run",
        description="Run a characterization sweep (see docs/SWEEPS.md).")
    what = p.add_mutually_exclusive_group()
    what.add_argument("--smoke", action="store_true",
                      help="<=16-point executable parity grid")
    what.add_argument("--figure", metavar="NAME",
                      help="a paper-figure preset (--list-figures)")
    what.add_argument("--spec", metavar="FILE",
                      help="JSON SweepSpec file")
    p.add_argument("--list-figures", action="store_true",
                   help="list figure presets and exit")
    p.add_argument("--root", default=None,
                   help="record-store root (default: $REPRO_SWEEP_ROOT "
                        "or ./results/sweeps)")
    p.add_argument("--backends", default=None,
                   help="comma-separated backend override, e.g. sim,pallas")
    p.add_argument("--shards", type=int, default=1,
                   help="total workers cooperating on this sweep")
    p.add_argument("--shard-index", type=int, default=0,
                   help="this worker's index in [0, --shards)")
    p.add_argument("--max-chunks", type=int, default=None,
                   help="stop after N chunks (partial run; resumable)")
    p.add_argument("--expect-cached", action="store_true",
                   help="fail if any chunk had to execute (CI resume check)")
    p.add_argument("--quiet", action="store_true",
                   help="suppress per-chunk progress lines")
    return p


def _resolve_spec(args) -> SweepSpec:
    if args.spec:
        spec = load_spec(args.spec)
    elif args.figure:
        try:
            spec = presets.FIGURE_SPECS[args.figure]()
        except KeyError:
            sys.exit(f"unknown figure {args.figure!r}; "
                     f"known: {sorted(presets.FIGURE_SPECS)}")
    else:  # --smoke is also the default action
        spec = presets.smoke_spec()
    if args.backends:
        try:
            spec = spec.replace(backends=tuple(args.backends.split(",")))
        except ValueError as e:
            sys.exit(str(e))
    return spec


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_figures:
        for name, builder in presets.FIGURE_SPECS.items():
            print(f"{name:8s} {builder.__doc__.splitlines()[0]}")
        return 0

    spec = _resolve_spec(args)
    result = run_sweep(
        spec, args.root, num_shards=args.shards,
        shard_index=args.shard_index, max_chunks=args.max_chunks,
        progress=not args.quiet)
    print(result.summary())

    if result.records:
        head = aggregate.headline(result.records)
        for k, v in head.items():
            print(f"  {k} = {v:+.4f}")
        by_op = aggregate.group_mean(result.records, ("op", "backend"))
        for (op, be), s in by_op.items():
            print(f"  mean success [{op}/{be}] = {s:.4f}")

    if args.expect_cached and result.executed_chunks:
        print(f"--expect-cached: {result.executed_chunks} chunks executed "
              f"(wanted 0)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
