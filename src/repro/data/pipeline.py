"""Data pipeline: deterministic synthetic token streams + packing.

Production posture: the loader is an iterator of already-sharded global
batches keyed by (step, host) so that restarts resume mid-epoch
deterministically (checkpoint stores the step counter only — no loader
state to snapshot) and elastic re-meshes re-shard cleanly.  The synthetic
source is a fixed-seed Markov-ish token process with enough structure that
cross-entropy demonstrably falls during the example runs (examples/).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 0     # audio
    n_patches: int = 0       # vlm
    d_model: int = 0         # vlm patch dim


def _structured_tokens(rng: np.random.Generator, shape, vocab: int):
    """Tokens with learnable structure: x[t+1] = (a*x[t] + b + noise) % V."""
    a = 31, 7
    base = rng.integers(0, vocab, size=shape[:-1] + (1,), dtype=np.int64)
    steps = np.arange(shape[-1], dtype=np.int64)
    seq = (base * a[0] + steps * a[1]) % vocab
    noise = rng.integers(0, vocab, size=shape)
    use_noise = rng.random(shape) < 0.1
    return np.where(use_noise, noise, seq).astype(np.int32)


class SyntheticLM:
    """Deterministic synthetic LM batches; batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        if c.n_codebooks:
            shape = (c.global_batch, c.seq_len + 1, c.n_codebooks)
            toks = _structured_tokens(rng, (c.global_batch, c.n_codebooks,
                                            c.seq_len + 1), c.vocab_size)
            toks = toks.transpose(0, 2, 1)
            out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        else:
            toks = _structured_tokens(rng, (c.global_batch, c.seq_len + 1),
                                      c.vocab_size)
            out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.n_patches:
            out["patches"] = rng.standard_normal(
                (c.global_batch, c.n_patches, c.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def loader_for(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
               global_batch: Optional[int] = None) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=global_batch or shape.global_batch,
        seed=seed,
        n_codebooks=cfg.n_codebooks,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
        d_model=cfg.d_model,
    ))


def pack_documents(docs: list[np.ndarray], seq_len: int, pad_id: int = 0):
    """Greedy sequence packing with boundary masks (standard pretraining
    packing; exercised by tests as the 'real data path' stand-in)."""
    out_tokens, out_mask, out_segments = [], [], []
    cur, seg, seg_id = [], [], 1
    for doc in docs:
        d = list(doc)
        while d:
            space = seq_len - len(cur)
            take, d = d[:space], d[space:]
            cur.extend(take)
            seg.extend([seg_id] * len(take))
            if len(cur) == seq_len:
                out_tokens.append(cur)
                out_mask.append([1] * seq_len)
                out_segments.append(seg)
                cur, seg = [], []
                seg_id += 1
        seg_id += 1
    if cur:
        pad = seq_len - len(cur)
        out_tokens.append(cur + [pad_id] * pad)
        out_mask.append([1] * len(cur) + [0] * pad)
        out_segments.append(seg + [0] * pad)
    return (np.asarray(out_tokens, np.int32), np.asarray(out_mask, np.int32),
            np.asarray(out_segments, np.int32))
