"""Distributed-execution utilities: logical-axis sharding rules."""

from repro.dist.sharding import (  # noqa: F401
    AxisRules, DEFAULT_RULES, SERVE_RULES, axis_extent, constraint,
    sharding_for, tree_shardings, use_rules,
)
