"""Logical-axis sharding: one rule table maps model axes to mesh axes.

Model code annotates tensors with *logical* axes (``"batch"``, ``"fsdp"``,
``"tp"``, ``"sp"``, ``"expert"``, ``"kv_batch"``); this module owns the
single mapping from those names onto the physical mesh axes (``pod``,
``data``, ``model``).  Swapping the active :class:`AxisRules` re-lays-out
the whole model without touching a single layer definition — that is how
serving flips to the activation-stationary layout (§Perf H3) and how the
elastic re-mesh path recomputes every sharding after a topology change.

Key invariants:

* **No mesh, no constraint** — outside a mesh context every helper
  degrades to a no-op / replicated sharding, so unit tests on one CPU
  device never pay a layout cost.
* **Indivisible dims replicate** — a logical axis whose mesh extent does
  not divide the tensor dim is dropped (replicated), never erroring
  (e.g. ``long_500k``'s global batch of 1 on a 16-way data axis).
* **Each physical axis is used at most once per spec** (SPMD requirement).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: Logical axis annotation: a tuple of logical names (or None) per dim.
Axes = Sequence[Optional[str]]


class AxisRules:
    """An immutable logical-axis -> physical-mesh-axes mapping."""

    def __init__(self, name: str, mapping: Mapping[str, tuple[str, ...]]):
        self.name = name
        self.mapping = dict(mapping)

    def physical(self, logical: Optional[str]) -> tuple[str, ...]:
        """Physical mesh axes a logical axis shards over ('' -> none)."""
        if logical is None:
            return ()
        return tuple(self.mapping.get(logical, ()))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"AxisRules({self.name!r})"


#: Training layout: batch-family axes over the data-parallel grid
#: (pod x data), weight/tensor axes over the model grid.  ``sp`` is the
#: sequence-parallel fallback when a head count does not divide TP.
DEFAULT_RULES = AxisRules("default", {
    "batch": ("pod", "data"),
    "kv_batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tp": ("model",),
    "sp": ("model",),
    "expert": ("model",),
})

#: Serving layout (activation-stationary, §Perf H3): per-token activations
#: replicate (their resharding is KBs but happens every decode step) while
#: the KV cache stays sharded over the data grid (gathering it is GBs).
SERVE_RULES = AxisRules("serve", {
    "batch": (),
    "kv_batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tp": ("model",),
    "sp": ("model",),
    "expert": ("model",),
})


_STATE = threading.local()


def _active_rules() -> AxisRules:
    return getattr(_STATE, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    """Swap the active rule table inside the context (thread-local)."""
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        if prev is None:
            del _STATE.rules
        else:
            _STATE.rules = prev


def _current_mesh() -> Optional[Mesh]:
    """The mesh entered via ``with mesh:``, or None outside any."""
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


def axis_extent(logical: str, rules: Optional[AxisRules] = None,
                mesh: Optional[Mesh] = None) -> int:
    """Product of mesh extents a logical axis shards over (1 off-mesh)."""
    mesh = mesh if mesh is not None else _current_mesh()
    if mesh is None:
        return 1
    rules = rules or _active_rules()
    extent = 1
    for a in rules.physical(logical):
        if a in mesh.axis_names:
            extent *= mesh.shape[a]
    return extent


def _spec_entries(axes: Axes, mesh: Mesh, rules: AxisRules,
                  shape: Optional[Sequence[int]] = None) -> list:
    """PartitionSpec entries for one tensor; drops unusable mappings."""
    entries: list = []
    used: set[str] = set()
    for i, logical in enumerate(axes):
        phys = [a for a in rules.physical(logical)
                if a in mesh.axis_names and a not in used]
        extent = 1
        for a in phys:
            extent *= mesh.shape[a]
        if not phys or extent <= 1:
            entries.append(None)
            continue
        if shape is not None and shape[i] % extent != 0:
            entries.append(None)  # indivisible: replicate this dim
            continue
        used.update(phys)
        entries.append(tuple(phys) if len(phys) > 1 else phys[0])
    return entries


def sharding_for(shape: Sequence[int], axes: Axes, mesh: Mesh,
                 rules: Optional[AxisRules] = None) -> NamedSharding:
    """NamedSharding for a concrete shape (indivisible dims replicate)."""
    rules = rules or _active_rules()
    return NamedSharding(
        mesh, P(*_spec_entries(tuple(axes), mesh, rules, tuple(shape))))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(axes_tree, mesh: Mesh,
                   rules: Optional[AxisRules] = None):
    """Map a pytree of logical-axes tuples to NamedShardings.

    Leaves are tuples of logical names / None (the empty tuple is a
    scalar leaf -> fully replicated).  Shape-unaware: divisibility is the
    annotator's contract here (shape-aware callers use
    :func:`sharding_for`).
    """
    rules = rules or _active_rules()

    def to_sharding(axes):
        return NamedSharding(mesh, P(*_spec_entries(axes, mesh, rules)))

    return jax.tree.map(to_sharding, axes_tree, is_leaf=_is_axes_leaf)


def constraint(x: jax.Array, axes: Axes) -> jax.Array:
    """Apply a logical-axes sharding constraint (no-op outside a mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    rules = _active_rules()
    entries = _spec_entries(tuple(axes), mesh, rules, x.shape)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
