"""Training loop, checkpoint/restart, TMR store, elastic resharding,
gradient compression, straggler detection, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.ckpt import checkpoint as ckpt
from repro.ckpt import tmr_store
from repro.data.pipeline import DataConfig, SyntheticLM, pack_documents
from repro.ft.elastic import plan_remesh
from repro.ft.failures import FailurePlan
from repro.ft.straggler import StragglerDetector
from repro.optim import compression as comp
from repro.train.step import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _small():
    cfg = get_config("xlstm-125m", smoke=True)
    tc = TrainConfig(lr=3e-3, total_steps=30, warmup_steps=3)
    loader = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4))
    return cfg, tc, loader


def test_loss_decreases():
    cfg, tc, loader = _small()
    t = Trainer(cfg, tc, loader, TrainerConfig(log_every=1000),
                log_fn=lambda *_: None)
    hist = t.run(25)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_microbatching_matches_full_batch():
    cfg = get_config("chatglm3-6b", smoke=True)
    loader = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4))
    batch = loader.batch(0)
    s1, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    s2, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    st1 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=1)))
    st2 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=2)))
    s1, m1 = st1(s1, batch)
    s2, m2 = st2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_checkpoint_roundtrip(tmp_path):
    cfg, tc, loader = _small()
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    ckpt.save(state, str(tmp_path), 7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, step = ckpt.restore(state, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_checkpoint_detects_corruption(tmp_path):
    cfg, tc, loader = _small()
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    path = ckpt.save(state, str(tmp_path), 1)
    shard = os.path.join(path, "shard_p0.npz")
    blob = bytearray(open(shard, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(shard, "wb").write(bytes(blob))
    with pytest.raises(Exception):
        ckpt.restore(state, str(tmp_path))


def test_tmr_store_heals_corrupted_replica(tmp_path):
    cfg, tc, loader = _small()
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    tmr_store.save(state, str(tmp_path), 3, replicas=3)
    # corrupt one replica's payload
    shard = os.path.join(str(tmp_path), "replica_1", "step_00000003",
                         "shard_p0.npz")
    blob = bytearray(open(shard, "rb").read())
    blob[len(blob) // 3] ^= 0xFF
    open(shard, "wb").write(bytes(blob))
    restored, step, healed = tmr_store.restore(state, str(tmp_path))
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_trainer_restarts_after_failure(tmp_path):
    cfg, tc, loader = _small()
    t = Trainer(cfg, tc, loader,
                TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                              log_every=1000),
                failure_plan=FailurePlan(at_steps=(12,)),
                log_fn=lambda *_: None)
    hist = t.run(20)
    steps = [h["step"] for h in hist]
    assert 12 in steps and 19 in steps
    # step 10..11 replayed after restart from the step-10 checkpoint
    assert steps.count(11) >= 2


def test_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (256,))}
    fb = comp.init_feedback(grads)
    dec, fb, stats = comp.compress(grads, fb, "int8")
    err = np.abs(np.asarray(dec["w"] - grads["w"])).max()
    assert err < 0.05  # int8 quantization error bounded
    assert stats["wire_bytes_frac"] == 0.25
    # residual carries the quantization error
    assert np.allclose(np.asarray(fb.residual["w"]),
                       np.asarray(grads["w"] - dec["w"]), atol=1e-6)


def test_topk_compression_sparsity():
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (1000,))}
    fb = comp.init_feedback(grads)
    dec, fb, _ = comp.compress(grads, fb, "topk", topk_frac=0.05)
    nz = float(jnp.sum(dec["w"] != 0))
    assert nz <= 60


def test_straggler_detector():
    d = StragglerDetector(n_workers=8)
    for w in range(8):
        for _ in range(5):
            d.record(w, 1.0 if w != 3 else 2.5)
    assert d.stragglers() == [3]
    assert d.fleet_slowdown() > 2.0


def test_straggler_detector_zero_step_time_is_a_sample():
    """Regression: a measured 0.0 step time used to look identical to
    the cold 'no samples yet' sentinel (ema == 0), silently excluding
    that worker from straggler math.  Sample counts are now explicit."""
    d = StragglerDetector(n_workers=3)
    d.record(0, 0.0)   # instant worker: a real measurement
    d.record(1, 0.1)
    d.record(2, 5.0)
    # worker 0's 0.0 participates: the median is 0.1 and worker 2 is
    # flagged against it rather than against a roster that forgot w0.
    assert d.stragglers() == [2]
    assert d.fleet_slowdown() > 10.0


def test_straggler_detector_seeded_and_cold_workers():
    # Seeded EMAs count as warm (one prior sample each)...
    d = StragglerDetector(n_workers=2, ema=np.array([1.0, 4.0]))
    assert d.stragglers() == [1]
    # ...while a cold worker (no samples) is excluded until it reports.
    d2 = StragglerDetector(n_workers=3)
    d2.record(0, 1.0)
    d2.record(1, 1.0)
    assert d2.stragglers() == []
    d2.record(2, 9.0)
    assert d2.stragglers() == [2]


def test_plan_remesh():
    assert plan_remesh(256, 16) == (16, 16)
    assert plan_remesh(240, 16) == (15, 16)  # one node lost
    assert plan_remesh(512, 16, pods=2) == (2, 16, 16)
    with pytest.raises(ValueError):
        plan_remesh(8, 16)


def test_data_determinism_and_packing():
    loader = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                    global_batch=2, seed=3))
    b1, b2 = loader.batch(5), loader.batch(5)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    docs = [np.arange(10), np.arange(37), np.arange(5)]
    toks, mask, seg = pack_documents(docs, 16)
    assert toks.shape[1] == 16 and (mask[0] == 1).all()
    assert toks.shape == mask.shape == seg.shape
