"""Majority-based bit-serial arithmetic (§8.1): exactness + op structure."""

import numpy as np
import pytest

from _proptest import rand_u32, sweep
from repro.core.errormodel import ErrorModel
from repro.pud.arith import BitSerial, run_elementwise
import jax.numpy as jnp


@pytest.mark.parametrize("tier", [3, 5, 7, 9])
@pytest.mark.parametrize("op", ["and", "or", "xor", "add", "sub"])
def test_logic_and_addsub_exact(tier, op):
    rng = np.random.default_rng((tier, hash(op) & 0xFF))
    a, b = rand_u32(rng, 48), rand_u32(rng, 48)
    ref = {"and": a & b, "or": a | b, "xor": a ^ b,
           "add": (a + b).astype(np.uint32),
           "sub": (a - b).astype(np.uint32)}[op]
    out, prog = run_elementwise(op, a, b, tier=tier,
                                n_act=32 if tier > 3 else 4)
    assert (np.asarray(out) == ref).all()
    assert len(prog.ops) > 0


@pytest.mark.parametrize("tier", [3, 5])
def test_mul_exact(tier):
    rng = np.random.default_rng(tier)
    a, b = rand_u32(rng, 24), rand_u32(rng, 24)
    out, _ = run_elementwise("mul", a, b, tier=tier)
    assert (np.asarray(out) == (a * b).astype(np.uint32)).all()


@pytest.mark.parametrize("tier", [3, 7])
def test_div_exact(tier):
    rng = np.random.default_rng(tier + 10)
    a = rand_u32(rng, 16)
    b = np.maximum(rand_u32(rng, 16) >> 20, 1).astype(np.uint32)
    out, _ = run_elementwise("div", a, b, tier=tier)
    assert (np.asarray(out) == (a // b)).all()


def test_tier5_shrinks_adder():
    rng = np.random.default_rng(0)
    a, b = rand_u32(rng, 8), rand_u32(rng, 8)
    _, p3 = run_elementwise("add", a, b, tier=3)
    _, p5 = run_elementwise("add", a, b, tier=5)
    # 7 MAJ + 2 NOT vs 2 MAJ + 1 NOT per bit
    assert len(p5.ops) < len(p3.ops) / 2.5


def test_tier7_uses_maj7_carry_skip():
    rng = np.random.default_rng(1)
    a, b = rand_u32(rng, 8), rand_u32(rng, 8)
    _, p7 = run_elementwise("add", a, b, tier=7)
    kinds = {(op.kind, op.x) for op in p7.ops}
    assert ("MAJ", 7) in kinds


def test_latency_model_orders_tiers():
    """MAJ5 construction beats MAJ3 baseline; MAJ9@H pays retry cost."""
    rng = np.random.default_rng(2)
    a, b = rand_u32(rng, 8), rand_u32(rng, 8)
    em = ErrorModel("H")
    t = {}
    for tier in (3, 5):
        _, prog = run_elementwise("add", a, b, tier=tier,
                                  n_act=32 if tier > 3 else 4)
        t[tier] = prog.latency_ns(em, pipelined=True, best_group=True)
    assert t[5] < t[3]


def test_carry_skip_identity():
    """c2 == MAJ7(a1,a1,b1,b1,a0,b0,c0) for every input combo."""
    ctx = BitSerial(tier=7, n_act=32)
    for bits in range(32):
        a1, b1, a0, b0, c0 = [(bits >> i) & 1 for i in range(5)]
        planes = [jnp.asarray([0xFFFFFFFF if v else 0], jnp.uint32)
                  for v in (a1, a1, b1, b1, a0, b0, c0)]
        got = int(np.asarray(ctx.maj(*planes))[0]) & 1
        c1 = (a0 + b0 + c0) >= 2
        c2 = (a1 + b1 + c1) >= 2
        assert got == int(c2), bits


def test_sum_via_maj5_identity():
    """s == MAJ5(a,b,c,~cout,~cout) for all 8 combos."""
    ctx = BitSerial(tier=5, n_act=32)
    for bits in range(8):
        a, b, c = [(bits >> i) & 1 for i in range(3)]
        cout = (a + b + c) >= 2
        planes = [jnp.asarray([0xFFFFFFFF if v else 0], jnp.uint32)
                  for v in (a, b, c, not cout, not cout)]
        got = int(np.asarray(ctx.maj(*planes))[0]) & 1
        assert got == ((a + b + c) & 1), bits


@sweep(5)
def test_program_costing_positive(rng):
    a, b = rand_u32(rng, 8), rand_u32(rng, 8)
    _, prog = run_elementwise("xor", a, b, tier=5, n_act=32)
    em = ErrorModel("H")
    assert prog.latency_ns(em) > 0
    assert prog.energy_nj(em) > 0
    assert prog.latency_ns(em, pipelined=True) < prog.latency_ns(em)
