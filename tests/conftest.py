import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests must see the real (single-CPU)
# topology; only launch/dryrun.py fakes 512 devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax

jax.config.update("jax_enable_x64", False)
