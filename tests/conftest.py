import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests must see the real (single-CPU)
# topology; only launch/dryrun.py fakes 512 devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax

jax.config.update("jax_enable_x64", False)

import numpy as np
import pytest

# --------------------------------------------------------------------------
# Shared serve-layer factories.  test_serve_service.py and
# test_serve_system.py each grew a private copy of these builders; they
# live here once so the construction defaults (smoke configs, tiny param
# trees, oracle backend) stay in lockstep across suites.


@pytest.fixture
def make_pud_service():
    """Factory: ``make_pud_service(backend="oracle", **cfg_kw)`` ->
    a fresh :class:`repro.serve.PudService` over a ServiceConfig."""
    from repro.serve import PudService, ServiceConfig

    def build(backend: str = "oracle", **cfg_kw) -> "PudService":
        return PudService(ServiceConfig(backend=backend, **cfg_kw))

    return build


@pytest.fixture
def make_tiny_pud_engine():
    """Factory: a 2-tensor-param Engine for PUD-integrity tests.

    Returns ``(engine, params)`` — the params dict is the ground truth
    the heal/verify assertions compare against.  Keyword args pass
    through to ``Engine`` (``pud_backend=``, ``pud_ctx=``,
    ``pud_service=``, ``strict_integrity=``, ``tenant=`` ...).
    """
    from repro.configs.registry import get_config
    from repro.serve.engine import Engine

    def build(**kw):
        params = {
            "w": np.linspace(-1, 1, 32, dtype=np.float32).reshape(4, 8),
            "b": np.arange(6, dtype=np.float32),
        }
        return Engine(params, get_config("xlstm-125m", smoke=True),
                      **kw), params

    return build


@pytest.fixture
def make_lm_engine():
    """Factory: a smoke-config LM Engine with freshly-initialised params.

    ``make_lm_engine("chatglm3-6b", max_seq=64)`` returns
    ``(engine, cfg)``; ``seed`` keys ``M.init``.  Keyword args pass
    through to ``Engine``.
    """
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.serve.engine import Engine

    def build(config_name: str = "chatglm3-6b", seed: int = 0,
              max_seq: int = 64, **kw):
        cfg = get_config(config_name, smoke=True)
        params, _ = M.init(jax.random.PRNGKey(seed), cfg)
        return Engine(params, cfg, max_seq=max_seq, **kw), cfg

    return build
