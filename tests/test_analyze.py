"""Static analyzer tests: races, liveness, equivalence, certification.

Positive direction: every golden fixture and every differential-suite
random program must certify across fused AND megakernel lowerings —
the analyzer may not reject artifacts the compiler legitimately emits
(aliasing, dead stores, input replication, mixed arities, cost-only
ops included).  Negative direction: every seeded table mutation
(:mod:`repro.analyze.mutate`) and every hand-built hazard (dependent
ops forced into one level, constant-row writes, use-after-free row
references) must be caught with its stable finding code.
"""

import glob
import json
import os

import numpy as np
import pytest

from repro.analyze import (Certificate, CertificationError, MUTATIONS,
                           allocator_findings, analyze, apply_mutation,
                           certify, check_ops, equivalence_findings,
                           lifetimes, liveness_findings, lowering_findings,
                           schedule_findings)
from repro.analyze.cert import schedule_digest
from repro.backends import ExecutionContext
from repro.compile import build_schedule, lower_schedule
from repro.compile.megakernel import ONE_ROW, TRASH_ROW, ZERO_ROW
from repro.compile.schedule import FusedGroup, Schedule
from repro.pud.isa import Program
from repro.session import DramSession
from repro.session.cache import CompileCache, program_key
from repro.session.rows import RowAllocator

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_FILES = sorted(glob.glob(os.path.join(GOLDEN_DIR, "*.json")))
GOLDEN_IDS = [os.path.basename(p)[:-5] for p in GOLDEN_FILES]


def _load_golden(path):
    with open(path) as f:
        doc = json.load(f)
    return doc, Program.from_json(json.dumps(doc["ops"]))


def _codes(findings):
    return {f.code for f in findings}


def _dedup_dsts(prog: Program) -> Program:
    """Differential programs draw dsts with replacement; a duplicate
    destination inside one op is a validation error (matching
    ``check_program``), so certification tests run the semantically
    identical dedup'd form."""
    out = Program()
    for op in prog.ops:
        out.emit(op.kind, x=op.x, n_act=op.n_act, tag=op.tag,
                 srcs=op.srcs, dsts=tuple(dict.fromkeys(op.dsts)))
    return out


# ------------------------------------------------------------ race pass


def test_check_ops_clean_program():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(3,), dsts=(4,))
    assert check_ops(prog, 5) == []


def test_check_ops_row_range_and_dup_dst():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 9), dsts=(2,))
    prog.emit("COPY", srcs=(0,), dsts=(1, 1))
    codes = _codes(check_ops(prog, 5))
    assert {"OP_ROW_RANGE", "OP_DUP_DST"} <= codes


def test_check_ops_maj_shape_errors():
    prog = Program()
    prog.emit("MAJ", x=4, n_act=8, srcs=(0, 1, 2, 3), dsts=(4,))
    prog.emit("MAJ", x=5, n_act=8, srcs=(0, 1, 2), dsts=(5,))
    prog.emit("MRC", n_act=8, srcs=(0, 1), dsts=(6,))
    codes = _codes(check_ops(prog, 8))
    assert {"OP_MAJ_ARITY", "OP_MAJ_OPERANDS", "OP_SRC_COUNT"} <= codes


def test_check_ops_underpowered_maj_is_warning_only():
    prog = Program()
    prog.emit("MAJ", x=5, n_act=2, srcs=(0, 1, 2, 3, 4), dsts=(5,))
    findings = check_ops(prog, 6)
    assert _codes(findings) == {"OP_NACT_UNDER_ARITY"}
    assert all(f.severity == "warning" for f in findings)


def test_check_ops_unknown_kind():
    prog = Program()
    prog.emit("XOR", srcs=(0,), dsts=(1,))
    assert _codes(check_ops(prog, 4)) == {"OP_UNKNOWN_KIND"}


def test_check_ops_duplicate_maj_operands_legal():
    # Input replication (paper identity): MAJ reading one row thrice.
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 0, 1), dsts=(2,))
    assert check_ops(prog, 3) == []


def _dependent_pair() -> Program:
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(3,), dsts=(4,))
    return prog


def test_schedule_findings_clean_on_compiler_output():
    prog = _dependent_pair()
    assert schedule_findings(build_schedule(prog), prog) == []


def test_schedule_findings_intra_level_raw():
    # Force both dependent ops into ONE level: the fused executor would
    # feed the NOT stale level-entry state.
    prog = _dependent_pair()
    maj, not_ = (op for op in prog.ops)
    bad = Schedule(levels=((FusedGroup("MAJ", 3, (maj,)),
                            FusedGroup("NOT", 0, (not_,))),))
    codes = _codes(schedule_findings(bad, prog))
    assert "RACE_RAW_LEVEL" in codes


def test_schedule_findings_intra_level_waw():
    prog = Program()
    prog.emit("COPY", srcs=(0,), dsts=(2,))
    prog.emit("COPY", srcs=(1,), dsts=(2,))
    a, b = prog.ops
    bad = Schedule(levels=((FusedGroup("COPY", 0, (a, b)),),))
    assert "RACE_WAW_LEVEL" in _codes(schedule_findings(bad, prog))


def test_schedule_findings_identical_redundant_writes_benign():
    # Two content-equal writers of one row commit the same value:
    # legal under unspecified level-exit commit order.
    prog = Program()
    prog.emit("COPY", srcs=(0,), dsts=(2,))
    prog.emit("COPY", srcs=(0,), dsts=(2,))
    a, b = prog.ops
    sched = Schedule(levels=((FusedGroup("COPY", 0, (a, b)),),))
    assert schedule_findings(sched, prog) == []


def test_schedule_findings_dropped_op():
    prog = _dependent_pair()
    maj = prog.ops[0]
    bad = Schedule(levels=((FusedGroup("MAJ", 3, (maj,)),),))
    assert "SCHED_OP_SET" in _codes(schedule_findings(bad, prog))


def test_lowering_findings_clean_on_compiler_output():
    for path in GOLDEN_FILES:
        _, prog = _load_golden(path)
        low = lower_schedule(build_schedule(prog))
        assert lowering_findings(low) == [], path


def test_lowering_findings_const_write_and_trash_read():
    _, prog = _load_golden(GOLDEN_FILES[0])
    low = lower_schedule(build_schedule(prog))
    bad = apply_mutation(low, "const_write")
    assert "RACE_CONST_WRITE" in _codes(lowering_findings(bad))
    trash = low.src.copy()
    # Point a live slot's first operand at the trash row.
    trash[0, 0, 0] = TRASH_ROW
    import dataclasses
    bad2 = dataclasses.replace(low, src=trash)
    assert "RACE_TRASH_READ" in _codes(lowering_findings(bad2))


# -------------------------------------------------------- liveness pass


def test_lifetimes_intervals():
    prog = Program()
    prog.emit("COPY", srcs=(0,), dsts=(1,))      # op 0
    prog.emit("NOT", srcs=(1,), dsts=(2,))       # op 1
    prog.emit("FRAC", dsts=(2,))                 # value-neutral: ignored
    lt = lifetimes(prog)
    assert lt[0].read_before_write and lt[0].first_read == 0
    assert lt[1].first_write == 0 and lt[1].last_read == 1
    assert lt[2].first_write == 1 and lt[2].last_write == 1


def test_dead_op_warning_and_outputs():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(0,), dsts=(4,))
    # Without explicit outputs every last write counts as live.
    assert liveness_findings(prog) == []
    # With outputs={3}, the NOT's write to row 4 is dead (warning).
    findings = liveness_findings(prog, outputs=(3,))
    assert _codes(findings) == {"LIVE_DEAD_OP"}
    assert all(f.severity == "warning" for f in findings)


def test_undeclared_input_error():
    prog = Program()
    prog.emit("NOT", srcs=(7,), dsts=(0,))
    assert liveness_findings(prog) == []  # inputs inferred silently
    findings = liveness_findings(prog, inputs=(1, 2))
    assert _codes(findings) == {"LIVE_UNDECLARED_INPUT"}


def test_allocator_use_after_free_and_leak():
    alloc = RowAllocator(capacity=8, name="arena")
    keep = alloc.alloc(2, tag="keep")
    stale = alloc.alloc(2, tag="stale")
    alloc.free(stale)
    assert set(alloc.free_rows) == set(stale.indices)

    prog = Program()
    prog.emit("COPY", srcs=(keep.indices[0],),
              dsts=(stale.indices[0],))        # write to a freed row
    codes = _codes(allocator_findings(prog, alloc))
    assert "LIVE_USE_AFTER_FREE" in codes
    # keep[1] is reserved but never referenced -> leak warning.
    assert "LIVE_LEAKED_ROWS" in codes

    prog2 = Program()
    prog2.emit("COPY", srcs=(0,), dsts=(99,))
    assert "LIVE_UNALLOCATED" in _codes(allocator_findings(prog2, alloc))


# ----------------------------------------------------- equivalence pass


def test_equivalence_clean_across_artifacts():
    for path in GOLDEN_FILES:
        _, prog = _load_golden(path)
        sched = build_schedule(prog)
        low = lower_schedule(sched)
        assert equivalence_findings(prog, sched, low) == [], path


def test_equivalence_catches_forced_same_level_dependency():
    # The race pass sees the RAW; equivalence independently proves the
    # stale-entry read computes a different dataflow.
    prog = _dependent_pair()
    maj, not_ = prog.ops
    bad = Schedule(levels=((FusedGroup("MAJ", 3, (maj,)),
                            FusedGroup("NOT", 0, (not_,))),))
    assert any(f.code == "EQ_SCHEDULE_ROW"
               for f in equivalence_findings(prog, bad))


def test_equivalence_padding_and_expansion_identities():
    # Mixed arities (forces constant padding), MRC expansion, NOT slots
    # in one program: the lowering certifies only because the symbolic
    # domain proves MAJ_k == MAJ_{k+2m}(.., 0*m, 1*m) and MAJ_1(v) == v.
    prog = Program()
    prog.emit("MAJ", x=7, n_act=8,
              srcs=(0, 1, 2, 3, 4, 5, 6), dsts=(7,))
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(8,))
    prog.emit("NOT", srcs=(7,), dsts=(9,))
    prog.emit("MRC", n_act=32, srcs=(8,), dsts=(10, 11, 12))
    sched = build_schedule(prog)
    low = lower_schedule(sched)
    assert low.x_max == 7  # the MAJ3 really is padded
    assert equivalence_findings(prog, sched, low) == []


# -------------------------------------------------- certification driver


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=GOLDEN_IDS)
def test_golden_certifies_and_matches_frozen_certificate(path):
    doc, prog = _load_golden(path)
    sched = build_schedule(prog)
    low = lower_schedule(sched)
    cert = certify(prog, sched=sched, lowering=low)
    frozen = doc["certificate"]
    assert cert.digest == frozen["digest"]
    assert cert.program_key == frozen["program_key"]
    assert cert.lowering_digest == frozen["lowering_digest"] \
        == low.digest()
    assert cert.schedule_digest == schedule_digest(sched)
    assert {name: {"errors": e, "warnings": w}
            for name, e, w in cert.summary} == frozen["passes"]


def test_certificate_deterministic():
    _, prog = _load_golden(GOLDEN_FILES[0])
    sched = build_schedule(prog)
    low = lower_schedule(sched)
    a = certify(prog, sched=sched, lowering=low)
    b = certify(prog, sched=sched, lowering=low)
    assert a == b and a.digest == b.digest
    assert isinstance(a, Certificate) and a.covers_lowering


@pytest.mark.parametrize("mutation", sorted(MUTATIONS))
def test_seeded_mutations_rejected(mutation):
    applied = 0
    for path in GOLDEN_FILES:
        _, prog = _load_golden(path)
        sched = build_schedule(prog)
        bad = apply_mutation(lower_schedule(sched), mutation)
        if bad is None:
            continue  # no site on this fixture (e.g. no NOT slots)
        applied += 1
        with pytest.raises(CertificationError) as err:
            certify(prog, sched=sched, lowering=bad)
        assert err.value.report.errors, (path, mutation)
    assert applied >= 1, f"mutation {mutation} never applicable"


def test_analyze_report_never_raises():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 99), dsts=(1,))
    report = analyze(prog, n_rows=4)
    assert not report.ok
    assert "OP_ROW_RANGE" in _codes(report.errors)
    # Summary is canonical: all three passes present even when clean.
    assert [s[0] for s in report.summary()] == \
        ["race", "liveness", "equivalence"]


# ----------------------------------- differential-suite certification


def test_differential_programs_certify():
    from test_compile_differential import rand_program

    rng = np.random.default_rng(0xA11A)
    for trial in range(25):
        prog = _dedup_dsts(rand_program(rng, n_ops=12))
        sched = build_schedule(prog)
        low = lower_schedule(sched)
        cert = certify(prog, sched=sched, lowering=low)
        assert cert.covers_lowering, trial


def test_traced_adder_certifies_with_dead_gate():
    from repro.compile import trace_planes
    from repro.core import bitplanes as bp

    rng = np.random.default_rng(3)
    A = bp.pack(rng.integers(0, 2, (4, 64)).astype(bool))
    B = bp.pack(rng.integers(0, 2, (4, 64)).astype(bool))

    def f(bs):
        s, carry = bs.add(A, B)
        bs.not_(carry)          # dead gate: complement nothing reads
        return list(s)

    prog = trace_planes(f, tier=5, n_act=32).program
    sched = build_schedule(prog)
    cert = certify(prog, sched=sched, lowering=lower_schedule(sched))
    assert cert.summary[0] == ("race", 0, 0)


# ------------------------------------------------ cache + session wiring


def test_certificate_cache_hit_and_upgrade():
    _, prog = _load_golden(GOLDEN_FILES[0])
    cache = CompileCache()
    sched = cache.schedule_for(prog)

    fused_only = cache.certificate_for(prog, sched=sched)
    assert fused_only.lowering_digest is None
    assert (cache.certificate_stats.misses,
            cache.certificate_stats.hits) == (1, 0)

    again = cache.certificate_for(prog, sched=sched)
    assert again is fused_only
    assert cache.certificate_stats.hits == 1  # zero re-analysis

    low = cache.lowering_for(prog, sched=sched)
    upgraded = cache.certificate_for(prog, sched=sched, lowering=low)
    assert upgraded.covers_lowering          # one extra miss: upgrade
    assert cache.certificate_stats.misses == 2

    final = cache.certificate_for(prog, sched=sched, lowering=low)
    assert final is upgraded
    assert cache.certificate_stats.hits == 2


def test_certificate_cache_rejects_uncertifiable():
    _, prog = _load_golden(GOLDEN_FILES[0])
    cache = CompileCache()
    sched = cache.schedule_for(prog)
    bad = apply_mutation(cache.lowering_for(prog, sched=sched),
                         "truncate_slot")
    with pytest.raises(CertificationError):
        cache.certificate_for(prog, sched=sched, lowering=bad)
    # Nothing admitted: a later good lookup is a fresh miss, not a hit.
    cache.certificate_for(prog, sched=sched)
    assert cache.certificate_stats.hits == 0


def test_session_certifies_run_fused():
    session = DramSession("oracle", ExecutionContext(ideal=True))
    prog = _dependent_pair()
    state = np.zeros((5, 4), np.uint32)
    session.run_fused(prog, state)
    assert session.cache.certificate_stats.lookups == 1
    session.run_fused(prog, state)
    assert session.cache.certificate_stats.hits == 1


def test_session_certify_opt_out():
    session = DramSession("oracle",
                          ExecutionContext(ideal=True, certify=False))
    prog = _dependent_pair()
    session.run_fused(prog, np.zeros((5, 4), np.uint32))
    assert session.cache.certificate_stats.lookups == 0


def test_validate_carries_findings():
    from repro.session.validate import (ProgramValidationError,
                                        check_program)

    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 7), dsts=(1, 1))
    with pytest.raises(ProgramValidationError) as err:
        check_program(prog, 4)
    codes = {f.code for f in err.value.findings}
    assert {"OP_ROW_RANGE", "OP_DUP_DST"} <= codes


def test_program_key_matches_cert_key():
    _, prog = _load_golden(GOLDEN_FILES[0])
    cert = certify(prog)
    assert cert.program_key == program_key(prog)
