"""Fault-tolerant sweep driver: elastic membership, straggler re-dispatch.

The contract under test: worker loss and re-dispatch change *who*
executes a chunk, never *what* it produces — records stay a pure
function of (spec, chunk), so every failure scenario below must end
with zero missing chunks and records identical to a plain
single-driver run.
"""

import threading
import time

import pytest

from repro.ft.elastic import ElasticMembership
from repro.ft.failures import WorkerLost
from repro.sweep import SweepSpec, plan, run_sweep, run_sweep_ft

# 4 one-point chunks: with 2 workers, whichever holds a chunk leaves the
# other a non-empty round-robin share, so barrier-synchronized failure
# injection in the hooks below cannot starve.
SPEC = dict(op="majx", backends=("sim",), x_values=(3, 5), n_act=(32,),
            seeds=(0, 1), rows=2, words=16, chunk=1)


def _spec(name):
    return SweepSpec(name=name, **SPEC)


def _sorted(records):
    return sorted(records, key=lambda r: r["index"])


# ------------------------------------------------------ elastic membership


def test_elastic_membership_replans_on_drop():
    m = ElasticMembership(3)
    items = list(range(7))
    p0 = m.plan(items)
    assert sorted(sum(p0.values(), [])) == items
    assert set(p0) == {0, 1, 2}

    gen = m.generation
    m.drop(1)
    assert m.generation > gen
    assert m.live == (0, 2)
    assert m.dropped == [1]
    p1 = m.plan(items)
    assert set(p1) == {0, 2}
    assert sorted(sum(p1.values(), [])) == items
    assert m.share(items, 1) == []  # dead workers own nothing

    m.drop(1)  # idempotent
    assert m.dropped == [1]

    m.join(1)
    assert m.live == (0, 1, 2)


def test_elastic_membership_validation():
    with pytest.raises(ValueError):
        ElasticMembership(0)


# ------------------------------------------------------------ happy path


def test_ft_run_matches_plain_run(tmp_path):
    spec = _spec("ft-plain")
    baseline = run_sweep(spec, str(tmp_path / "base"))
    ft = run_sweep_ft(spec, str(tmp_path / "ft"), n_workers=2)
    assert ft.lost_workers == [] and ft.re_dispatched == 0
    assert _sorted(ft.records) == _sorted(baseline.records)
    assert sum(ft.worker_chunks.values()) == ft.executed_chunks
    assert len(ft.records) == spec.n_points()

    # resume: everything cached, no worker executes anything
    again = run_sweep_ft(spec, str(tmp_path / "ft"), n_workers=2)
    assert again.executed_chunks == 0
    assert again.cached_chunks == ft.executed_chunks
    assert _sorted(again.records) == _sorted(baseline.records)


# ------------------------------------------------------------ worker loss


def test_dead_worker_chunks_are_reassigned(tmp_path):
    """Worker 1 dies after both workers hold a chunk: the run must still
    finish with zero missing chunks and untouched record content."""
    spec = _spec("ft-dead")
    baseline = run_sweep(spec, str(tmp_path / "base"))

    barrier = threading.Barrier(2, timeout=10)
    lock = threading.Lock()
    seen = set()

    def hook(wid, chunk):
        with lock:
            first = wid not in seen
            seen.add(wid)
        if first:
            barrier.wait()  # both workers are mid-claim before the death
        if wid == 1:
            raise WorkerLost("injected")

    ft = run_sweep_ft(spec, str(tmp_path / "ft"), n_workers=2,
                      worker_hook=hook)
    assert ft.lost_workers == [1]
    # the survivor picked up everything, including the dead worker's share
    assert ft.worker_chunks.get(1, 0) == 0
    assert ft.worker_chunks[0] == ft.executed_chunks == len(plan(spec))
    assert len(ft.records) == spec.n_points()
    assert _sorted(ft.records) == _sorted(baseline.records)


def test_all_workers_lost_raises(tmp_path):
    spec = _spec("ft-all-lost")

    def hook(wid, chunk):
        raise WorkerLost("injected")

    with pytest.raises(RuntimeError, match="workers lost"):
        run_sweep_ft(spec, str(tmp_path), n_workers=2, worker_hook=hook)


def test_worker_exception_propagates(tmp_path):
    spec = _spec("ft-crash")

    def hook(wid, chunk):
        raise RuntimeError("kaboom")

    with pytest.raises(RuntimeError, match="worker failed") as err:
        run_sweep_ft(spec, str(tmp_path), n_workers=2, worker_hook=hook)
    assert "kaboom" in str(err.value.__cause__)


# ------------------------------------------------------- straggler steal


def test_straggler_chunk_is_redispatched(tmp_path):
    """Worker 1 stalls on its first chunk; past the timeout the monitor
    re-dispatches that chunk to the healthy worker and the run finishes
    promptly with complete, untorn records."""
    spec = _spec("ft-straggle")
    baseline = run_sweep(spec, str(tmp_path / "base"))
    n_chunks = len(plan(spec))
    assert n_chunks >= 2

    stalled = threading.Event()

    def hook(wid, chunk):
        if wid == 1 and not stalled.is_set():
            stalled.set()
            time.sleep(8.0)  # far past straggler_timeout_s

    t0 = time.monotonic()
    ft = run_sweep_ft(spec, str(tmp_path / "ft"), n_workers=2,
                      worker_hook=hook, straggler_timeout_s=0.15,
                      poll_s=0.02)
    wall = time.monotonic() - t0
    assert ft.re_dispatched >= 1
    assert wall < 8.0  # finished without waiting out the stall
    assert len(ft.records) == spec.n_points()
    assert _sorted(ft.records) == _sorted(baseline.records)
