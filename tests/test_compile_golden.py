"""Golden-program regression tests.

tests/golden/*.json freeze canonical serialized Programs (ripple-carry
adders, MAJ5/7/9 reduction trees, fan-out-31 Multi-RowCopy waves) with
their expected output bitplanes under fixed seeds — regenerate with
``tests/golden/generate.py`` only on intentional semantic changes.  A
scheduler change that reorders ops but alters results fails here loudly,
on every backend and on all three execution paths (per-op, fused,
megakernel); each fixture additionally pins the megakernel lowering's
level-table structure and content digest, so a silent repacking of the
tables fails even when the final state happens to agree.
"""

import glob
import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import ExecutionContext, get_backend
from repro.compile import build_schedule, lower_schedule
from repro.pud.isa import Program

IDEAL = ExecutionContext(ideal=True)
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_FILES = sorted(glob.glob(os.path.join(GOLDEN_DIR, "*.json")))


def _load(path):
    with open(path) as f:
        doc = json.load(f)
    prog = Program()
    for raw in doc["ops"]:
        prog.emit(raw["kind"], x=raw["x"], n_act=raw["n_act"],
                  tag=raw["tag"], srcs=tuple(raw["srcs"]),
                  dsts=tuple(raw["dsts"]))
    rng = np.random.default_rng((doc["seed"], 0x601D))
    state = rng.integers(0, 2 ** 32, (doc["rows"], doc["words"]),
                         dtype=np.uint32)
    expected = np.array(
        [[int(row[i:i + 8], 16) for i in range(0, len(row), 8)]
         for row in doc["expected"]], dtype=np.uint32)
    return doc, prog, state, expected


def test_fixture_set_is_complete():
    names = {os.path.basename(p)[:-5] for p in GOLDEN_FILES}
    assert {"add8", "add16", "add32", "maj5_tree", "maj7_tree",
            "maj9_tree", "mrc_fanout31"} <= names


@pytest.mark.parametrize(
    "path", GOLDEN_FILES, ids=[os.path.basename(p)[:-5]
                               for p in GOLDEN_FILES])
def test_golden_program_all_backends_both_paths(path):
    doc, prog, state, expected = _load(path)
    assert prog.n_rows() == doc["rows"]
    state = jnp.asarray(state)
    for name in ("oracle", "sim", "pallas"):
        be = get_backend(name, IDEAL)
        for mode, run in (("per_op", be.run), ("fused", be.run_fused)):
            got = np.asarray(run(prog, state))
            assert (got == expected).all(), (doc["name"], name, mode)
        got = np.asarray(be.run_fused(prog, state, mode="megakernel"))
        assert (got == expected).all(), (doc["name"], name, "megakernel")


@pytest.mark.parametrize(
    "path", GOLDEN_FILES, ids=[os.path.basename(p)[:-5]
                               for p in GOLDEN_FILES])
def test_golden_fused_dispatch_budget(path):
    """Fused execution of every golden stays within its level budget and
    never exceeds the per-op launch count."""
    _, prog, state, _ = _load(path)
    sched = build_schedule(prog)
    pal = get_backend("pallas", IDEAL)
    pal.reset_dispatches()
    pal.run_fused(prog, jnp.asarray(state))
    assert pal.dispatch_count == sched.n_dispatches()
    assert pal.dispatch_count <= sched.n_levels or sched.n_levels == 0
    assert sched.n_dispatches() <= sched.per_op_dispatches()


@pytest.mark.parametrize(
    "path", GOLDEN_FILES, ids=[os.path.basename(p)[:-5]
                               for p in GOLDEN_FILES])
def test_golden_megakernel_lowering_structure(path):
    """The frozen level-table structure: shapes, per-level slot counts,
    and the byte-level table digest must reproduce exactly."""
    doc, prog, _, expected = _load(path)
    frozen = doc["megakernel"]
    low = lower_schedule(build_schedule(prog))
    assert low.n_levels == frozen["n_levels"]
    assert low.w_max == frozen["w_max"]
    assert low.x_max == frozen["x_max"]
    assert [list(c) for c in low.level_meta] == frozen["level_meta"]
    assert low.digest() == frozen["table_digest"]
    assert hashlib.sha256(
        np.ascontiguousarray(expected).tobytes()).hexdigest() \
        == frozen["final_digest"]


@pytest.mark.parametrize(
    "path", GOLDEN_FILES, ids=[os.path.basename(p)[:-5]
                               for p in GOLDEN_FILES])
def test_golden_megakernel_is_one_dispatch(path):
    _, prog, state, expected = _load(path)
    pal = get_backend("pallas", IDEAL)
    with pal.count_dispatches() as scope:
        got = np.asarray(pal.run_fused(prog, jnp.asarray(state),
                                       mode="megakernel"))
    assert scope.count == 1
    assert (got == expected).all()


def test_serialization_roundtrip():
    _, prog, _, _ = _load(GOLDEN_FILES[0])
    again = Program.from_json(prog.to_json())
    assert again.ops == prog.ops
    assert again.histogram() == prog.histogram()


@pytest.mark.parametrize(
    "path", GOLDEN_FILES, ids=[os.path.basename(p)[:-5]
                               for p in GOLDEN_FILES])
def test_golden_certificate_is_frozen_and_deterministic(path):
    """The frozen certificate section must reproduce bit-for-bit.

    Recomputes the full static analysis (races, liveness, symbolic
    equivalence over schedule AND lowering) and compares against the
    fixture's pinned digest: an analyzer change that silently alters
    what is checked — or a compiler change that alters the artifacts —
    moves this digest and must go through fixture regeneration.
    """
    from repro.analyze import certify

    doc, prog, _, _ = _load(path)
    sched = build_schedule(prog)
    lowering = lower_schedule(sched)
    cert = certify(prog, sched=sched, lowering=lowering)
    frozen = doc["certificate"]
    assert cert.to_dict() == frozen
    # Determinism: a second independent run lands on the same digest.
    assert certify(prog, sched=sched, lowering=lowering).digest \
        == frozen["digest"]
