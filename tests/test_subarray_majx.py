"""Behavioural subarray simulator: MAJX / Multi-RowCopy / SiMRA semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import rand_u32, sweep
from repro.core import calibration as cal
from repro.core import commands as cmd
from repro.core import majx as mj
from repro.core import rowcopy as rc
from repro.core.subarray import DeviceProfile, Subarray


def _ops(rng, x, words):
    return [jnp.asarray(rand_u32(rng, words)) for _ in range(x)]


@sweep(8)
def test_ideal_majx_matches_boolean_majority(rng):
    x = int(rng.choice([3, 5, 7, 9]))
    n_act = int(rng.choice([n for n in (4, 8, 16, 32) if n >= x]))
    sa = Subarray(cols=512, ideal=True)
    ops = _ops(rng, x, sa.n_words)
    got = mj.majx(sa, ops, n_act)
    want = mj.majx_reference(jnp.stack(ops))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_measured_success_tracks_calibration():
    rng = np.random.default_rng(0)
    for x, n_act in [(3, 4), (3, 32), (5, 32), (7, 32)]:
        accs = []
        for seed in range(3):
            sa = Subarray(cols=4096, seed=seed)
            accs.append(mj.majx_success_measured(
                sa, _ops(rng, x, sa.n_words), n_act))
        want = sa.errors.majx_success(x, n_act)
        assert np.mean(accs) == pytest.approx(want, abs=0.02), (x, n_act)


def test_and_or_via_maj3():
    rng = np.random.default_rng(1)
    sa = Subarray(cols=256, ideal=True)
    a, b = _ops(rng, 2, sa.n_words)
    assert (np.asarray(mj.and_via_maj3(sa, a, b)) == np.asarray(a & b)).all()
    assert (np.asarray(mj.or_via_maj3(sa, a, b)) == np.asarray(a | b)).all()


def test_multi_rowcopy_ideal():
    rng = np.random.default_rng(2)
    sa = Subarray(cols=256, ideal=True)
    src = jnp.asarray(rand_u32(rng, sa.n_words))
    src_row, dests = rc.multi_rowcopy(sa, src, 32)
    assert len(dests) == 31
    for d in dests:
        assert (np.asarray(sa.read_row(d)) == np.asarray(src)).all()


def test_multi_rowcopy_success_rate():
    """All-0 src into all-1 rows: every failed cell is visible."""
    sa = Subarray(cols=8192, seed=3)
    sa.fill("0xFF")
    src = jnp.zeros((sa.n_words,), jnp.uint32)
    acc = rc.mrc_success_measured(sa, src, 32)
    assert acc == pytest.approx(cal.MRC_SUCCESS_BEST[31], abs=5e-4)


def test_simra_wr_overdrive():
    """§3.2 methodology: APA + WR updates all simultaneously open rows."""
    sa = Subarray(cols=256, ideal=True)
    sa.fill("0x00")
    pattern = np.full((sa.n_words,), 0xDEADBEEF, np.uint32)
    rf, rs = sa.decoder.pair_for_n_rows(8, 0)
    sa.run(cmd.apa_with_wr(rf, rs, 3.0, 3.0, pattern))
    group = sa.decoder.apa_activated_rows(rf, rs)
    assert len(group) == 8
    for r in group:
        assert (np.asarray(sa.read_row(r)) == pattern).all()
    # rows outside the group untouched (Limitation 3 check)
    outside = [r for r in range(sa.rows) if r not in group][:16]
    for r in outside:
        assert (np.asarray(sa.read_row(r)) == 0).all()


def test_rowclone_fn6():
    sa = Subarray(cols=256, ideal=True)
    rng = np.random.default_rng(4)
    src = jnp.asarray(rand_u32(rng, sa.n_words))
    sa.write_row(5, src)
    rc.rowclone(sa, 5, 9)
    assert (np.asarray(sa.read_row(9)) == np.asarray(src)).all()


def test_frac_rows_are_neutral_in_majority():
    """MAJ3 with 4-row activation: the 4th (Frac) row must not vote."""
    sa = Subarray(cols=256, ideal=True)
    ones = jnp.full((sa.n_words,), 0xFFFFFFFF, jnp.uint32)
    zeros = jnp.zeros((sa.n_words,), jnp.uint32)
    got = mj.majx(sa, [ones, zeros, ones], 4)
    assert (np.asarray(got) == 0xFFFFFFFF).all()


def test_samsung_profile_no_simra():
    sa = Subarray(DeviceProfile.mfr_s(), cols=256, ideal=True)
    sa.fill("0x00")
    rng = np.random.default_rng(5)
    src = jnp.asarray(rand_u32(rng, sa.n_words))
    sa.write_row(0, src)
    rf, rs = sa.decoder.pair_for_n_rows(4, 0)
    sa.run(cmd.apa(rf, rs, 3.0, 3.0))
    # chip ignored the violated timing: only rs activated, nothing written
    group = sa.decoder.apa_activated_rows(rf, rs)
    for r in group:
        if r not in (0,):
            assert (np.asarray(sa.read_row(r)) == 0).all()


def test_mfr_m_majx_via_bias():
    """Mfr M has no Frac but neutral rows via sense-amp bias (§3.3 fn 5)."""
    sa = Subarray(DeviceProfile.mfr_m(), cols=256, ideal=True)
    rng = np.random.default_rng(6)
    ops = _ops(rng, 3, sa.n_words)
    got = mj.majx(sa, ops, 4)
    want = mj.majx_reference(jnp.stack(ops))
    assert (np.asarray(got) == np.asarray(want)).all()
