"""The calibrated success-rate surfaces reproduce the paper's observations."""

import pytest

from repro.core import calibration as cal
from repro.core.errormodel import ErrorModel

em = ErrorModel("H")
em_m = ErrorModel("M")
em_s = ErrorModel("S")


def test_obs1_simra_anchors():
    for n, s in cal.SIMRA_SUCCESS_BEST.items():
        assert em.simra_success(n) == pytest.approx(s, abs=1e-6)


def test_obs2_timing_cliff():
    best = em.simra_success(8, t1=1.5, t2=3.0)
    worst = em.simra_success(8, t1=1.5, t2=1.5)
    assert worst / best == pytest.approx(1 - 0.2174, rel=1e-2)


def test_obs3_temperature_small_effect():
    drop = 1 - em.simra_success(32, temp_c=90.0) / em.simra_success(32)
    assert drop == pytest.approx(0.0007, abs=2e-4)


def test_obs4_vpp_small_effect():
    drop = 1 - em.simra_success(32, vpp_v=2.1) / em.simra_success(32)
    assert drop <= 0.0041 + 1e-6


def test_obs7_maj3_timing_optimum():
    best = em.majx_success(3, 32, t1=1.5, t2=3.0)
    second = em.majx_success(3, 32, t1=3.0, t2=3.0)
    assert best == pytest.approx(0.9900, abs=1e-6)
    assert best / second == pytest.approx(1.455, rel=1e-3)


def test_obs8_majx_anchors():
    for x, s in cal.MAJX_SUCCESS_32ROW.items():
        assert em.majx_success(x, 32) == pytest.approx(s, abs=1e-6)


def test_obs6_replication_gain():
    gain = em.majx_success(3, 32) / em.majx_success(3, 4)
    assert gain == pytest.approx(1.3081, rel=1e-3)


def test_obs10_replication_gains():
    for x, g in cal.MAJX_REPLICATION_GAIN_REL.items():
        n_min = cal.min_activation_for(x)
        gain = em.majx_success(x, 32) / em.majx_success(x, n_min)
        assert gain == pytest.approx(1 + g, rel=1e-3)


def test_replication_monotone():
    for x in (3, 5, 7, 9):
        levels = [n for n in cal.N_ACT_LEVELS if n >= cal.min_activation_for(x)]
        vals = [em.majx_success(x, n) for n in levels]
        assert vals == sorted(vals)


def test_obs9_pattern_effect():
    for x in (3, 5, 7, 9):
        rnd = em.majx_success(x, 32, pattern="random")
        fixed = em.majx_success(x, 32, pattern="0x00/0xFF")
        assert fixed > rnd
        assert 1 - rnd / fixed == pytest.approx(
            cal.MAJX_RANDOM_BELOW_FIXED_REL[x], rel=5e-2)


def test_obs11_obs12_temperature():
    # temperature helps MAJX; replication damps the sensitivity
    v4 = em.majx_success(3, 4, temp_c=90.0) / em.majx_success(3, 4) - 1
    v32 = em.majx_success(3, 32, temp_c=90.0) / em.majx_success(3, 32) - 1
    assert v4 == pytest.approx(0.1520, rel=5e-2)
    assert v32 <= 0.0165 + 1e-3
    assert v4 > v32 > 0


def test_obs13_vpp_effect_small():
    v = 1 - em.majx_success(5, 32, vpp_v=2.1) / em.majx_success(5, 32)
    assert v == pytest.approx(0.011, rel=1e-2)


def test_obs14_mrc_anchors():
    for n, s in cal.MRC_SUCCESS_BEST.items():
        assert em.mrc_success(n) == pytest.approx(s, abs=1e-6)


def test_obs15_mrc_low_t1():
    worst = em.mrc_success(31, t1=1.5)
    second_worst = em.mrc_success(31, t1=3.0)
    assert 1 - worst / second_worst == pytest.approx(0.4979, rel=1e-3)


def test_obs16_mrc_all1_pattern():
    base = em.mrc_success(31)
    all1 = em.mrc_success(31, pattern="0xFF")
    assert 1 - all1 / base == pytest.approx(0.0079, rel=1e-2)
    small = 1 - em.mrc_success(15, pattern="0xFF") / em.mrc_success(15)
    assert small <= 0.0011 + 1e-6


def test_obs17_obs18_mrc_env():
    t = 1 - em.mrc_success(31, temp_c=90.0) / em.mrc_success(31)
    v = 1 - em.mrc_success(31, vpp_v=2.1) / em.mrc_success(31)
    assert abs(t) == pytest.approx(0.0004, abs=2e-4)
    assert v <= 0.0132 + 1e-6


def test_abstract_env_bounds_all_ops():
    """Abstract: <=2.13 % (temp) / <=1.32 % (VPP) across all tested ops."""
    ops = []
    for n in cal.N_ACT_LEVELS:
        ops.append(lambda t=50.0, v=2.5, n=n: em.simra_success(n, temp_c=t, vpp_v=v))
    for x in (3, 5, 7, 9):
        ops.append(lambda t=50.0, v=2.5, x=x: em.majx_success(x, 32, temp_c=t, vpp_v=v))
    for n in cal.MRC_SUCCESS_BEST:
        ops.append(lambda t=50.0, v=2.5, n=n: em.mrc_success(n, temp_c=t, vpp_v=v))
    for op in ops:
        base = op()
        assert abs(op(t=90.0) / base - 1) <= 0.16  # MAJ3@4 is the outlier
        assert abs(op(v=2.1) / base - 1) <= cal.ALL_OPS_VPP_VARIATION_MAX_REL + 1e-6


def test_samsung_no_pud():
    """§9 Limitation 1: Samsung shows no SiMRA, no MAJX, no Multi-RowCopy."""
    assert em_s.simra_success(32) == 0.0
    assert em_s.majx_success(3, 4) == 0.0
    assert em_s.mrc_success(31) == 0.0
    # …but plain RowClone still works
    assert em_s.mrc_success(1, t1=36.0, t2=6.0) > 0.999


def test_mfr_m_caps_at_maj7():
    """fn 11: MAJ9+ on Mfr M has <1 % success."""
    assert em_m.majx_success(9, 32) < 0.01
    assert em_m.majx_success(7, 32) == pytest.approx(0.3387, abs=1e-4)


def test_fn6_consecutive_activation_degenerates():
    """t2 >= 6 ns degenerates to a RowClone (only one destination)."""
    assert em.mrc_success(31, t2=6.0) < 0.1
    assert em.simra_success(32, t1=3.0, t2=6.0) == 0.0
