"""Serving engine + end-to-end system behaviour through the public API.

Engine construction (smoke config + fresh ``M.init`` params) comes from
the shared ``make_lm_engine`` factory in conftest.py.
"""

import numpy as np
import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.serve.engine import Engine, Request
from repro.train.trainer import Trainer, TrainerConfig


def test_engine_generates_deterministically(make_lm_engine):
    eng, cfg = make_lm_engine("chatglm3-6b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
               for _ in range(3)]
    reqs1 = [Request(rid=i, prompt=p, max_new_tokens=6)
             for i, p in enumerate(prompts)]
    reqs2 = [Request(rid=i, prompt=p.copy(), max_new_tokens=6)
             for i, p in enumerate(prompts)]
    out1 = eng.generate(reqs1)
    out2 = eng.generate(reqs2)
    for a, b in zip(out1, out2):
        assert [int(t) for t in a.out_tokens] == [int(t) for t in b.out_tokens]
        assert len(a.out_tokens) == 6


def test_engine_continuous_batching_mixed_lengths(make_lm_engine):
    eng, cfg = make_lm_engine("chatglm3-6b")
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (8 if i % 2 else 12,), dtype=np.int32),
                    max_new_tokens=4)
            for i in range(4)]
    done = eng.generate(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out_tokens) == 4 for r in done)


def test_engine_greedy_matches_forward(make_lm_engine):
    """Engine's first sampled token == argmax of the teacher-forced logits."""
    eng, cfg = make_lm_engine("gemma-7b", max_seq=32)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
    import jax.numpy as jnp

    logits, _ = M.forward(eng.params,
                          {"tokens": jnp.asarray(prompt[None])}, cfg)
    want = int(jnp.argmax(logits[0, -1]))
    out = eng.generate([Request(rid=0, prompt=prompt, max_new_tokens=1)])
    assert int(out[0].out_tokens[0]) == want


def test_end_to_end_train_then_serve(tmp_path):
    """Train a tiny model, checkpoint, restore, and serve with it."""
    cfg = get_config("xlstm-125m", smoke=True)
    tc = TrainConfig(lr=3e-3, total_steps=12, warmup_steps=2)
    loader = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4))
    t = Trainer(cfg, tc, loader,
                TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=6,
                              log_every=1000),
                log_fn=lambda *_: None)
    hist = t.run(12)
    assert len(hist) == 12
    from repro.ckpt import checkpoint as ckpt
    from repro.train.step import init_train_state

    proto, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    state, step = ckpt.restore(proto, str(tmp_path))
    assert step == 12
    eng = Engine(state.params, cfg, max_seq=64)
    rng = np.random.default_rng(3)
    out = eng.generate([Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32),
        max_new_tokens=4)])
    assert len(out[0].out_tokens) == 4
