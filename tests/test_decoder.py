"""Row-decoder hypothesis tests (paper §7.1)."""

import pytest

from _proptest import sweep
from repro.core.decoder import RowDecoder, fig13_32row_example, fig14_example


def test_fig14_walkthrough():
    """APA(0, 7) activates exactly rows {0, 1, 6, 7}."""
    assert fig14_example() == (0, 1, 6, 7)


def test_fig13_127_128_gives_32_rows():
    """ACT 127 -> PRE -> ACT 128 splits all 5 predecoders -> 32 rows."""
    rows = fig13_32row_example()
    assert len(rows) == 32
    assert 127 in rows and 128 in rows


def test_reachable_counts_are_powers_of_two():
    """Limitation 2: only 2/4/8/16/32 simultaneous rows are reachable."""
    d = RowDecoder.for_subarray(512)
    seen = set()
    for rf in range(0, 512, 37):
        for rs in range(0, 512, 41):
            if rf != rs:
                seen.add(d.n_activated(rf, rs))
    assert seen <= {2, 4, 8, 16, 32}
    assert 2 in seen and 4 in seen


def test_count_is_two_to_split_predecoders():
    d = RowDecoder.for_subarray(512)
    for rf, rs in [(0, 1), (0, 7), (127, 128), (5, 250), (100, 413)]:
        k = d.split_predecoders(rf, rs)
        assert d.n_activated(rf, rs) == 2 ** k


@sweep(10)
def test_pair_for_n_rows_inverse(rng):
    d = RowDecoder.for_subarray(512)
    n = int(rng.choice([2, 4, 8, 16, 32]))
    base = int(rng.integers(0, 256))
    rf, rs = d.pair_for_n_rows(n, base)
    group = d.apa_activated_rows(rf, rs)
    assert len(group) == n
    assert base in group


def test_micron_1024_row_subarray_reaches_32():
    d = RowDecoder.for_subarray(1024)
    assert len(d.row_group(32, 0)) == 32
    assert len(d.predecoders) == 5


def test_group_contains_both_endpoints():
    d = RowDecoder.for_subarray(512)
    rows = d.apa_activated_rows(3, 300)
    assert 3 in rows and 300 in rows


def test_non_power_of_two_rejected():
    d = RowDecoder.for_subarray(512)
    with pytest.raises(ValueError):
        d.pair_for_n_rows(6)
    with pytest.raises(ValueError):
        d.pair_for_n_rows(64)
