"""Regenerate the golden-program fixtures (tests/golden/*.json).

Run after an *intentional* semantic change to program construction or
execution::

    PYTHONPATH=src:tests python tests/golden/generate.py

Each fixture freezes (a) a canonical serialized Program, (b) the seed of
its random initial (rows, words) state, (c) the expected final state
computed by the per-op oracle interpreter, (d) a ``megakernel``
section pinning the lowered level-table structure (shapes, per-level
slot counts, content digest) plus a digest of the expected final state,
and (e) a ``certificate`` section freezing the static analyzer's
verdict (:func:`repro.analyze.certify` digest + per-pass error/warning
counts) — so an analyzer change that silently alters what is checked,
or a compiler change that alters the artifacts, moves a pinned digest.
tests/test_compile_golden.py replays every fixture through per-op,
fused, and megakernel execution on all backends: a scheduler or
lowering change that reorders ops but alters results — or silently
repacks the tables — fails loudly against these bytes.  Review
regenerated diffs op-by-op — a changed ``expected`` row means changed
semantics, not formatting.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import numpy as np  # noqa: E402

WORDS = 4  # state width of every fixture (uint32 words per row)


def _adder(nbits: int):
    """Traced tier-5 ripple-carry adder over nbits-plane operands."""
    from repro.compile import trace_planes
    from repro.core import bitplanes as bp

    rng = np.random.default_rng(nbits)
    A = bp.pack(rng.integers(0, 2, (nbits, WORDS * 32)).astype(bool))
    B = bp.pack(rng.integers(0, 2, (nbits, WORDS * 32)).astype(bool))
    cp = trace_planes(lambda bs: list(bs.add(A, B)[0]), tier=5, n_act=32)
    return cp.program


def _maj_tree(x: int):
    """Two-level MAJ_x reduction tree: x*x leaf rows -> x -> 1."""
    from repro.core import calibration as cal
    from repro.pud.isa import Program

    prog = Program()
    n_act = cal.min_activation_for(x)
    leaves = x * x
    for i in range(x):
        prog.emit("MAJ", x=x, n_act=n_act, tag=f"tree/l1[{i}]",
                  srcs=tuple(range(i * x, (i + 1) * x)),
                  dsts=(leaves + i,))
    prog.emit("MAJ", x=x, n_act=n_act, tag="tree/root",
              srcs=tuple(range(leaves, leaves + x)),
              dsts=(leaves + x,))
    return prog


def _mrc_fanout31():
    """Fan-out-31 Multi-RowCopy waves + a vote over the copies."""
    from repro.pud.isa import Program

    prog = Program()
    prog.emit("WR", tag="stage/pattern")
    prog.emit("MRC", n_act=32, tag="wave0", srcs=(0,),
              dsts=tuple(range(1, 32)))
    prog.emit("NOT", tag="complement", srcs=(16,), dsts=(32,))
    prog.emit("MRC", n_act=32, tag="wave1", srcs=(32,),
              dsts=tuple(range(33, 64)))
    prog.emit("MAJ", x=3, n_act=4, tag="vote", srcs=(1, 31, 33),
              dsts=(64,))
    return prog


FIXTURES = {
    "add8": lambda: _adder(8),
    "add16": lambda: _adder(16),
    "add32": lambda: _adder(32),
    "maj5_tree": lambda: _maj_tree(5),
    "maj7_tree": lambda: _maj_tree(7),
    "maj9_tree": lambda: _maj_tree(9),
    "mrc_fanout31": _mrc_fanout31,
}


def _megakernel_section(prog, final: np.ndarray) -> dict:
    """Freeze the lowered level-table structure + final-state digest."""
    import hashlib

    from repro.compile import build_schedule, lower_schedule

    low = lower_schedule(build_schedule(prog))
    return {
        "n_levels": low.n_levels,
        "w_max": low.w_max,
        "x_max": low.x_max,
        "level_meta": [list(c) for c in low.level_meta],
        "table_digest": low.digest(),
        "final_digest": hashlib.sha256(
            np.ascontiguousarray(final).tobytes()).hexdigest(),
    }


def _certificate_section(prog) -> dict:
    """Freeze the analyzer's certificate for schedule + lowering.

    Deterministic: the digest covers program content, both artifact
    digests, the analyzer version, and the per-pass finding counts —
    ``python -m repro.analyze --golden`` and
    ``tests/test_compile_golden.py`` both recompute and compare it.
    """
    from repro.analyze import certify
    from repro.compile import build_schedule, lower_schedule

    sched = build_schedule(prog)
    cert = certify(prog, sched=sched, lowering=lower_schedule(sched))
    return cert.to_dict()


def main() -> None:
    from repro.backends import ExecutionContext, get_backend

    oracle = get_backend("oracle", ExecutionContext(ideal=True))
    out_dir = os.path.dirname(os.path.abspath(__file__))
    for name, build in FIXTURES.items():
        prog = build()
        seed = sum(ord(c) for c in name)  # stable, content-derived
        rng = np.random.default_rng((seed, 0x601D))
        state = rng.integers(0, 2 ** 32, (prog.n_rows(), WORDS),
                             dtype=np.uint32)
        final = np.asarray(oracle.run(prog, state))
        doc = {
            "name": name,
            "seed": seed,
            "rows": prog.n_rows(),
            "words": WORDS,
            "ops": json.loads(prog.to_json()),
            "expected": ["".join(f"{w:08x}" for w in row) for row in final],
            "megakernel": _megakernel_section(prog, final),
            "certificate": _certificate_section(prog),
        }
        path = os.path.join(out_dir, f"{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {path}: {len(prog.ops)} ops, {prog.n_rows()} rows")


if __name__ == "__main__":
    main()
