"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import rand_u32, sweep
from repro.core import bitplanes as bp
from repro.kernels.bitserial.ops import add_u32, bitserial_add
from repro.kernels.bitserial.ref import bitserial_add_ref
from repro.kernels.majx.ops import majx, vote
from repro.kernels.majx.ref import majx_ref
from repro.kernels.mismatch.ops import mismatch_count, success_rate
from repro.kernels.mismatch.ref import mismatch_count_ref
from repro.kernels.rowcopy.ops import fanout
from repro.kernels.rowcopy.ref import fanout_ref


@pytest.mark.parametrize("n", [3, 5, 7, 9])
@pytest.mark.parametrize("shape", [(1, 64), (8, 128), (13, 700), (32, 2048)])
def test_majx_kernel_shapes(n, shape):
    rng = np.random.default_rng((n, *shape))
    x = jnp.asarray(rand_u32(rng, n, *shape))
    assert (np.asarray(majx(x)) == np.asarray(majx_ref(x))).all()


@sweep(6)
def test_majx_kernel_random_blocks(rng):
    n = int(rng.choice([3, 5, 7, 9]))
    r = int(rng.integers(1, 40))
    c = int(rng.integers(1, 900))
    x = jnp.asarray(rand_u32(rng, n, r, c))
    br = int(rng.choice([8, 16]))
    bc = int(rng.choice([128, 256, 512]))
    got = majx(x, block_r=br, block_c=bc)
    assert (np.asarray(got) == np.asarray(majx_ref(x))).all()


@pytest.mark.parametrize("nbits", [8, 16, 32])
def test_bitserial_add_widths(nbits):
    rng = np.random.default_rng(nbits)
    a = rand_u32(rng, 4, 300) >> (32 - nbits)
    b = rand_u32(rng, 4, 300) >> (32 - nbits)
    pa = bp.pack_uint_elements(jnp.asarray(a.reshape(-1)), nbits).reshape(
        nbits, -1)
    pb = bp.pack_uint_elements(jnp.asarray(b.reshape(-1)), nbits).reshape(
        nbits, -1)
    got = bitserial_add(pa, pb)
    want = bitserial_add_ref(pa, pb)
    assert (np.asarray(got) == np.asarray(want)).all()


@sweep(6)
def test_add_u32_matches_numpy(rng):
    k = int(rng.integers(1, 700))
    a, b = rand_u32(rng, k), rand_u32(rng, k)
    got = np.asarray(add_u32(a, b))
    assert (got == (a + b)).all()


@pytest.mark.parametrize("fanout_n", [1, 3, 7, 15, 31])
def test_fanout_kernel(fanout_n):
    rng = np.random.default_rng(fanout_n)
    src = jnp.asarray(rand_u32(rng, 9, 300))
    got = fanout(src, fanout_n)
    want = fanout_ref(src, fanout_n)
    assert got.shape == (fanout_n, 9, 300)
    assert (np.asarray(got) == np.asarray(want)).all()


@sweep(6)
def test_mismatch_kernel(rng):
    n = int(rng.integers(1, 3000))
    g, w = rand_u32(rng, n), rand_u32(rng, n)
    got = int(mismatch_count(jnp.asarray(g), jnp.asarray(w)))
    want = int(mismatch_count_ref(jnp.asarray(g), jnp.asarray(w)))
    assert got == want
    assert success_rate(g, g) == 1.0


def test_vote_kernel_heals_corruption():
    from repro.pud.tmr import corrupt

    # key chosen so no bit flips in >= 2 replicas (TMR heals single faults
    # only; a double fault is uncorrectable by majority, not a kernel bug)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (513,), jnp.float32)
    reps = [corrupt(x, jax.random.fold_in(key, i), 1e-3) for i in range(3)]
    healed = vote(reps)
    assert (np.asarray(healed) == np.asarray(x)).all()


def test_vote_kernel_bf16():
    from repro.pud.tmr import corrupt

    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (257,), jnp.float32).astype(jnp.bfloat16)
    reps = [corrupt(x, jax.random.fold_in(key, i), 5e-4) for i in range(5)]
    healed = vote(reps)
    assert (np.asarray(healed) == np.asarray(x)).all()
