"""Model-zoo component tests: attention paths, Mamba2, MoE, xLSTM."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.models import attention as A
from repro.models import mamba2, xlstm
from repro.models import model as M


def _mk_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=128)
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------- attention


def test_streaming_matches_dense():
    cfg = _mk_cfg()
    key = jax.random.PRNGKey(0)
    p, _ = A.init_attention(key, cfg)
    x = jax.random.normal(key, (2, 64, 64), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    dense = A.attention_forward(p, x, pos, cfg, streaming_threshold=10**9)
    stream = A.attention_forward(p, x, pos, cfg, streaming_threshold=1)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(stream),
                               atol=2e-5)


def test_sliding_window_masks_distant_tokens():
    cfg = _mk_cfg(sliding_window=8)
    key = jax.random.PRNGKey(1)
    p, _ = A.init_attention(key, cfg)
    x = jax.random.normal(key, (1, 32, 64), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(32), (1, 32))
    out_full = A.attention_forward(p, x, pos, cfg)
    # changing a token > window away must not change the output at t=31
    x2 = x.at[:, 5].set(1.0)
    out2 = A.attention_forward(p, x2, pos, cfg)
    np.testing.assert_allclose(np.asarray(out_full[:, 31]),
                               np.asarray(out2[:, 31]), atol=1e-5)
    assert not np.allclose(np.asarray(out_full[:, 6]), np.asarray(out2[:, 6]))


def test_gqa_equals_mha_when_kv_equals_heads():
    cfg_mha = _mk_cfg(n_kv_heads=4)
    key = jax.random.PRNGKey(2)
    p, _ = A.init_attention(key, cfg_mha)
    x = jax.random.normal(key, (2, 16, 64), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    out = A.attention_forward(p, x, pos, cfg_mha)
    assert out.shape == (2, 16, 64)


def test_prefill_then_decode_matches_forward():
    """Incremental decode reproduces teacher-forced logits."""
    cfg = _mk_cfg(n_layers=2)
    key = jax.random.PRNGKey(3)
    params, _ = M.init(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, {"tokens": toks}, cfg)
    logits_pre, cache = M.prefill(params, {"tokens": toks[:, :8]}, cfg,
                                  max_seq=16)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(logits_full[:, 7]), atol=2e-3)
    lg = logits_pre
    for t in range(8, 12):
        lg, cache = M.decode(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]), atol=2e-3)


def test_swa_rolling_cache_decode():
    """Rolling-window decode matches full forward within the window."""
    cfg = _mk_cfg(sliding_window=8, n_layers=1)
    key = jax.random.PRNGKey(4)
    params, _ = M.init(key, cfg)
    toks = jax.random.randint(key, (1, 20), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, {"tokens": toks}, cfg)
    _, cache = M.prefill(params, {"tokens": toks[:, :16]}, cfg, max_seq=32)
    lg, cache = M.decode(params, toks[:, 16:17], cache, cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, 16]), atol=2e-3)


# ----------------------------------------------------------- mamba2


def test_mamba2_chunked_matches_recurrence():
    cfg = get_config("zamba2-1.2b", smoke=True)
    key = jax.random.PRNGKey(5)
    p, _ = mamba2.init_mamba2(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model), jnp.float32) * 0.3
    y_chunk, st = mamba2.mamba2_forward(p, x, cfg)
    y_ref = mamba2.mamba2_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=3e-4, rtol=1e-3)


def test_mamba2_state_continuation():
    cfg = get_config("zamba2-1.2b", smoke=True)
    key = jax.random.PRNGKey(6)
    p, _ = mamba2.init_mamba2(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32) * 0.3
    y_all, _ = mamba2.mamba2_forward(p, x, cfg)
    y1, st = mamba2.mamba2_forward(p, x[:, :8], cfg)
    ys = [y1]
    for t in range(8, 16):
        y, st = mamba2.mamba2_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_all), np.asarray(y_cat),
                               atol=3e-4, rtol=1e-3)


# ----------------------------------------------------------- xlstm


def test_mlstm_chunked_matches_stepwise():
    cfg = get_config("xlstm-125m", smoke=True)
    key = jax.random.PRNGKey(7)
    p, _ = xlstm.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, 40, cfg.d_model), jnp.float32) * 0.5
    y1, st1 = xlstm.mlstm_forward(p, x, cfg, chunk=8)
    y2, st2 = xlstm.mlstm_forward_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st1.c), np.asarray(st2.c),
                               atol=2e-5)


def test_mlstm_decode_continues_chunked_state():
    cfg = get_config("xlstm-125m", smoke=True)
    key = jax.random.PRNGKey(8)
    p, _ = xlstm.init_mlstm(key, cfg)
    x = jax.random.normal(key, (1, 17, cfg.d_model), jnp.float32) * 0.5
    y_all, _ = xlstm.mlstm_forward_reference(p, x, cfg)
    _, st = xlstm.mlstm_forward(p, x[:, :16], cfg, chunk=8)
    y_last, _ = xlstm.mlstm_decode(p, x[:, 16:], cfg, st)
    np.testing.assert_allclose(np.asarray(y_all[:, -1:]),
                               np.asarray(y_last), atol=3e-5)


# ----------------------------------------------------------- moe


def test_moe_top1_equals_dense_expert():
    """With 1 expert and top-1 routing, MoE == that expert's SwiGLU."""
    cfg = _mk_cfg(family="moe", n_experts=1, top_k=1, capacity_factor=4.0)
    from repro.models.moe import init_moe, moe_forward

    key = jax.random.PRNGKey(9)
    p, _ = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, 64), jnp.float32) * 0.3
    out, aux = moe_forward(p, x, cfg)
    want = (jax.nn.silu(x @ p["w_gate"][0]) * (x @ p["w_up"][0])) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_moe_routing_mass_conservation():
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    from repro.models.moe import init_moe, moe_forward

    key = jax.random.PRNGKey(10)
    p, _ = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32) * 0.3
    out, aux = moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 at/above perfect balance
    assert jnp.isfinite(out).all()
