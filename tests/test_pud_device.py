"""PUDDevice integration: bank topology, op accounting, fan-out broadcast."""

import jax.numpy as jnp
import numpy as np

from _proptest import rand_u32, sweep
from repro.pud.device import DeviceConfig, PUDDevice
from repro.core.subarray import DeviceProfile


def _dev(ideal=True, **kw):
    return PUDDevice(DeviceConfig(cols=256, ideal=ideal, **kw))


def test_topology():
    d = _dev(n_banks=4, subarrays_per_bank=2)
    assert d.n_subarrays == 8
    assert d.subarray(3, 1) is d.subarrays[7]


def test_majx_and_accounting():
    rng = np.random.default_rng(0)
    d = _dev()
    ops = [jnp.asarray(rand_u32(rng, d.subarrays[0].n_words))
           for _ in range(3)]
    out = d.majx(0, ops, 4)
    from repro.core.majx import majx_reference

    assert (np.asarray(out) == np.asarray(majx_reference(jnp.stack(ops)))).all()
    st = d.stats()
    assert st["ops"] == 1 and st["elapsed_ns"] > 0 and st["energy_nj"] > 0
    assert ("MAJ", 3, 4) in st["histogram"]


def test_broadcast_fanout_replicates():
    rng = np.random.default_rng(1)
    d = _dev()
    src = jnp.asarray(rand_u32(rng, d.subarrays[0].n_words))
    rows = d.broadcast_fanout(0, src, 40)
    assert len(rows) == 40
    sa = d.subarray(0)
    for r in rows:
        assert (np.asarray(sa.read_row(r)) == np.asarray(src)).all()


@sweep(4)
def test_rowclone_roundtrip(rng):
    d = _dev()
    sa = d.subarray(1)
    src = jnp.asarray(rand_u32(rng, sa.n_words))
    sa.write_row(3, src)
    d.rowclone(1, 3, 77)
    assert (np.asarray(sa.read_row(77)) == np.asarray(src)).all()


def test_samsung_device_profile_rejected_ops():
    d = PUDDevice(DeviceConfig(profile=DeviceProfile.mfr_s(), cols=256,
                               ideal=True))
    assert d.errors.majx_success(3, 4) == 0.0
