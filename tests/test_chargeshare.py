"""Monte-Carlo charge-sharing model reproduces the §7.2 SPICE study."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import calibration as cal
from repro.core import chargeshare as cs


def test_deviation_gain_anchor():
    """MAJ3@32-row has exactly +159.05 % bitline deviation vs @4-row."""
    gain = cs.deviation_mean(32) / cs.deviation_mean(4) - 1
    assert gain == pytest.approx(cal.SPICE_DEVIATION_GAIN_32_OVER_4_REL,
                                 rel=1e-6)


def test_deviation_monotone_in_replication():
    devs = [cs.deviation_mean(n) for n in (4, 8, 16, 32)]
    assert devs == sorted(devs)


def test_pv_sensitivity_4row_vs_32row():
    """At 40 % process variation: 4-row drops ~46.58 %, 32-row ~0.01 %."""
    key = jax.random.PRNGKey(0)
    r4_0 = cs.monte_carlo_maj3(key, 4, 0.0)
    r4_40 = cs.monte_carlo_maj3(key, 4, 0.40)
    r32_40 = cs.monte_carlo_maj3(key, 32, 0.40)
    s4_0 = float(jnp.mean(r4_0["success"]))
    s4_40 = float(jnp.mean(r4_40["success"]))
    s32_40 = float(jnp.mean(r32_40["success"]))
    assert s4_0 == pytest.approx(1.0, abs=1e-3)
    assert 1 - s4_40 / s4_0 == pytest.approx(cal.SPICE_MAJ3_4ROW_PV_DROP_REL,
                                             abs=0.05)
    assert 1 - s32_40 == pytest.approx(cal.SPICE_MAJ3_32ROW_PV_DROP_REL,
                                       abs=0.005)


def test_success_monotone_in_n_act_under_pv():
    key = jax.random.PRNGKey(1)
    succ = [float(jnp.mean(cs.monte_carlo_maj3(key, n, 0.30)["success"]))
            for n in (4, 8, 16, 32)]
    assert all(b >= a - 0.02 for a, b in zip(succ, succ[1:]))


def test_neutral_rows_contribute_no_charge():
    model = cs.BitlineModel()
    charges = jnp.asarray([1.0, 1.0, 0.0, 0.5])  # MAJ3 + one Frac row
    caps = jnp.ones((4,))
    dev_with = model.deviation(charges, caps)
    dev_without = model.deviation(charges[:3], caps[:3])
    # neutral row adds capacitance (denominator) but no differential charge
    assert float(dev_with) < float(dev_without)
    assert float(dev_with) > 0


def test_sense_amp_margin():
    model = cs.BitlineModel()
    assert float(model.sense(jnp.asarray(model.sense_margin * 2))) == 1.0
    assert float(model.sense(jnp.asarray(-model.sense_margin * 2))) == -1.0
    assert float(model.sense(jnp.asarray(model.sense_margin / 2))) == 0.0


def test_spice_study_shapes():
    out = cs.spice_study(jax.random.PRNGKey(2), iters=500)
    assert (1, 0.0) in out and (32, 0.40) in out
    # Fig 15a: activating *more than eight* rows always beats the
    # single-row-activation deviation (paper §7.2, observation 2).
    for pv in cal.SPICE_PV_LEVELS:
        for n in (16, 32):
            assert out[(n, pv)]["dev_mean"] > out[(1, pv)]["dev_mean"]
