"""Seeded random-sweep property testing (hypothesis is not installable in
this offline container; this keeps the same many-cases + explicit-edges
discipline with deterministic seeds).

The leading underscore marks this as a *helper* module, deliberately
outside pytest's ``test_*.py`` collection pattern: it must never define
tests of its own (``tests/test_compile_differential.py`` has a meta-test
enforcing that for every helper under ``tests/``, so no coverage can go
silently uncollected)."""

from __future__ import annotations

import numpy as np
import pytest


def sweep(n_cases: int = 20, seed: int = 0):
    """Parametrize a test over ``n_cases`` seeded numpy Generators."""
    rngs = [np.random.default_rng((seed, i)) for i in range(n_cases)]
    return pytest.mark.parametrize(
        "rng", rngs, ids=[f"case{i}" for i in range(n_cases)])


def rand_u32(rng, *shape) -> np.ndarray:
    return rng.integers(0, 2**32, size=shape, dtype=np.uint32)


def rand_bits(rng, *shape) -> np.ndarray:
    return rng.integers(0, 2, size=shape).astype(bool)
