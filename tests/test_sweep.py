"""Sweep engine: spec identity, planning, resumability, record parity.

The load-bearing guarantees: a killed campaign restarts without
recomputing or changing its aggregates; the same grid measured by
oracle / sim / pallas under ideal contexts yields identical records;
the analytic pseudo-backend equals the calibrated ErrorModel surface.
"""

import json
import os

import pytest

from repro.core.errormodel import ErrorModel
from repro.sweep import (RecordStore, SweepSpec, aggregate, plan,
                         presets, run_sweep, shard)
from repro.sweep.run import main as sweep_cli

TINY = dict(x_values=(3,), n_act=(4, 32), ideal=True, rows=2, words=16,
            chunk=2)


# ------------------------------------------------------------ spec / grid


def test_spec_hash_stable_and_content_sensitive():
    a = SweepSpec(name="s", **TINY)
    assert a.spec_hash() == SweepSpec(name="s", **TINY).spec_hash()
    assert a.spec_hash() != a.replace(n_act=(32,)).spec_hash()
    assert a.spec_hash() != a.replace(seeds=(1,)).spec_hash()


def test_spec_json_roundtrip():
    spec = SweepSpec(name="rt", op="mrc", backends=("sim",),
                     timings=((36.0, 3.0),), n_act=(8,))
    again = SweepSpec.from_json(spec.to_json())
    assert again == spec and again.spec_hash() == spec.spec_hash()


def test_grid_drops_unreachable_combinations():
    spec = SweepSpec(name="g", op="majx", x_values=(3, 5), n_act=(4, 32))
    pts = list(spec.points())
    # MAJ5@4-row cannot hold five operands and is filtered (§3.3).
    assert all(not (p.x == 5 and p.n_act == 4) for p in pts)
    assert len(pts) == 3
    assert [p.index for p in pts] == [0, 1, 2]  # dense, stable indices


def test_spec_rejects_bad_axes():
    with pytest.raises(ValueError, match="not reachable"):
        SweepSpec(name="bad", n_act=(6,))
    with pytest.raises(ValueError, match="patterns"):
        SweepSpec(name="bad", op="majx", patterns=("0x00",))
    with pytest.raises(ValueError, match="odd"):
        SweepSpec(name="bad", x_values=(4,))
    with pytest.raises(ValueError, match="unknown backends"):
        SweepSpec(name="bad", backends=("palas",))
    with pytest.raises(ValueError, match="analytic-only"):
        SweepSpec(name="bad", op="simra", backends=("sim",))


# --------------------------------------------------------------- planning


def test_plan_chunks_partition_grid():
    spec = SweepSpec(name="p", backends=("sim", "pallas"), **TINY)
    chunks = plan(spec)
    seen = [p.index for c in chunks for p in c.points]
    assert sorted(seen) == list(range(spec.n_points()))
    assert all(len(c.points) <= spec.chunk for c in chunks)
    # one backend per chunk (the unit of backend-native batching)
    assert all(len({p.backend for p in c.points}) == 1 for c in chunks)


def test_shard_partition_disjoint_and_complete():
    spec = SweepSpec(name="sh", backends=("sim", "pallas"), **TINY)
    chunks = plan(spec)
    parts = [shard(chunks, 3, i) for i in range(3)]
    keys = [c.key for p in parts for c in p]
    assert sorted(keys) == sorted(c.key for c in chunks)
    assert len(set(keys)) == len(keys)


# ----------------------------------------------------- execution / resume


def test_sweep_executes_then_fully_caches(tmp_path):
    spec = SweepSpec(name="cache", backends=("sim",), **TINY)
    first = run_sweep(spec, str(tmp_path))
    assert first.executed_chunks > 0 and first.cached_chunks == 0
    assert len(first.records) == spec.n_points()

    second = run_sweep(spec, str(tmp_path))
    assert second.executed_chunks == 0
    assert second.cached_chunks == first.executed_chunks
    assert second.records == first.records


def test_resume_after_kill_recomputes_nothing(tmp_path):
    """Kill mid-sweep (max_chunks), restart: only missing chunks run and
    aggregates equal an uninterrupted run's."""
    spec = SweepSpec(name="kill", backends=("sim",), seeds=(0, 1), **TINY)
    total = len(plan(spec))
    assert total >= 2

    partial = run_sweep(spec, str(tmp_path / "a"), max_chunks=1)
    assert partial.executed_chunks == 1

    # mtimes identify recomputation of already-stored chunks
    store = RecordStore(str(tmp_path / "a"), spec)
    before = {k: os.path.getmtime(os.path.join(store.path, "chunks",
                                               k + ".json"))
              for k in store.completed()}

    resumed = run_sweep(spec, str(tmp_path / "a"))
    assert resumed.executed_chunks == total - 1
    for k, mt in before.items():
        assert os.path.getmtime(os.path.join(
            store.path, "chunks", k + ".json")) == mt

    uninterrupted = run_sweep(spec, str(tmp_path / "b"))
    assert resumed.records == uninterrupted.records
    assert (aggregate.headline(resumed.records)
            == aggregate.headline(uninterrupted.records))


def test_resume_invalidated_by_calibration_fingerprint_change(
        tmp_path, monkeypatch):
    """Editing the calibrated physics must orphan cached records, not
    silently serve them: the spec hash folds in the calibration/
    errormodel source fingerprint, so the same grid re-executes from
    scratch in a fresh store while the stale store stays untouched."""
    import repro.sweep.spec as spec_mod

    spec = SweepSpec(name="fp", backends=("sim",), **TINY)
    first = run_sweep(spec, str(tmp_path))
    assert first.executed_chunks > 0
    old_hash = spec.spec_hash()
    old_store = RecordStore(str(tmp_path), spec)
    old_mtimes = {k: os.path.getmtime(os.path.join(
        old_store.path, "chunks", k + ".json"))
        for k in old_store.completed()}
    assert old_mtimes

    # A physics edit changes the module fingerprint...
    monkeypatch.setattr(spec_mod, "_model_fingerprint", lambda: "0badcafe")
    assert spec.spec_hash() != old_hash

    # ...so resuming the identical grid recomputes every chunk into a
    # new store instead of reusing stale records.
    second = run_sweep(spec, str(tmp_path))
    assert second.executed_chunks == first.executed_chunks
    assert second.cached_chunks == 0
    assert second.store_path != first.store_path

    # The pre-change store is preserved verbatim for audit.
    for k, mt in old_mtimes.items():
        assert os.path.getmtime(os.path.join(
            old_store.path, "chunks", k + ".json")) == mt

    # And a third run under the new fingerprint is fully cached again.
    third = run_sweep(spec, str(tmp_path))
    assert third.executed_chunks == 0
    assert third.cached_chunks == second.executed_chunks


def test_sharded_workers_complete_one_store(tmp_path):
    spec = SweepSpec(name="workers", backends=("sim", "pallas"), **TINY)
    r0 = run_sweep(spec, str(tmp_path), num_shards=2, shard_index=0)
    assert len(r0.records) < spec.n_points()
    r1 = run_sweep(spec, str(tmp_path), num_shards=2, shard_index=1)
    assert len(r1.records) == spec.n_points()
    assert run_sweep(spec, str(tmp_path)).executed_chunks == 0


# ----------------------------------------------------------- record parity


def test_backend_record_parity_on_tiny_grid(tmp_path):
    """oracle / sim / pallas sweep records agree point-for-point under
    ideal contexts (same data, same reference, success exactly 1.0)."""
    spec = SweepSpec(name="parity", backends=("oracle", "sim", "pallas"),
                     patterns=("random", "0x00/0xFF"), **TINY)
    records = run_sweep(spec, str(tmp_path)).records
    assert len(records) == spec.n_points()
    by_backend = {}
    for r in records:
        key = (r["x"], r["n_act"], r["pattern"], r["seed"])
        by_backend.setdefault(r["backend"], {})[key] = (
            r["success"], r["n_bits"])
    assert set(by_backend) == {"oracle", "sim", "pallas"}
    assert by_backend["oracle"] == by_backend["sim"] == by_backend["pallas"]
    assert all(s == 1.0 for recs in by_backend.values()
               for s, _ in recs.values())


def test_mrc_sweep_parity(tmp_path):
    spec = SweepSpec(name="mrc-parity", op="mrc",
                     backends=("sim", "pallas"), n_act=(8, 32),
                     ideal=True, words=16, chunk=4)
    records = run_sweep(spec, str(tmp_path)).records
    assert {r["n_dest"] for r in records} == {7, 31}
    assert all(r["success"] == 1.0 for r in records)


def test_analytic_matches_errormodel(tmp_path):
    spec = presets.fig6_spec()
    records = run_sweep(spec, str(tmp_path)).records
    em = ErrorModel("H")
    for r in records:
        want = em.majx_success(r["x"], r["n_act"], t1=r["t1"], t2=r["t2"],
                               pattern=r["pattern"], temp_c=r["temp_c"],
                               vpp_v=r["vpp_v"])
        assert r["success"] == pytest.approx(want)
        assert r["expected"] == pytest.approx(want)
    # Obs 6 headline falls out of the aggregation layer
    assert aggregate.replication_delta(records) == pytest.approx(
        0.3081, abs=1e-4)


def test_stochastic_records_independent_of_execution_history(tmp_path):
    """Measured values must be a pure function of (spec, chunk): a
    killed-and-resumed stochastic sweep and a 2-shard stochastic sweep
    produce records identical to an uninterrupted single-worker run."""
    spec = SweepSpec(name="det", backends=("sim",), x_values=(3, 5),
                     n_act=(32,), rows=2, words=32, chunk=1)
    baseline = run_sweep(spec, str(tmp_path / "base")).records

    run_sweep(spec, str(tmp_path / "resumed"), max_chunks=1)
    resumed = run_sweep(spec, str(tmp_path / "resumed")).records
    assert resumed == baseline

    run_sweep(spec, str(tmp_path / "sharded"), num_shards=2, shard_index=1)
    sharded = run_sweep(spec, str(tmp_path / "sharded"),
                        num_shards=2, shard_index=0)
    assert run_sweep(spec, str(tmp_path / "sharded")).records == baseline
    assert sharded.pending_chunks == 0


def test_stochastic_sim_tracks_calibration(tmp_path):
    spec = SweepSpec(name="stoch", backends=("sim",), x_values=(3,),
                     n_act=(4, 32), rows=2, words=64, chunk=8)
    records = run_sweep(spec, str(tmp_path)).records
    for r in records:
        assert r["success"] == pytest.approx(r["expected"], abs=0.05)
    assert aggregate.replication_delta(records) > 0.15  # Obs 6 ordering


# ------------------------------------------------------------ aggregation


def _rec(**kw):
    base = dict(op="majx", backend="sim", mfr="H", x=3, n_act=32, n_dest=0,
                pattern="random", t1=1.5, t2=3.0, temp_c=50.0, vpp_v=2.5,
                seed=0, success=1.0, expected=1.0, n_bits=64, index=0)
    base.update(kw)
    return base


def test_aggregates_accept_one_shot_generators():
    """Regression: headline()/pattern_sensitivity()/replication_delta()
    iterated their input more than once, so a generator argument
    silently computed from a partial (or empty) record set."""
    records = [
        _rec(index=0, n_act=4, success=0.6),
        _rec(index=1, n_act=32, success=0.9),
        _rec(index=2, n_act=32, pattern="0x00/0xFF", success=0.8),
        _rec(index=3, n_act=32, temp_c=85.0, success=0.7),
    ]
    assert aggregate.replication_delta(iter(records)) \
        == aggregate.replication_delta(records)
    assert aggregate.pattern_sensitivity(iter(records)) \
        == aggregate.pattern_sensitivity(records)
    head = aggregate.headline(iter(records))
    assert head == aggregate.headline(records)
    # every headline family must actually be present, so the generator
    # path exercised each multi-pass reducer
    assert {"maj3_32_over_4_rel", "pattern_effect_x3_rel",
            "temp_variation_max_rel"} <= set(head)


def test_env_resilience_distinguishes_absent_from_zero_baseline():
    """Regression: a group whose nominal-condition success was exactly
    0.0 was skipped as if it had never been measured."""
    # absent baseline: no record at 50C -> group skipped, variation 0
    absent = [_rec(temp_c=85.0, success=0.4)]
    assert aggregate.env_resilience(absent, "temp_c", 50.0) == 0.0

    # zero baseline, succeeds elsewhere: unbounded relative variation
    revived = [_rec(temp_c=50.0, success=0.0),
               _rec(index=1, temp_c=85.0, success=0.4)]
    assert aggregate.env_resilience(revived, "temp_c", 50.0) \
        == float("inf")

    # zero baseline, zero everywhere: contributes no variation
    dead = [_rec(temp_c=50.0, success=0.0),
            _rec(index=1, temp_c=85.0, success=0.0)]
    assert aggregate.env_resilience(dead, "temp_c", 50.0) == 0.0


# ------------------------------------------------------------------- CLI


def test_cli_smoke_and_expect_cached(tmp_path, capsys):
    root = str(tmp_path)
    assert sweep_cli(["--smoke", "--root", root, "--quiet"]) == 0
    # second run: fully cached; --expect-cached enforces zero executions
    assert sweep_cli(["--smoke", "--root", root, "--quiet",
                      "--expect-cached"]) == 0
    out = capsys.readouterr().out
    assert "0 chunks executed" in out

    # a changed spec gets a different store: --expect-cached now fails
    assert sweep_cli(["--figure", "fig3", "--root", root, "--quiet",
                      "--expect-cached"]) == 1


def test_store_chunk_files_are_self_describing(tmp_path):
    spec = SweepSpec(name="audit", backends=("sim",), **TINY)
    result = run_sweep(spec, str(tmp_path))
    store_dir = result.store_path
    with open(os.path.join(store_dir, "spec.json")) as f:
        assert SweepSpec.from_json(f.read()) == spec
    chunk_files = sorted(os.listdir(os.path.join(store_dir, "chunks")))
    assert chunk_files
    with open(os.path.join(store_dir, "chunks", chunk_files[0])) as f:
        payload = json.load(f)
    assert payload["indices"] == [r["index"] for r in payload["records"]]
