"""Differential tests for the program-fusion layer (repro.compile).

The load-bearing guarantee: for ANY addressed Program, fused execution
(`run_fused`, level-batched kernel dispatches on ``pallas``) is
bit-identical to per-op interpretation (`run`) on every backend — the
oracle reference, the ideal behavioural sim, and pallas itself.  The
generator deliberately produces the hazards the scheduler must respect:
destination rows aliasing sources, rows rewritten many times, dead ops
whose results nothing reads, cost-only ops, and mixed MAJ arities inside
one dependency level.
"""

import os

import jax.numpy as jnp
import numpy as np

from _proptest import rand_u32, sweep
from repro.backends import ExecutionContext, get_backend
from repro.compile import (build_schedule, compile_elementwise,
                           dependency_levels)
from repro.core import calibration as cal
from repro.pud.isa import Program

IDEAL = ExecutionContext(ideal=True)
ROWS, WORDS = 20, 8


# ------------------------------------------------------------ generator


def rand_program(rng, rows: int = ROWS, n_ops: int = 10) -> Program:
    """Random DAG-shaped addressed Program with deliberate hazards.

    Ops read/write one shared row space with replacement, so source
    aliasing, repeated rewrites of a row, and dead stores all occur;
    cost-only (addressless) and FRAC/WR/RD ops are mixed in to check
    they stay value-neutral under fusion.
    """
    prog = Program()
    for _ in range(n_ops):
        kind = rng.choice(["MAJ", "MAJ", "MAJ", "NOT", "COPY", "MRC",
                           "FRAC", "WR", "cost"])
        if kind == "cost":  # recorded for costing only: no addresses
            prog.emit("MAJ", x=3, n_act=4)
        elif kind == "MAJ":
            x = int(rng.choice([3, 5, 7]))
            srcs = tuple(int(r) for r in rng.integers(0, rows, x))
            n_dst = int(rng.integers(1, 3))
            dsts = tuple(int(r) for r in rng.integers(0, rows, n_dst))
            prog.emit("MAJ", x=x, n_act=cal.min_activation_for(x),
                      srcs=srcs, dsts=dsts)
        elif kind in ("NOT", "COPY"):
            prog.emit(kind, srcs=(int(rng.integers(0, rows)),),
                      dsts=tuple(int(r)
                                 for r in rng.integers(0, rows,
                                                       rng.integers(1, 3))))
        elif kind == "MRC":
            fan = int(rng.integers(1, 8))
            prog.emit("MRC", n_act=8, srcs=(int(rng.integers(0, rows)),),
                      dsts=tuple(int(r) for r in rng.integers(0, rows, fan)))
        elif kind == "FRAC":
            prog.emit("FRAC", dsts=(int(rng.integers(0, rows)),))
        else:
            prog.emit("WR")
    return prog


def _run_everywhere(prog: Program, state) -> dict[str, np.ndarray]:
    outs = {}
    for name in ("oracle", "sim", "pallas"):
        be = get_backend(name, IDEAL)
        outs[f"{name}/per_op"] = np.asarray(be.run(prog, state))
        outs[f"{name}/fused"] = np.asarray(be.run_fused(prog, state))
    return outs


# ----------------------------------------------------- differential sweep


@sweep(n_cases=8, seed=0x5EED)
def test_random_programs_fused_equals_per_op_everywhere(rng):
    prog = rand_program(rng)
    state = jnp.asarray(rand_u32(rng, ROWS, WORDS))
    outs = _run_everywhere(prog, state)
    want = outs["oracle/per_op"]
    for name, got in outs.items():
        assert (got == want).all(), name


def test_destination_aliasing_program():
    """An op overwriting its own source row, twice over."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(0,))  # dst in srcs
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(1,))  # reads new 0
    prog.emit("NOT", srcs=(1,), dsts=(1,))                     # in-place NOT
    prog.emit("MRC", n_act=4, srcs=(1,), dsts=(2, 0, 3))       # clobber 0
    rng = np.random.default_rng(1)
    state = jnp.asarray(rand_u32(rng, 4, WORDS))
    outs = _run_everywhere(prog, state)
    want = outs["oracle/per_op"]
    for name, got in outs.items():
        assert (got == want).all(), name
    # the in-place chain forces strictly increasing levels
    assert len(dependency_levels(prog)) == 4


def test_dead_ops_still_write_their_rows():
    """Dead stores (results never read) must still land in state."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(5,))  # dead
    prog.emit("COPY", srcs=(0,), dsts=(6,))                    # dead
    prog.emit("MAJ", x=3, n_act=4, srcs=(1, 2, 3), dsts=(4,))
    rng = np.random.default_rng(2)
    state = jnp.asarray(rand_u32(rng, 7, WORDS))
    pal = get_backend("pallas", IDEAL)
    got = np.asarray(pal.run_fused(prog, state))
    want = np.asarray(get_backend("oracle", IDEAL).run(prog, state))
    assert (got == want).all()
    assert not (got[5] == np.asarray(state)[5]).all()  # the store happened


def test_cost_only_program_fuses_to_identity():
    prog = Program()
    for _ in range(5):
        prog.emit("MAJ", x=5, n_act=8)
        prog.emit("NOT")
    assert build_schedule(prog).n_levels == 0
    state = jnp.asarray(rand_u32(np.random.default_rng(3), 4, 4))
    got = get_backend("pallas", IDEAL).run_fused(prog, state)
    assert (np.asarray(got) == np.asarray(state)).all()


# --------------------------------------------------- scheduler structure


def test_levels_respect_hazards_by_construction():
    """Every op's sources are written strictly before its level; no two
    same-level ops write one row."""
    rng = np.random.default_rng(4)
    prog = rand_program(rng, n_ops=30)
    levels = dependency_levels(prog)
    write_level: dict[int, int] = {}
    for i, ops in enumerate(levels):
        written_here: set[int] = set()
        for op in ops:
            for s in op.srcs:
                assert write_level.get(s, -1) < i  # RAW
            # WAW within a level: no row written by two *ops* (duplicate
            # dsts inside one op are legal — identical values).
            for d in set(op.dsts):
                assert d not in written_here
                written_here.add(d)
        for d in written_here:
            write_level[d] = i
    assert sum(len(ops) for ops in levels) == sum(
        1 for op in prog.ops
        if op.dsts and op.kind in ("MAJ", "NOT", "COPY", "MRC"))


def test_mixed_arity_level_is_one_dispatch():
    """MAJ3 + MAJ7 in one level fuse via 0/1 pair padding."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(8,))
    prog.emit("MAJ", x=7, n_act=8, srcs=(0, 1, 2, 3, 4, 5, 6), dsts=(9,))
    sched = build_schedule(prog)
    assert sched.n_levels == 1 and sched.n_dispatches() == 1
    rng = np.random.default_rng(5)
    state = jnp.asarray(rand_u32(rng, 10, WORDS))
    pal = get_backend("pallas", IDEAL)
    pal.reset_dispatches()
    got = np.asarray(pal.run_fused(prog, state))
    assert pal.dispatch_count == 1
    want = np.asarray(get_backend("oracle", IDEAL).run(prog, state))
    assert (got == want).all()


# ------------------------------------------- the acceptance dispatch gate


def test_adder32_dispatch_budget():
    """Fused 32-bit ripple-carry add: <= one dispatch per dependency
    level (vs one per MAJ gate per-op), bit-exact against the oracle."""
    rng = np.random.default_rng(6)
    a = rand_u32(rng, 32)
    b = rand_u32(rng, 32)
    cp = compile_elementwise("add", a, b, tier=5, n_act=32)
    sched = build_schedule(cp.program)

    pal = get_backend("pallas", IDEAL)
    pal.reset_dispatches()
    per_op = np.asarray(pal.run(cp.program, cp.state))
    per_op_dispatches = pal.dispatch_count

    pal.reset_dispatches()
    fused = np.asarray(pal.run_fused(cp.program, cp.state))
    fused_dispatches = pal.dispatch_count

    assert fused_dispatches <= sched.n_levels
    assert fused_dispatches < per_op_dispatches
    assert per_op_dispatches == sum(
        1 for op in cp.program.ops if op.kind == "MAJ")
    assert (fused == per_op).all()
    want = np.asarray(get_backend("oracle", IDEAL).run(cp.program, cp.state))
    assert (fused == want).all()
    assert (np.asarray(cp.outputs(fused)) == (a + b).astype(np.uint32)).all()


def test_fused_elementwise_matches_per_gate_recording():
    """The pallas fused elementwise path returns the same values and op
    histogram as the per-gate executors (and an addressed program)."""
    rng = np.random.default_rng(7)
    a, b = rand_u32(rng, 16), rand_u32(rng, 16)
    out_p, prog_p = get_backend("pallas", IDEAL).elementwise(
        "add", a, b, tier=5, n_act=32)
    out_o, prog_o = get_backend("oracle", IDEAL).elementwise(
        "add", a, b, tier=5, n_act=32)
    assert (np.asarray(out_p) == np.asarray(out_o)).all()
    assert prog_p.histogram() == prog_o.histogram()
    assert all(op.dsts for op in prog_p.ops)      # addressed
    assert not any(op.dsts for op in prog_o.ops)  # cost-only


# --------------------------------------------------------- helper hygiene


def test_no_silent_test_helpers():
    """Helper modules under tests/ (anything not matching test_*.py)
    must not define tests, or pytest would silently skip them — the
    failure mode tests/proptest.py had before it became _proptest.py."""
    here = os.path.dirname(__file__)
    for fname in sorted(os.listdir(here)):
        if not fname.endswith(".py") or fname.startswith("test_"):
            continue
        with open(os.path.join(here, fname)) as f:
            src = f.read()
        assert "\ndef test_" not in src and not src.startswith("def test_"), \
            (f"{fname} defines tests but is not collected by pytest; "
             f"rename it to test_*.py or move the tests out")
