"""Roofline machinery: collective parser, composition, analytic FLOPs."""

import pytest

from repro.configs.registry import SHAPES, get_config
from repro.launch import roofline as rl

HLO_SAMPLE = """
  %ag = bf16[16,4096,256]{2,1,0} all-gather(%x), replica_groups=..., metadata={op_name="jit(step)/jvp/while/body/dot" }
  %ar = f32[4096,4096]{1,0} all-reduce(%y), metadata={op_name="jit(step)/outer/dot"}
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), metadata={op_name="jit(step)/opt"}
  %a2a = (f32[64,64]{1,0}) all-to-all(%w), metadata={op_name="jit(step)/while/body/moe"}
  %cp = u32[1024]{0} collective-permute(%q), metadata={op_name="jit(step)/ring"}
"""


def test_collective_parser_bytes_and_kinds():
    stats = rl.collective_bytes(HLO_SAMPLE, loop_multiplier=10)
    # all-gather inside while: 16*4096*256*2 bytes * 10
    assert stats.bytes_by_kind["all-gather"] == 16 * 4096 * 256 * 2 * 10
    # all-reduce outside while: 2x operand bytes
    assert stats.bytes_by_kind["all-reduce"] == 4096 * 4096 * 4 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == 8 * 128 * 2
    assert stats.bytes_by_kind["all-to-all"] == 64 * 64 * 4 * 10
    assert stats.bytes_by_kind["collective-permute"] == 1024 * 4
    assert stats.n_ops == 5
    assert stats.dominant == "all-gather"


def test_composition_transformer():
    cfg = get_config("chatglm3-6b")
    pts = {0: rl.CostPoint(flops=100.0, bytes_accessed=10.0),
           1: rl.CostPoint(flops=150.0, bytes_accessed=14.0)}
    c = rl.compose(cfg, pts)
    assert c.flops == 100 + 28 * 50
    assert c.bytes_accessed == 10 + 28 * 4


def test_composition_hybrid():
    cfg = get_config("zamba2-1.2b")  # 38 layers, attn every 6
    pts = {0: rl.CostPoint(10.0, 1.0),
           6: rl.CostPoint(10.0 + 5.0 + 3.0, 1.0 + 0.5 + 0.3),
           7: rl.CostPoint(10.0 + 5.0 + 3.0 + 5.0, 1.0 + 0.5 + 0.3 + 0.5)}
    c = rl.compose(cfg, pts)
    # body=5, attn=3, n_full=6 -> 10 + 38*5 + 6*3
    assert c.flops == pytest.approx(10 + 38 * 5 + 6 * 3)


def test_compose_seq_linear():
    pts = {64: rl.CostPoint(100.0, 50.0), 128: rl.CostPoint(164.0, 82.0)}
    c = rl.compose_seq(4096, pts)
    assert c.flops == pytest.approx(100 + (4096 - 64) * 1.0)


def test_model_flops_scales():
    cfg = get_config("chatglm3-6b")
    f_train = rl.model_flops(cfg, SHAPES["train_4k"])
    f_prefill = rl.model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = rl.model_flops(cfg, SHAPES["decode_32k"])
    # training is fwd+bwd on 1M tokens; prefill is fwd on 1M tokens but
    # carries a 32k^2 attention term, so the ratio is ~2 rather than ~3
    assert f_train > 1.5 * f_prefill
    assert f_decode < f_prefill / 100
    # ~6ND sanity: chatglm3 ~6.2B params, 1M tokens
    n = cfg.n_params()
    assert f_train == pytest.approx(6 * n * 1048576, rel=0.25)


def test_moe_uses_active_params():
    cfg = get_config("mixtral-8x22b")
    assert cfg.n_active_params() < cfg.n_params() / 2.5
    f = rl.model_flops(cfg, SHAPES["train_4k"])
    assert f < 6 * cfg.n_params() * 1048576


def test_report_bottleneck_and_fraction():
    r = rl.RooflineReport(
        arch="a", shape="s", mesh="16x16", n_chips=256,
        flops_per_chip=1e12, bytes_per_chip=1e9, coll_bytes_per_chip=1e9,
        coll_dominant_kind="all-gather", model_flops_global=200e12,
        mem_per_chip_bytes=8 * 2**30)
    assert r.t_compute == pytest.approx(1e12 / rl.PEAK_FLOPS)
    assert r.bottleneck == "collective"
    assert 0 < r.roofline_fraction < 1
    row = r.row()
    assert row["bottleneck"] == "collective"


def test_long500k_gating():
    from repro.configs.shapes import shape_applicable

    ok, _ = shape_applicable(get_config("mixtral-8x22b"), SHAPES["long_500k"])
    assert ok  # SWA
    ok, why = shape_applicable(get_config("gemma-7b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = shape_applicable(get_config("xlstm-125m"), SHAPES["long_500k"])
    assert ok
    ok, _ = shape_applicable(get_config("zamba2-1.2b"), SHAPES["long_500k"])
    assert ok
