"""Session-layer tests: typed row allocation, build-time validation,
the content-hashed compile cache, scoped dispatch counters, and the
Program JSON round-trip.

The load-bearing claims: (1) a `DramSession` executes any valid
addressed Program bit-identically to its raw backend (it only *adds*
validation and schedule caching); (2) malformed programs fail at build
time with subarray context, never inside a kernel; (3) a repeated
program is a schedule-cache hit; (4) dispatch counts read through
`count_dispatches` scopes cannot leak between workloads.
"""

import numpy as np
import pytest

from _proptest import rand_u32, sweep
from repro.backends import ExecutionContext, get_backend, resolve_backend
from repro.compile import build_schedule
from repro.pud.isa import Program
from repro.session import (CompileCache, DramSession, PlaneGroup,
                           ProgramValidationError, RowAllocationError,
                           SessionError, program_key)
from test_compile_differential import ROWS, WORDS, rand_program

IDEAL = ExecutionContext(ideal=True)


def valid_rand_program(rng, rows: int = ROWS, n_ops: int = 10) -> Program:
    """A hazard-heavy random program that passes session validation
    (per-op duplicate destinations deduped; everything else is legal —
    aliasing, rewrites, dead stores, cost-only ops all stay)."""
    prog = Program()
    for op in rand_program(rng, rows=rows, n_ops=n_ops).ops:
        dsts = tuple(dict.fromkeys(op.dsts))
        prog.emit(op.kind, x=op.x, n_act=op.n_act, tag=op.tag,
                  srcs=op.srcs, dsts=dsts)
    return prog


# ------------------------------------------------- Program JSON round-trip


@sweep(12)
def test_program_json_roundtrip(rng):
    """to_json -> from_json is the identity on random op streams
    (addresses, arities, cost-only ops, tags) and is itself stable."""
    prog = rand_program(rng, n_ops=int(rng.integers(0, 25)))
    text = prog.to_json()
    back = Program.from_json(text)
    assert back.ops == prog.ops
    assert back.to_json() == text


def test_program_json_roundtrip_edges():
    prog = Program()
    assert Program.from_json(prog.to_json()).ops == []  # empty program
    prog.emit("MAJ", x=9, n_act=32, tag="weird/tag[αβ]\"quoted\"",
              srcs=tuple(range(9)), dsts=(9, 10))
    prog.emit("WR", tag="")  # cost-only, no addresses
    back = Program.from_json(prog.to_json())
    assert back.ops == prog.ops
    assert back.ops[0].tag == "weird/tag[αβ]\"quoted\""


# ------------------------------------------------------- session execution


@sweep(8)
def test_session_matches_backend(rng):
    """run/run_fused through a session == the raw backend, both paths."""
    prog = valid_rand_program(rng)
    state = rand_u32(rng, ROWS, WORDS)
    want = np.asarray(get_backend("oracle", IDEAL).run(prog, state))
    for name in ("oracle", "pallas"):
        sess = DramSession(name, IDEAL)
        assert (np.asarray(sess.run(prog, state)) == want).all()
        assert (np.asarray(sess.run_fused(prog, state)) == want).all()


def test_run_fused_accepts_prebuilt_schedule():
    rng = np.random.default_rng(3)
    prog = valid_rand_program(rng)
    state = rand_u32(rng, ROWS, WORDS)
    be = get_backend("pallas", IDEAL)
    want = np.asarray(be.run(prog, state))
    got = be.run_fused(prog, state, sched=build_schedule(prog))
    assert (np.asarray(got) == want).all()


# ----------------------------------------------------------- compile cache


def test_compile_cache_hit_on_repeat():
    rng = np.random.default_rng(0)
    sess = DramSession("pallas", IDEAL)
    prog = valid_rand_program(rng)
    state = rand_u32(rng, ROWS, WORDS)
    first = np.asarray(sess.run_fused(prog, state))
    assert (sess.cache.stats.hits, sess.cache.stats.misses) == (0, 1)
    second = np.asarray(sess.run_fused(prog, state))
    assert (sess.cache.stats.hits, sess.cache.stats.misses) == (1, 1)
    assert (first == second).all()
    # schedule_for returns the *same* cached object, no re-scheduling
    assert sess.schedule_for(prog) is sess.schedule_for(prog)


def test_program_key_ignores_tags_only():
    a, b, c = Program(), Program(), Program()
    a.emit("MAJ", x=3, n_act=4, tag="left", srcs=(0, 1, 2), dsts=(3,))
    b.emit("MAJ", x=3, n_act=4, tag="right", srcs=(0, 1, 2), dsts=(3,))
    c.emit("MAJ", x=3, n_act=4, tag="left", srcs=(0, 1, 2), dsts=(4,))
    assert program_key(a) == program_key(b)   # provenance never executes
    assert program_key(a) != program_key(c)   # addresses do


def test_shared_cache_across_sessions():
    """Schedules are content-pure: the sweep runner's per-chunk sessions
    share one cache and the second chunk-shaped program is a hit."""
    rng = np.random.default_rng(1)
    cache = CompileCache()
    prog = valid_rand_program(rng)
    state = rand_u32(rng, ROWS, WORDS)
    DramSession("pallas", IDEAL, cache=cache).run_fused(prog, state)
    DramSession("pallas", IDEAL, cache=cache).run_fused(prog, state)
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_cache_eviction_bounded():
    cache = CompileCache(maxsize=2)
    for d in (3, 4, 5, 6):
        p = Program()
        p.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(d,))
        cache.schedule_for(p)
    assert len(cache) == 2
    assert cache.stats.misses == 4


def test_elementwise_through_session_caches():
    sess = DramSession("pallas", IDEAL)
    a = np.arange(8, dtype=np.uint32)
    b = np.arange(8, dtype=np.uint32) * 3 + 1
    out1, _ = sess.elementwise("add", a, b, tier=5, n_act=32)
    out2, _ = sess.elementwise("add", a, b, tier=5, n_act=32)
    assert (np.asarray(out1) == (a + b).astype(np.uint32)).all()
    assert (np.asarray(out2) == (a + b).astype(np.uint32)).all()
    assert sess.cache.stats.hits >= 1


# ------------------------------------------------------ typed construction


def test_builder_program_runs_everywhere():
    rng = np.random.default_rng(2)
    sess = DramSession("oracle", IDEAL)
    b = sess.program(rows=16, name="typed-demo")
    ins = b.input(rand_u32(rng, 5, 8))
    vote = b.maj(*list(ins), tag="vote")
    inv = b.not_(vote, tag="inv")
    fan = b.mrc(inv, 4, tag="fan")
    prog, state = b.build(), b.initial_state()
    assert prog.n_rows() == 11 and len(fan) == 4
    want = np.asarray(sess.run(prog, state))
    for name in ("oracle", "sim", "pallas"):
        got = np.asarray(DramSession(name, IDEAL).run_fused(prog, state))
        assert (got == want).all(), name
    # builder.run() is the same execution, compile-cached
    assert (np.asarray(b.run()) == want).all()


def test_builder_input_binding_positions():
    sess = DramSession("oracle", IDEAL)
    b = sess.program()
    scratch = b.alloc_rows(2, tag="scratch")
    vals = np.arange(16, dtype=np.uint32).reshape(2, 8)
    bound = b.input(vals)
    state = b.initial_state()
    assert state.shape == (4, 8)
    assert (state[list(scratch.indices)] == 0).all()
    assert (state[list(bound.indices)] == vals).all()


def test_allocator_capacity_error_names_subarray():
    sess = DramSession("oracle", IDEAL)
    b = sess.program(rows=4, name="tiny")
    b.alloc_rows(3)
    with pytest.raises(RowAllocationError, match=r"tiny.*3/4 in use"):
        b.alloc_rows(2, tag="overflow")


def test_builder_rejects_even_arity():
    b = DramSession("oracle", IDEAL).program(name="arity")
    rows = b.alloc_rows(4)
    with pytest.raises(SessionError, match="odd >= 3"):
        b.maj(rows[0], rows[1], rows[2], rows[3])


def test_builder_rejects_foreign_rows():
    sess = DramSession("oracle", IDEAL)
    mine, other = sess.program(name="mine"), sess.program(name="other")
    r = other.alloc_rows(3)
    with pytest.raises(SessionError, match="different program"):
        mine.maj(r[0], r[1], r[2])


def test_builder_rejects_duplicate_mrc_destinations():
    b = DramSession("oracle", IDEAL).program(name="dup")
    src = b.alloc_row()
    d = b.alloc_row(tag="dst")
    with pytest.raises(SessionError, match="more than once"):
        b.mrc(src, PlaneGroup((d, d)))


def test_builder_allows_input_replication():
    """Duplicate MAJ *operands* are the paper's replication identity."""
    b = DramSession("oracle", IDEAL).program()
    vals = b.input(np.array([[0xF0F0F0F0], [0x00FF00FF], [0xFFFF0000]],
                            np.uint32))
    b.maj(vals[0], vals[1], vals[2], vals[2], vals[2], tag="maj5-rep")
    final = np.asarray(b.run())
    assert final[3, 0] == 0xFFFF0000  # replicated operand dominates


# -------------------------------------------------- arena row free-list


def test_allocator_free_list_reuse():
    """Arenas (serve admission) free completed reservations; freed
    indices are reused, so a bounded budget admits an endless stream."""
    from repro.session.rows import RowAllocator

    a = RowAllocator(capacity=4, name="arena")
    first = a.alloc(3, tag="req0")
    assert (a.in_use, a.n_rows) == (3, 3)
    a.free(first)
    assert (a.in_use, a.n_rows) == (0, 3)   # high-water mark sticks
    again = a.alloc(4, tag="req1")          # 3 reused + 1 fresh
    assert a.in_use == 4
    assert set(again.indices) == {0, 1, 2, 3}


def test_allocator_free_validates_ownership_and_double_free():
    from repro.session.rows import RowAllocator

    a, other = RowAllocator(8, name="a"), RowAllocator(8, name="other")
    mine = a.alloc(2)
    theirs = other.alloc(1)
    with pytest.raises(RowAllocationError, match="not allocated here"):
        a.free(theirs)
    a.free(mine)
    with pytest.raises(RowAllocationError, match="double free"):
        a.free(mine[0])


def test_allocator_capacity_checks_in_use_not_high_water():
    from repro.session.rows import RowAllocator

    a = RowAllocator(capacity=2, name="tight")
    for _ in range(5):                      # 5x the budget, sequentially
        g = a.alloc(2)
        a.free(g)
    assert a.in_use == 0 and a.n_rows == 2  # never grew past the budget
    a.alloc(2)
    with pytest.raises(RowAllocationError, match="2/2 in use"):
        a.alloc(1)


def test_concurrent_cache_single_build():
    """Thread-safe cache: concurrent same-key lookups build once."""
    from concurrent.futures import ThreadPoolExecutor

    rng = np.random.default_rng(7)
    cache = CompileCache()
    prog = valid_rand_program(rng)
    with ThreadPoolExecutor(max_workers=6) as pool:
        scheds = list(pool.map(lambda _: cache.schedule_for(prog),
                               range(6)))
    assert (cache.stats.hits, cache.stats.misses) == (5, 1)
    assert all(s is scheds[0] for s in scheds)


# --------------------------------------------------- build-time validation


def test_session_rejects_out_of_range_rows():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, tag="bad", srcs=(0, 1, 7), dsts=(2,))
    sess = DramSession("pallas", IDEAL)
    state = np.zeros((4, 8), np.uint32)
    with pytest.raises(ProgramValidationError,
                       match=r"source row 7.*4-row subarray"):
        sess.run_fused(prog, state)
    with pytest.raises(ProgramValidationError, match="4-row subarray"):
        sess.run(prog, state)


def test_session_rejects_duplicate_destinations():
    prog = Program()
    prog.emit("MRC", n_act=4, srcs=(0,), dsts=(1, 2, 1))
    with pytest.raises(ProgramValidationError, match=r"\[1\] more than"):
        DramSession("oracle", IDEAL).run(prog, np.zeros((3, 8), np.uint32))


def test_session_rejects_malformed_maj():
    prog = Program()
    prog.emit("MAJ", x=5, n_act=8, srcs=(0, 1, 2), dsts=(3,))
    with pytest.raises(ProgramValidationError, match="MAJ5 carries 3"):
        DramSession("oracle", IDEAL).run(prog, np.zeros((4, 8), np.uint32))


def test_cost_only_ops_exempt_from_validation():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4)   # cost-only: no addresses at all
    prog.emit("WR")
    sess = DramSession("oracle", IDEAL)
    state = np.ones((2, 4), np.uint32)
    assert (np.asarray(sess.run_fused(prog, state)) == state).all()


# -------------------------------------------------------- dispatch scopes


def test_dispatch_scope_counts_window_only():
    rng = np.random.default_rng(4)
    sess = DramSession("pallas", IDEAL)
    planes = rand_u32(rng, 3, 2, 16)
    sess.majx(planes)  # outside any scope: must not leak in
    with sess.count_dispatches() as scope:
        sess.majx(planes)
        sess.majx(planes)
    assert scope.count == 2
    with sess.count_dispatches() as scope2:
        sess.majx(planes)
    assert scope2.count == 1 and scope.count == 2


def test_dispatch_scope_frozen_after_exit():
    rng = np.random.default_rng(5)
    sess = DramSession("pallas", IDEAL)
    planes = rand_u32(rng, 3, 2, 16)
    with sess.count_dispatches() as scope:
        sess.majx(planes)
    sess.majx(planes)          # after exit: scope must not move
    assert scope.count == 1


def test_dispatch_scopes_nest():
    rng = np.random.default_rng(6)
    be = get_backend("pallas", IDEAL)
    planes = rand_u32(rng, 3, 2, 16)
    with be.count_dispatches() as outer:
        be.majx(planes)
        with be.count_dispatches() as inner:
            be.majx(planes)
        assert inner.count == 1
        be.majx(planes)
    assert outer.count == 3


# --------------------------------------------------------- resolution


def test_resolve_backend_passthrough_and_mismatch():
    be = get_backend("oracle", IDEAL)
    assert resolve_backend(be) is be
    assert resolve_backend(be, IDEAL) is be
    with pytest.raises(ValueError, match="already carries"):
        resolve_backend(be, ExecutionContext(ideal=False))
    sess = DramSession(be)      # sessions accept prebuilt instances
    assert sess.backend is be and sess.ctx == IDEAL
