"""The unified CostModel: one pricing authority for latency, power, and
TPU constants.

Load-bearing claims: (1) the Fig. 5 / Obs 5 power anchors are pinned
(32-row SiMRA draws 21.19 % less than REF) and W x ns = nJ exactly;
(2) the TPU machine constants have ONE source — ``repro.pud.offload``
and ``repro.launch.roofline`` re-export ``repro.core.costmodel``'s
values, and ``repro.pud.latency`` is a pure shim; (3) Program costing
delegates to COST bit-identically, preserving the historical retry
semantics (NOT/COPY energy prices one clean issue while its latency is
retry-aware); (4) offload decisions carry an energy verdict next to
the latency verdict; (5) backend dispatch scopes meter energy — zero
on the oracle, positive on sim, and ordered megakernel <= fused <=
per-op on pallas; (6) the serve layer threads energy into its SLO
snapshots and the sync ``serve()`` path honors ``tick_window_s``.
"""

import os
import time

import numpy as np
import pytest

from _proptest import rand_u32
from repro.core import calibration as cal
from repro.core import power as pw
from repro.core.costmodel import COST, LAT, CostModel, majx_issue_ns
from repro.core.errormodel import ErrorModel
from repro.backends import ExecutionContext, get_backend
from repro.pud.isa import Program
from repro.serve import PudService, ServiceConfig
from repro.session import DramSession
from test_serve_service import heal_req

REPO = os.path.join(os.path.dirname(__file__), "..")
IDEAL = ExecutionContext(ideal=True)


# --------------------------------------------------- Fig. 5 / Obs 5 anchors


def test_obs5_simra32_vs_ref_pinned():
    """The paper's one pinned power relationship: 32-row SiMRA draws
    21.19 % less than REF (Obs 5)."""
    want = pw.STANDARD_POWER_W["REF"] * (1.0 + cal.SIMRA32_POWER_VS_REF)
    assert pw.simra_power_w(32) == pytest.approx(want, rel=1e-12)
    assert cal.SIMRA32_POWER_VS_REF == -0.2119
    assert COST.simra_power_w(32) == pw.simra_power_w(32)


def test_simra_power_monotonic_in_n_act():
    """Wordline/CSL driver load grows with asserted wordlines: power is
    strictly increasing over the measured activation counts."""
    series = [pw.simra_power_w(n) for n in cal.N_ACT_LEVELS]
    assert all(a < b for a, b in zip(series, series[1:]))
    assert pw.simra_power_w(2) > pw.STANDARD_POWER_W["ACT_PRE"]


def test_energy_is_watts_times_ns():
    """1 W held for 1 ns is exactly 1 nJ."""
    assert pw.energy_nj("REF", 12.5) == pytest.approx(1.80 * 12.5)
    assert pw.energy_nj("ACT_PRE", 1.0) == pw.STANDARD_POWER_W["ACT_PRE"]
    # CostModel's duration path is the same table.
    assert COST.energy_nj("REF", 12.5) == pw.energy_nj("REF", 12.5)
    assert COST.power_w("WR") == pw.STANDARD_POWER_W["WR"]


def test_energy_unknown_series_names_valid_ops():
    """The bugfix: a clear ValueError (not a bare KeyError) listing the
    calibrated series."""
    with pytest.raises(ValueError, match="valid ops") as ei:
        pw.energy_nj("SIMRA_3", 10.0)
    assert "SIMRA_32" in str(ei.value) and "REF" in str(ei.value)
    with pytest.raises(ValueError, match="valid ops"):
        COST.power_w("BOGUS")


def test_power_table_cached_and_copy_safe():
    """The table is built once but handed out as fresh copies: a caller
    mutating its copy cannot corrupt later pricing."""
    t1 = pw.power_table()
    t1["REF"] = 0.0
    t1["EVIL"] = 99.0
    t2 = pw.power_table()
    assert t2["REF"] == pw.STANDARD_POWER_W["REF"]
    assert "EVIL" not in t2
    assert pw.energy_nj("REF", 1.0) == pw.STANDARD_POWER_W["REF"]
    assert set(f"SIMRA_{n}" for n in cal.N_ACT_LEVELS) <= set(t2)


# ------------------------------------------------- single-source constants


def test_tpu_constants_single_source():
    """offload and roofline must re-export COST's values, never carry
    their own copies."""
    from repro.launch import roofline
    from repro.pud import offload

    assert offload.PEAK_FLOPS == roofline.PEAK_FLOPS == COST.peak_flops
    assert offload.HBM_BYTES_PER_S == roofline.HBM_BW == COST.hbm_bytes_per_s
    assert offload.KERNEL_LAUNCH_NS == COST.kernel_launch_ns
    assert roofline.ICI_BW == COST.ici_bytes_per_s
    assert COST.dispatch_overhead(3) == 3 * COST.kernel_launch_ns


def test_latency_module_is_a_shim():
    """repro.pud.latency re-exports the costmodel objects unchanged."""
    from repro.pud import latency

    assert latency.LAT is LAT
    assert latency.majx_issue_ns is majx_issue_ns
    assert latency.ROW_BITS == 65536


# ----------------------------------------------------- per-op / per-program


def test_unknown_op_kind_raises():
    with pytest.raises(ValueError, match="unknown op kind"):
        COST.latency_ns("XOR")
    with pytest.raises(ValueError, match="unknown op kind"):
        COST.energy_nj("XOR")


def test_maj_energy_is_simra_power_times_retry_latency():
    em = ErrorModel("H")
    t = COST.latency_ns("MAJ", x=3, n_act=32, errors=em)
    assert t > majx_issue_ns(3, 32)  # retries lengthen the issue
    want = pw.simra_power_w(32) * t
    assert COST.energy_nj("MAJ", x=3, n_act=32, errors=em) == \
        pytest.approx(want, rel=1e-12)


def test_support_op_energy_prices_one_clean_issue():
    """Historical §8 semantics: NOT/COPY *latency* is retry-aware but
    their *energy* charges a single clean RowClone at ACT+PRE power."""
    em = ErrorModel("H")
    clean = pw.energy_nj("ACT_PRE", LAT.rowclone)
    assert COST.energy_nj("NOT", errors=em) == pytest.approx(clean)
    assert COST.energy_nj("COPY") == pytest.approx(clean)
    assert COST.latency_ns("COPY", errors=em) > COST.latency_ns("COPY")


def test_program_costing_delegates_to_cost():
    prog = Program()
    prog.emit("WR", dsts=(0,))
    prog.emit("MAJ", x=3, n_act=32, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("MRC", n_act=8, srcs=(3,), dsts=tuple(range(4, 11)))
    prog.emit("NOT", srcs=(3,), dsts=(11,))
    prog.emit("FRAC", dsts=(12,))
    prog.emit("RD", srcs=(3,))
    em = ErrorModel("H")
    assert prog.latency_ns(em) == \
        pytest.approx(COST.program_latency_ns(prog, em), rel=1e-12)
    assert prog.energy_nj(em) == \
        pytest.approx(COST.program_energy_nj(prog, em), rel=1e-12)
    per_op = sum(COST.energy_nj(op.kind, x=op.x, n_act=op.n_act, errors=em)
                 for op in prog.ops)
    assert prog.energy_nj(em) == pytest.approx(per_op, rel=1e-12)
    assert prog.energy_nj(em) > 0


def test_costmodel_replace_for_what_if():
    """Frozen dataclass: what-if variants via dataclasses.replace."""
    import dataclasses

    slow = dataclasses.replace(COST, kernel_launch_ns=4000.0)
    assert slow.dispatch_energy_nj(1) == 2 * COST.dispatch_energy_nj(1)
    assert isinstance(slow, CostModel)
    with pytest.raises(dataclasses.FrozenInstanceError):
        COST.kernel_launch_ns = 0.0


# ------------------------------------------------- offload energy verdicts


def test_offload_decision_carries_energy():
    from repro.pud.offload import plan_broadcast, plan_vote

    d = plan_vote(1 << 20)
    assert d.tpu_energy_nj > 0 and d.pud_energy_nj > 0
    assert d.winner_energy in ("pud", "tpu")
    assert d.energy_savings == \
        pytest.approx(d.tpu_energy_nj / d.pud_energy_nj)
    b = plan_broadcast(1 << 20, fanout=31)
    assert b.winner_energy in ("pud", "tpu")
    assert b.pud_energy_nj > 0


# ----------------------------------------------- backend energy metering


def test_dispatch_scope_energy_oracle_zero_sim_positive():
    rng = np.random.default_rng(0)
    planes = rand_u32(rng, 3, 4, 8)
    oracle = get_backend("oracle", IDEAL)
    with oracle.count_dispatches() as scope:
        oracle.majx(planes, n_act=32)
    assert scope.energy_nj == 0.0

    sim = get_backend("sim", IDEAL)
    with sim.count_dispatches() as scope:
        sim.majx(planes, n_act=32)
    assert scope.energy_nj > 0.0
    frozen = scope.energy_nj
    sim.majx(planes, n_act=32)  # outside the window
    assert scope.energy_nj == frozen
    sim.reset_dispatches()
    assert sim.energy_nj_total == 0.0


def test_pallas_energy_ordering_mega_fused_per_op():
    """Fusion's joule story mirrors its dispatch story: launch energy
    amortizes, so megakernel <= fused <= per-op."""
    sess = DramSession("pallas")
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2**32, 8, dtype=np.uint32)
    b = rng.integers(0, 2**32, 8, dtype=np.uint32)
    _, prog = sess.elementwise("add", a, b, tier=5, n_act=32)
    state = np.zeros((prog.n_rows(), 1), np.uint32)
    nj = {}
    for mode, run in (
            ("per_op", lambda: sess.run(prog, state)),
            ("fused", lambda: sess.run_fused(prog, state)),
            ("megakernel", lambda: sess.run_fused(
                prog, state, mode="megakernel"))):
        with sess.count_dispatches() as scope:
            run()
        nj[mode] = scope.energy_nj
        assert scope.energy_nj > 0
    assert nj["megakernel"] <= nj["fused"] <= nj["per_op"]
    assert nj["megakernel"] < nj["per_op"]


# ------------------------------------------------------- serve-layer energy


def test_slo_snapshot_carries_energy():
    svc = PudService(ServiceConfig(backend="sim"))
    rng = np.random.default_rng(2)
    svc.serve([heal_req(rng)])
    snap = svc.snapshot()
    assert snap.energy_nj > 0.0
    assert snap.to_dict()["energy_nj"] == snap.energy_nj
    svc.reset_slo()
    assert svc.snapshot().energy_nj == 0.0


def test_sync_serve_honors_tick_window():
    """The bugfix: tick_window_s used to be honored only on the asyncio
    path — the sync serve() must pay the coalescing wait too."""
    window = 0.05
    svc = PudService(ServiceConfig(backend="oracle", tick_window_s=window))
    rng = np.random.default_rng(3)
    t0 = time.monotonic()
    svc.serve([heal_req(rng)])
    assert time.monotonic() - t0 >= window


# -------------------------------------------------- bench schema contracts


def test_bench_schemas_carry_energy_columns():
    """Both bench writers are on the energy-carrying schema revisions
    (the gates in scripts/ci.sh and scripts/check_docs.py assume so)."""
    with open(os.path.join(REPO, "benchmarks", "bench.py")) as f:
        fused_src = f.read()
    with open(os.path.join(REPO, "benchmarks", "serve_bench.py")) as f:
        serve_src = f.read()
    assert 'SCHEMA = "repro-bench/fused-v4"' in fused_src
    assert 'SCHEMA = "repro-bench/serve-v2"' in serve_src
    assert '"energy_nj"' in fused_src
    assert '"energy_nj"' in serve_src
    assert '"energy_per_req_nj"' in serve_src
    assert '"tick_window_s"' in serve_src
