"""Backend parity: oracle / sim (ideal) / pallas (interpret) must agree
bit-exactly on every op class, and sim's calibrated error model must
reproduce the paper's success-rate ordering."""

import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import rand_u32
from repro.backends import (ExecutionContext, available_backends,
                            get_backend, register_backend)
from repro.backends.base import Backend
from repro.pud.isa import Program

BACKENDS = ("oracle", "sim", "pallas")
IDEAL = ExecutionContext(ideal=True)


def _all(ctx=IDEAL):
    return {name: get_backend(name, ctx) for name in BACKENDS}


# ---------------------------------------------------------------- registry


def test_registry_lists_all_three():
    assert set(BACKENDS) <= set(available_backends())


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("cuda")


def test_registry_accepts_new_backend():
    from repro.backends import _REGISTRY

    before = dict(_REGISTRY)
    try:
        @register_backend("oracle2")
        class Oracle2(get_backend("oracle").__class__):
            pass

        assert "oracle2" in available_backends()
        assert isinstance(get_backend("oracle2"), Backend)
    finally:  # don't leak the test backend into the process registry
        _REGISTRY.clear()
        _REGISTRY.update(before)


def test_capabilities_shape():
    caps = {n: get_backend(n, IDEAL).capabilities() for n in BACKENDS}
    assert caps["sim"].device_model and not caps["oracle"].device_model
    assert caps["pallas"].accelerated and caps["pallas"].native_batch
    assert not caps["sim"].stochastic  # ideal ctx
    assert get_backend("sim").capabilities().stochastic


# ------------------------------------------------------------- MAJX parity


@pytest.mark.parametrize("x", [3, 5, 7, 9])
def test_majx_parity(x):
    rng = np.random.default_rng(x)
    planes = jnp.asarray(rand_u32(rng, x, 4, 40))
    outs = {n: np.asarray(be.majx(planes, n_act=32))
            for n, be in _all().items()}
    assert (outs["oracle"] == outs["sim"]).all()
    assert (outs["oracle"] == outs["pallas"]).all()


def test_majx_minimum_activation_parity():
    """n_act at the minimum reachable level (no replication)."""
    rng = np.random.default_rng(0)
    planes = jnp.asarray(rand_u32(rng, 3, 16))
    outs = {n: np.asarray(be.majx(planes, n_act=4))
            for n, be in _all().items()}
    assert (outs["oracle"] == outs["sim"]).all()
    assert (outs["oracle"] == outs["pallas"]).all()


def test_majx_batch_matches_loop():
    rng = np.random.default_rng(1)
    batch = jnp.asarray(rand_u32(rng, 3, 5, 8, 128))
    pal = get_backend("pallas", IDEAL)
    ora = get_backend("oracle", IDEAL)
    got = np.asarray(pal.majx_batch(batch))
    want = np.stack([np.asarray(ora.majx(p)) for p in batch])
    assert (got == want).all()


# ----------------------------------------------------- Multi-RowCopy parity


@pytest.mark.parametrize("n_dst", [1, 7, 15, 31])
def test_rowcopy_parity(n_dst):
    rng = np.random.default_rng(n_dst)
    src = jnp.asarray(rand_u32(rng, 24))
    outs = {n: np.asarray(be.rowcopy(src, n_dst))
            for n, be in _all().items()}
    assert outs["oracle"].shape == (n_dst, 24)
    assert (outs["oracle"] == outs["sim"]).all()
    assert (outs["oracle"] == outs["pallas"]).all()


def test_rowcopy_2d_parity():
    rng = np.random.default_rng(9)
    src = jnp.asarray(rand_u32(rng, 3, 40))
    outs = {n: np.asarray(be.rowcopy(src, 7)) for n, be in _all().items()}
    assert outs["oracle"].shape == (7, 3, 40)
    assert (outs["oracle"] == outs["sim"]).all()
    assert (outs["oracle"] == outs["pallas"]).all()


# ------------------------------------------------------------ mismatch parity


def test_mismatch_parity():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rand_u32(rng, 700))
    b = jnp.asarray(rand_u32(rng, 700))
    counts = {n: int(be.mismatch(a, b)) for n, be in _all().items()}
    assert counts["oracle"] == counts["sim"] == counts["pallas"]
    for be in _all().values():
        assert int(be.mismatch(a, a)) == 0
        assert be.success_rate(a, a) == 1.0


# ----------------------------------------------------- program execution


def _demo_program() -> Program:
    p = Program()
    p.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    p.emit("NOT", srcs=(3,), dsts=(4,))
    p.emit("COPY", srcs=(4,), dsts=(5,))
    p.emit("MRC", n_act=8, srcs=(5,), dsts=tuple(range(6, 13)))
    p.emit("MAJ", x=5, n_act=32, srcs=(0, 1, 2, 3, 4), dsts=(13, 14))
    p.emit("FRAC", dsts=(15,))
    return p


def test_program_execution_parity():
    rng = np.random.default_rng(3)
    prog = _demo_program()
    state = jnp.asarray(rand_u32(rng, prog.n_rows(), 8))
    finals = {n: np.asarray(be.run(prog, state)) for n, be in _all().items()}
    assert (finals["oracle"] == finals["sim"]).all()
    assert (finals["oracle"] == finals["pallas"]).all()


def test_program_semantics_against_closed_form():
    rng = np.random.default_rng(4)
    prog = _demo_program()
    state0 = np.asarray(rand_u32(rng, prog.n_rows(), 8))
    out = np.asarray(get_backend("oracle").run(prog, jnp.asarray(state0)))
    maj3 = ((state0[0] & state0[1]) | (state0[1] & state0[2])
            | (state0[0] & state0[2]))
    assert (out[3] == maj3).all()
    assert (out[4] == ~maj3).all()
    assert (out[5] == ~maj3).all()
    for d in range(6, 13):
        assert (out[d] == ~maj3).all()
    assert (out[15] == state0[15]).all()  # FRAC: value-wise untouched


def test_cost_only_program_is_noop():
    """Programs recorded purely for costing execute as identity."""
    rng = np.random.default_rng(5)
    be = get_backend("oracle")
    _, prog = be.elementwise("xor", rand_u32(rng, 8), rand_u32(rng, 8))
    state = jnp.asarray(rand_u32(rng, 4, 4))
    assert (np.asarray(be.run(prog, state)) == np.asarray(state)).all()


# ---------------------------------------------- compiled §8.1 arithmetic


@pytest.mark.parametrize("op,ref", [
    ("add", lambda a, b: (a + b).astype(np.uint32)),
    ("xor", lambda a, b: a ^ b),
    ("and", lambda a, b: a & b),
])
def test_elementwise_parity(op, ref):
    rng = np.random.default_rng(6)
    a = rand_u32(rng, 16)
    b = rand_u32(rng, 16)
    progs = {}
    for name, be in _all().items():
        out, prog = be.elementwise(op, a, b, tier=5, n_act=32)
        assert (np.asarray(out) == ref(a, b)).all(), name
        progs[name] = prog.histogram()
    # the recorded Program is backend-invariant
    assert progs["oracle"] == progs["sim"] == progs["pallas"]


# -------------------------------------------------- calibrated error model


def test_sim_error_model_replication_ordering():
    """Obs 6: 32-row MAJ3 success > 4-row MAJ3 success (input replication
    strengthens the charge-share margin)."""
    rng = np.random.default_rng(7)
    planes = jnp.asarray(rand_u32(rng, 3, 256))
    want = get_backend("oracle").majx(planes)
    rates = {}
    for n_act in (4, 32):
        sim = get_backend("sim", ExecutionContext(seed=11))
        rates[n_act] = sim.success_rate(sim.majx(planes, n_act=n_act), want)
    assert rates[32] > rates[4]
    em = ExecutionContext().error_model
    assert rates[4] == pytest.approx(em.majx_success(3, 4), abs=0.05)
    assert rates[32] == pytest.approx(em.majx_success(3, 32), abs=0.05)


def test_sim_ideal_vs_stochastic():
    rng = np.random.default_rng(8)
    planes = jnp.asarray(rand_u32(rng, 7, 256))
    want = get_backend("oracle").majx(planes)
    ideal = get_backend("sim", IDEAL)
    assert ideal.success_rate(ideal.majx(planes, n_act=32), want) == 1.0
    noisy = get_backend("sim", ExecutionContext(seed=3))
    s = noisy.success_rate(noisy.majx(planes, n_act=32), want)
    assert s < 1.0  # MAJ7@32: ~34% success (Obs 8)


def test_shared_context_threads_regime():
    """One ExecutionContext declares the regime for any backend."""
    ctx = ExecutionContext(mfr="M", temp_c=90.0, vpp_v=2.1, tier=7,
                           ideal=True)
    for name in BACKENDS:
        be = get_backend(name, ctx)
        assert be.ctx.mfr == "M"
        assert be.ctx.error_model.mfr == "M"
    # Mfr M caps MAJX arity at 7 (fn 11)
    assert get_backend("sim", ctx.replace(ideal=False)
                       ).capabilities().max_majx == 7
