"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.step import init_train_state, make_train_step


def _loader(cfg, batch=2, seq=32):
    return SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        n_codebooks=cfg.n_codebooks,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
        d_model=cfg.d_model))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=2)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tc))
    batch = _loader(cfg).batch(0)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    # parameters actually moved
    p0, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(p0.params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_shapes(arch):
    from repro.models import model as M

    cfg = get_config(arch, smoke=True)
    params, _ = M.init(jax.random.PRNGKey(1), cfg)
    batch = _loader(cfg).batch(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    logits, aux = M.forward(params, batch, cfg)
    b, s = batch["tokens"].shape[:2]
    if cfg.family == "audio":
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any(), arch


@pytest.mark.parametrize("arch", ["gemma-7b", "zamba2-1.2b", "xlstm-125m",
                                  "musicgen-medium", "qwen3-moe-235b-a22b"])
def test_arch_smoke_prefill_decode(arch):
    from repro.models import model as M

    cfg = get_config(arch, smoke=True)
    params, _ = M.init(jax.random.PRNGKey(2), cfg)
    batch = _loader(cfg, batch=2, seq=16).batch(0)
    toks = jnp.asarray(batch["tokens"])
    logits, cache = M.prefill(params, {"tokens": toks}, cfg, max_seq=32)
    nxt = toks[:, -1:]
    lg, cache = M.decode(params, nxt, cache, cfg)
    if cfg.family == "audio":
        assert lg.shape == (2, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert lg.shape == (2, 1, cfg.vocab_size)
    assert not jnp.isnan(lg.astype(jnp.float32)).any()


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == \
        (56, 6144, 48, 8, 16384, 32768, 8, 2)
    assert c.sliding_window == 4096
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == \
        (94, 4096, 64, 4, 1536, 151936, 128, 8)
    c = get_config("chatglm3-6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 4096, 32, 2, 13696, 65024)
    c = get_config("gemma-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.head_dim, c.d_ff,
            c.vocab_size) == (28, 3072, 16, 256, 24576, 256000)
    c = get_config("deepseek-coder-33b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (62, 7168, 56, 8, 19200, 32256)
    c = get_config("glm4-9b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (40, 4096, 151552)
    c = get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (38, 2048, 64, 32000)
    c = get_config("musicgen-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.n_codebooks) == (48, 1536, 24, 6144, 2048, 4)
    c = get_config("xlstm-125m")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == \
        (12, 768, 4, 50304)
    c = get_config("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (32, 3072, 32, 8192, 32064)
