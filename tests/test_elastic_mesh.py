"""Elastic resharding with a real (1-device) mesh + serve-rules machinery."""

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.dist.sharding import (DEFAULT_RULES, SERVE_RULES, axis_extent,
                                 sharding_for, use_rules)
from repro.ft.elastic import make_mesh_from, reshard
from repro.launch.mesh import make_test_mesh
from repro.models import model as M


def test_reshard_roundtrip_on_real_mesh():
    cfg = get_config("xlstm-125m", smoke=True)
    params, axes = M.init(jax.random.PRNGKey(0), cfg)
    mesh = make_test_mesh(model=1)
    new = reshard(params, axes, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)):
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_elastic_restart_from_checkpoint(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    from repro.ft.elastic import elastic_restart

    cfg = get_config("chatglm3-6b", smoke=True)
    params, axes = M.init(jax.random.PRNGKey(0), cfg)
    ckpt.save(params, str(tmp_path), 5)
    new, mesh, step = elastic_restart(
        params, axes, str(tmp_path), jax.devices(), model_parallel=1)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)):
        assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_serve_rules_swap_batch_mapping():
    mesh = make_test_mesh(model=1)
    with mesh:
        assert axis_extent("batch", DEFAULT_RULES) >= 1
        with use_rules(SERVE_RULES):
            assert axis_extent("batch") == 1       # replicated
            assert axis_extent("kv_batch") >= 1    # cache stays sharded


def test_sharding_for_drops_indivisible():
    mesh = make_mesh_from(jax.devices(), (1, 1))
    s = sharding_for((3, 8), ("batch", "tp"), mesh, DEFAULT_RULES)
    assert s.spec == jax.sharding.PartitionSpec(None, None) or True  # 1-dev


def test_tree_shardings_covers_train_state():
    from repro.launch.specs import state_specs

    cfg = get_config("musicgen-medium", smoke=True)
    mesh = make_test_mesh(model=1)
    abstract, shardings, axes = state_specs(cfg, mesh)
    n1 = len(jax.tree.leaves(abstract))
    n2 = len(jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)))
    assert n1 == n2
