"""Adaptive boundary search: grid parity, economy, kill/resume.

The load-bearing invariant: adaptive probes are ordinary dense-grid
chunks executed into the ordinary content-hashed store, so records on
points both modes touch are byte-identical, aggregates over overlapping
points agree exactly, and a killed adaptive campaign resumes with zero
recomputation — exactly like a grid one.
"""

import os

import pytest

from repro.sweep import (AdaptiveSpec, MemoryBackend, RecordStore, SweepSpec,
                         presets, run_adaptive, run_sweep)

LADDER = tuple((1.5 + 1.5 * k, 3.0) for k in range(20))


def _smoke():
    return presets.adaptive_smoke_spec()


# ----------------------------------------------------------- spec policy


def test_adaptive_spec_validation():
    base = _smoke().base
    with pytest.raises(ValueError, match="threshold"):
        AdaptiveSpec(base=base, thresholds=())
    with pytest.raises(ValueError, match=r"\(0, 1\)"):
        AdaptiveSpec(base=base, thresholds=(1.5,))
    with pytest.raises(ValueError, match="unknown search axis"):
        AdaptiveSpec(base=base, axes=("pattern",))
    with pytest.raises(ValueError, match="not swept"):
        AdaptiveSpec(base=base, axes=("n_act",))  # single value in base
    with pytest.raises(ValueError, match="refine_radius"):
        AdaptiveSpec(base=base, refine_radius=-1)
    with pytest.raises(ValueError, match="metric"):
        AdaptiveSpec(base=base, metric="latency")


def test_search_axes_default_to_swept_axes():
    aspec = _smoke()
    assert aspec.search_axes() == ("timings",)
    assert AdaptiveSpec(base=aspec.base, axes=("timings",)).search_axes() \
        == ("timings",)


# ------------------------------------------------- cliff location / economy


def test_adaptive_locates_dense_cliff_with_few_points(tmp_path):
    """The boundary search must bracket exactly the dense scan's first
    below-threshold step while consulting <= 40 % of the ladder."""
    aspec = _smoke()
    dense = run_sweep(aspec.base, str(tmp_path / "dense"))
    adaptive = run_adaptive(aspec, str(tmp_path / "adaptive"))

    assert adaptive.complete
    assert adaptive.points_covered <= 0.4 * adaptive.n_grid_points

    by_idx = {r["index"]: r["success"] for r in dense.records}
    order = sorted(by_idx)
    assert len(adaptive.crossings) == len(aspec.thresholds)
    for c in adaptive.crossings:
        assert c.crossed and c.direction == "falling"
        first_below = next(i for i in order if by_idx[i] < c.threshold)
        assert (c.lo_index, c.hi_index) == (first_below - 1, first_below)


def test_flat_surface_probes_endpoints_only(tmp_path):
    """An ideal (always-1.0) surface never crosses: the search must
    report crossed=False after touching only the two endpoints."""
    base = SweepSpec(name="flat", op="majx", backends=("sim",),
                     x_values=(3,), n_act=(32,), timings=LADDER[:8],
                     ideal=True, rows=2, words=16, chunk=1)
    result = run_adaptive(AdaptiveSpec(base=base), str(tmp_path))
    assert result.n_probed == 2
    assert all(not c.crossed for c in result.crossings)
    assert all(c.direction is None for c in result.crossings)


# -------------------------------------------------------- store parity


def test_grid_and_adaptive_records_byte_identical(tmp_path):
    """Stochastic sim backend: every chunk file both modes produce must
    be byte-for-byte identical (records are pure f(spec, chunk))."""
    base = SweepSpec(name="parity", op="majx", backends=("sim",),
                     x_values=(3,), n_act=(32,), timings=LADDER[:10],
                     rows=2, words=32, chunk=1)
    dense = run_sweep(base, str(tmp_path / "dense"))
    adaptive = run_adaptive(AdaptiveSpec(base=base), str(tmp_path / "adapt"))

    d_dir = os.path.join(dense.store_path, "chunks")
    a_dir = os.path.join(adaptive.store_path, "chunks")
    common = sorted(set(os.listdir(d_dir)) & set(os.listdir(a_dir)))
    assert common  # at minimum the endpoint probes overlap
    for f in common:
        with open(os.path.join(d_dir, f), "rb") as da, \
                open(os.path.join(a_dir, f), "rb") as ad:
            assert da.read() == ad.read(), f

    # Aggregate parity on the overlapping points follows from the above
    # but is the user-facing contract — check it directly too.
    probed = {r["index"] for r in adaptive.records}
    dense_sub = sorted((r for r in dense.records if r["index"] in probed),
                       key=lambda r: r["index"])
    adapt_sub = sorted(adaptive.records, key=lambda r: r["index"])
    assert dense_sub == adapt_sub


def test_adaptive_then_dense_shares_one_store(tmp_path):
    """A later dense run over the same spec fills in only the plateau the
    search skipped — the cliff probes are never recomputed."""
    aspec = _smoke()
    adaptive = run_adaptive(aspec, str(tmp_path))
    assert adaptive.executed_chunks > 0
    dense = run_sweep(aspec.base, str(tmp_path))
    assert dense.store_path == adaptive.store_path
    assert dense.cached_chunks == adaptive.executed_chunks
    assert dense.executed_chunks == (aspec.base.n_points()
                                     - adaptive.executed_chunks)


# ---------------------------------------------------------- kill / resume


def test_adaptive_kill_resume_recomputes_nothing(tmp_path):
    """Kill mid-search (max_chunks), restart: stored probes replay from
    the store (mtimes unchanged), only missing probes execute, and the
    final crossings equal an uninterrupted run's."""
    aspec = _smoke()
    partial = run_adaptive(aspec, str(tmp_path / "a"), max_chunks=3)
    assert not partial.complete
    assert partial.executed_chunks == 3

    store = RecordStore(str(tmp_path / "a"), aspec.base)
    before = {k: os.path.getmtime(os.path.join(store.path, "chunks",
                                               k + ".json"))
              for k in store.completed()}
    assert len(before) == 3

    resumed = run_adaptive(aspec, str(tmp_path / "a"))
    assert resumed.complete
    assert resumed.cached_chunks == 3
    for k, mt in before.items():
        assert os.path.getmtime(os.path.join(
            store.path, "chunks", k + ".json")) == mt

    uninterrupted = run_adaptive(aspec, str(tmp_path / "b"))
    assert resumed.crossings == uninterrupted.crossings
    assert resumed.executed_chunks + resumed.cached_chunks \
        == uninterrupted.executed_chunks

    # Third invocation: the whole search replays from the store.
    again = run_adaptive(aspec, str(tmp_path / "a"))
    assert again.executed_chunks == 0
    assert again.crossings == uninterrupted.crossings


# ------------------------------------------------------- pluggable store


def test_memory_backend_matches_local_store(tmp_path):
    """The in-memory backend is a drop-in: same records, same crossings,
    same resume semantics, no filesystem."""
    aspec = _smoke()
    disk = run_adaptive(aspec, str(tmp_path))

    backend = MemoryBackend("adaptive-test")
    store = RecordStore("unused-root", aspec.base, backend=backend)
    mem = run_adaptive(aspec, store=store)
    assert mem.store_path == "memory://adaptive-test"
    assert mem.crossings == disk.crossings
    assert sorted(mem.records, key=lambda r: r["index"]) \
        == sorted(disk.records, key=lambda r: r["index"])

    # Resume against the same live backend: zero executions.
    again = run_adaptive(aspec, store=RecordStore("unused-root", aspec.base,
                                                  backend=backend))
    assert again.executed_chunks == 0
    assert again.crossings == disk.crossings
