"""Pin every paper anchor the rest of the system calibrates against."""

from repro.core import calibration as cal


def test_obs1_simra_success_levels():
    assert cal.SIMRA_SUCCESS_BEST[32] == 0.9985
    for n in (2, 4, 8, 16):
        assert cal.SIMRA_SUCCESS_BEST[n] == 0.9999


def test_obs8_majx_success_32row():
    assert cal.MAJX_SUCCESS_32ROW == {3: 0.9900, 5: 0.7964, 7: 0.3387,
                                      9: 0.0591}


def test_obs6_derived_maj3_4row():
    # 99.00 / 1.3081 = 75.68…%
    assert abs(cal.maj3_success_4row() - 0.7568) < 1e-3


def test_obs10_derived_unreplicated_bases():
    assert abs(cal.majx_success_min_activation(5) - 0.7964 / 1.5627) < 1e-4
    assert abs(cal.majx_success_min_activation(7) - 0.3387 / 1.3515) < 1e-4
    assert abs(cal.majx_success_min_activation(9) - 0.0591 / 1.1311) < 1e-4


def test_obs9_fixed_pattern_stays_below_one():
    for x in (3, 5, 7, 9):
        assert cal.majx_success_fixed_pattern(x) <= 1.0


def test_obs14_mrc_levels():
    assert cal.MRC_SUCCESS_BEST == {1: 0.99996, 3: 0.99989, 7: 0.99998,
                                    15: 0.99999, 31: 0.99982}


def test_replication_plan_matches_paper_examples():
    # §3.3: MAJ3@32 -> 10 copies, 2 neutral.
    assert cal.replication_plan(3, 32) == (10, 2)
    assert cal.replication_plan(5, 32) == (6, 2)
    assert cal.replication_plan(7, 32) == (4, 4)
    assert cal.replication_plan(9, 32) == (3, 5)
    assert cal.replication_plan(3, 4) == (1, 1)


def test_min_activation_levels():
    assert cal.min_activation_for(3) == 4
    assert cal.min_activation_for(5) == 8
    assert cal.min_activation_for(7) == 8
    assert cal.min_activation_for(9) == 16


def test_device_anchors():
    assert cal.DEVICE_ANCHORS["H"].max_majx == 9
    assert cal.DEVICE_ANCHORS["M"].max_majx == 7
    assert not cal.DEVICE_ANCHORS["S"].supports_simra
    assert cal.DEVICE_ANCHORS["M"].frac_via_bias


def test_decoder_constants():
    assert cal.DECODER_NUM_PREDECODERS == 5
    assert 2 ** cal.DECODER_ROW_BITS == 512
