"""Dry-run machinery on a small faked-device mesh (subprocess: the device
count is locked at first jax init, so tests exercise it out of process)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.configs.registry import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import _cost_analysis, lower_cell
from repro.launch import roofline as rl

cfg = get_config("xlstm-125m", smoke=True)
import dataclasses
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
mesh = make_mesh((4, 2), ("data", "model"))
lowered, compiled = lower_cell(cfg, shape, mesh)
mem = compiled.memory_analysis()
coll = rl.collective_bytes(compiled.as_text(), loop_multiplier=cfg.n_layers)
ca = _cost_analysis(compiled)
print(json.dumps({
    "temp_gb": mem.temp_size_in_bytes / 2**30,
    "flops": ca.get("flops", 0.0),
    "coll_ops": coll.n_ops,
    "coll_bytes": coll.total_bytes,
}))
"""


@pytest.mark.slow
def test_dryrun_lowers_on_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["flops"] > 0
    assert data["coll_ops"] > 0          # mesh collectives present
    assert data["temp_gb"] < 64          # smoke-size memory


@pytest.mark.slow
def test_decode_cell_lowers():
    script = SCRIPT.replace('SHAPES["train_4k"], seq_len=64, global_batch=8',
                            'SHAPES["decode_32k"], seq_len=128, global_batch=8')
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
