"""Differential fuzz + structural tests for the megakernel executor.

The load-bearing guarantee extends test_compile_differential.py by one
mode: for ANY addressed Program,

    oracle per-op == sim == pallas per-op == pallas fused
                  == pallas MEGAKERNEL (one dispatch)

bit-exactly.  The generator produces the hazards the lowering must
survive — aliasing destinations, dead stores, mixed MAJ arities in one
level, wide MRC fan-out — and the structural tests pin the lowering
invariants (table shapes, parity padding, constant-row layout, digest
stability) plus the session-layer lowering cache and the one-dispatch
acceptance gate for the 32-bit adder.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import rand_u32, sweep
from repro.backends import ExecutionContext, get_backend
from repro.compile import (MegaLowering, build_schedule, compile_elementwise,
                           lower_schedule, plan_vmem)
from repro.compile.megakernel import N_CONST_ROWS, TRASH_ROW, ZERO_ROW
from repro.kernels.megakernel import run_lowering, schedule_exec_ref
from repro.pud.isa import Program
from repro.session import DramSession
from test_compile_differential import rand_program

IDEAL = ExecutionContext(ideal=True)
ROWS, WORDS = 20, 8


def _oracle_want(prog, state):
    return np.asarray(get_backend("oracle", IDEAL).run(prog, state))


def _all_modes(prog, state) -> dict[str, np.ndarray]:
    outs = {}
    for name in ("oracle", "sim", "pallas"):
        be = get_backend(name, IDEAL)
        outs[f"{name}/fused"] = np.asarray(be.run_fused(prog, state))
        outs[f"{name}/megakernel"] = np.asarray(
            be.run_fused(prog, state, mode="megakernel"))
    outs["pallas/per_op"] = np.asarray(
        get_backend("pallas", IDEAL).run(prog, state))
    return outs


# ------------------------------------------------------ differential fuzz


@sweep(n_cases=8, seed=0x3E6A)
def test_random_programs_all_modes_agree(rng):
    prog = rand_program(rng)
    state = jnp.asarray(rand_u32(rng, ROWS, WORDS))
    want = _oracle_want(prog, state)
    for name, got in _all_modes(prog, state).items():
        assert (got == want).all(), name


@sweep(n_cases=4, seed=0xD1FF)
def test_megakernel_is_one_dispatch_for_any_nonempty_program(rng):
    prog = rand_program(rng, n_ops=14)
    state = jnp.asarray(rand_u32(rng, ROWS, WORDS))
    pal = get_backend("pallas", IDEAL)
    nonempty = build_schedule(prog).n_levels > 0
    with pal.count_dispatches() as scope:
        pal.run_fused(prog, state, mode="megakernel")
    assert scope.count == (1 if nonempty else 0)


def test_destination_aliasing_program_megakernel():
    """In-place rewrites force one level per op; the scan must sample
    level-entry state, never the half-updated image."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(0,))
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(1,))
    prog.emit("NOT", srcs=(1,), dsts=(1,))
    prog.emit("MRC", n_act=4, srcs=(1,), dsts=(2, 0, 3))
    state = jnp.asarray(rand_u32(np.random.default_rng(1), 4, WORDS))
    want = _oracle_want(prog, state)
    for name, got in _all_modes(prog, state).items():
        assert (got == want).all(), name


def test_dead_ops_still_write_their_rows_megakernel():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(5,))  # dead
    prog.emit("COPY", srcs=(0,), dsts=(6,))                    # dead
    prog.emit("MAJ", x=3, n_act=4, srcs=(1, 2, 3), dsts=(4,))
    state = jnp.asarray(rand_u32(np.random.default_rng(2), 7, WORDS))
    got = np.asarray(get_backend("pallas", IDEAL).run_fused(
        prog, state, mode="megakernel"))
    assert (got == _oracle_want(prog, state)).all()
    assert not (got[5] == np.asarray(state)[5]).all()


def test_mixed_arity_maj3579_single_level_single_dispatch():
    """MAJ3/5/7/9 sharing one level: all pad to x_max=9 with 0/1 pairs."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(10,))
    prog.emit("MAJ", x=5, n_act=8, srcs=(0, 1, 2, 3, 4), dsts=(11,))
    prog.emit("MAJ", x=7, n_act=8, srcs=(0, 1, 2, 3, 4, 5, 6), dsts=(12,))
    prog.emit("MAJ", x=9, n_act=16, srcs=tuple(range(9)), dsts=(13,))
    low = lower_schedule(build_schedule(prog))
    assert (low.n_levels, low.w_max, low.x_max) == (1, 4, 9)
    state = jnp.asarray(rand_u32(np.random.default_rng(3), 14, WORDS))
    pal = get_backend("pallas", IDEAL)
    with pal.count_dispatches() as scope:
        got = np.asarray(pal.run_fused(prog, state, mode="megakernel"))
    assert scope.count == 1
    assert (got == _oracle_want(prog, state)).all()


def test_mrc_fanout31_is_31_identity_slots_one_dispatch():
    prog = Program()
    prog.emit("MRC", n_act=32, srcs=(0,), dsts=tuple(range(1, 32)))
    low = lower_schedule(build_schedule(prog))
    assert (low.n_levels, low.w_max, low.x_max) == (1, 31, 1)
    assert low.level_meta == ((0, 31, 0, 0),)
    state = jnp.asarray(rand_u32(np.random.default_rng(4), 32, WORDS))
    pal = get_backend("pallas", IDEAL)
    with pal.count_dispatches() as scope:
        got = np.asarray(pal.run_fused(prog, state, mode="megakernel"))
    assert scope.count == 1
    assert (got[1:] == np.asarray(state)[0]).all()


def test_single_op_degenerate_schedule():
    prog = Program()
    prog.emit("NOT", srcs=(0,), dsts=(1,))
    state = jnp.asarray(rand_u32(np.random.default_rng(5), 2, WORDS))
    want = _oracle_want(prog, state)
    for name, got in _all_modes(prog, state).items():
        assert (got == want).all(), name


def test_cost_only_program_is_identity_at_zero_dispatches():
    prog = Program()
    for _ in range(5):
        prog.emit("MAJ", x=5, n_act=8)
        prog.emit("WR")
    state = jnp.asarray(rand_u32(np.random.default_rng(6), 4, 4))
    pal = get_backend("pallas", IDEAL)
    with pal.count_dispatches() as scope:
        got = pal.run_fused(prog, state, mode="megakernel")
    assert scope.count == 0
    assert (np.asarray(got) == np.asarray(state)).all()


def test_unknown_mode_rejected_everywhere():
    prog = Program()
    prog.emit("NOT", srcs=(0,), dsts=(1,))
    state = jnp.zeros((2, 4), jnp.uint32)
    for name in ("oracle", "sim", "pallas"):
        with pytest.raises(ValueError, match="unknown run_fused mode"):
            get_backend(name, IDEAL).run_fused(prog, state, mode="warp")


# ------------------------------------------------------ lowering structure


def test_lowering_invariants_random_programs():
    rng = np.random.default_rng(7)
    for _ in range(6):
        prog = rand_program(rng, n_ops=16)
        sched = build_schedule(prog)
        low = lower_schedule(sched)
        assert isinstance(low, MegaLowering)
        assert low.x_max % 2 == 1                       # parity-safe padding
        assert low.src.shape == (low.n_levels, low.w_max, low.x_max)
        assert low.dst.shape == low.inv.shape == (low.n_levels, low.w_max)
        assert low.n_levels == sched.n_levels
        # Every table index addresses the augmented image.
        assert low.src.min() >= 0
        assert low.src.max() < low.n_rows + N_CONST_ROWS
        assert ((low.dst >= N_CONST_ROWS) | (low.dst == TRASH_ROW)).all()
        for li, counts in enumerate(low.level_meta):
            live = sum(counts)
            assert live <= low.w_max
            # Inert padding slots: zero-row gather, trash-row scatter.
            assert (low.dst[li, live:] == TRASH_ROW).all()
            assert (low.src[li, live:] == ZERO_ROW).all()
            assert (low.inv[li, live:] == 0).all()


def test_lowering_digest_is_content_stable_and_sensitive():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(3,), dsts=(4,))
    d1 = lower_schedule(build_schedule(prog)).digest()
    d2 = lower_schedule(build_schedule(prog)).digest()
    assert d1 == d2
    prog2 = Program()
    prog2.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog2.emit("NOT", srcs=(3,), dsts=(5,))             # one address differs
    assert lower_schedule(build_schedule(prog2)).digest() != d1


def test_lowering_is_state_height_independent():
    """Tables depend on program content only — the cacheability contract."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    low = lower_schedule(build_schedule(prog))
    for rows in (4, 9, 40):
        state = rand_u32(np.random.default_rng(rows), rows, WORDS)
        got = np.asarray(run_lowering(low, jnp.asarray(state)))
        want = _oracle_want(prog, jnp.asarray(state))
        assert (got == want).all(), rows


def test_run_lowering_rejects_short_state():
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(9,))
    low = lower_schedule(build_schedule(prog))
    with pytest.raises(ValueError, match="addresses 10 rows"):
        run_lowering(low, jnp.zeros((4, 4), jnp.uint32))


# ----------------------------------------- numpy ref vs the Pallas kernel


@sweep(n_cases=4, seed=0x2EF5)
def test_numpy_ref_executor_matches_pallas_kernel(rng):
    """Separates lowering bugs from kernel bugs: both executors consume
    the SAME tables and must agree bit-exactly (and with the oracle)."""
    prog = rand_program(rng, n_ops=12)
    low = lower_schedule(build_schedule(prog))
    state = rand_u32(rng, ROWS, WORDS)
    want = _oracle_want(prog, jnp.asarray(state))
    ref = schedule_exec_ref(low, state)
    assert (ref == want).all()
    if low.n_levels:
        krn = np.asarray(run_lowering(low, jnp.asarray(state)))
        assert (krn == ref).all()


# --------------------------------------------------- session + lowering cache


def _adder_program(n_bits=8, seed=0xADD):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**16, n_bits, dtype=np.uint32)
    b = rng.integers(0, 2**16, n_bits, dtype=np.uint32)
    return compile_elementwise("add", a, b, tier=5, n_act=32), a, b


def test_session_megakernel_caches_lowering_separately():
    cp, a, b = _adder_program()
    sess = DramSession("pallas", IDEAL)

    fused = np.asarray(sess.run_fused(cp.program, cp.state))
    sched_stats = sess.cache.stats.snapshot()
    assert sess.cache.lowering_stats.lookups == 0

    mega1 = np.asarray(sess.run_fused(cp.program, cp.state,
                                      mode="megakernel"))
    mega2 = np.asarray(sess.run_fused(cp.program, cp.state,
                                      mode="megakernel"))
    assert (mega1 == fused).all() and (mega2 == fused).all()
    assert (np.asarray(cp.outputs(mega1))
            == (a + b).astype(np.uint32)).all()
    # Lowerings account on their own window; schedule stats advance by
    # exactly one (cached) lookup per run, same as fused mode would.
    assert sess.cache.lowering_stats.misses == 1
    assert sess.cache.lowering_stats.hits == 1
    delta = sess.cache.stats.delta(sched_stats)
    assert delta.misses == 0 and delta.hits == 2


def test_session_megakernel_on_fallback_backend_skips_lowering():
    cp, a, b = _adder_program(seed=0xFA11)
    sess = DramSession("oracle", IDEAL)
    got = np.asarray(sess.run_fused(cp.program, cp.state,
                                    mode="megakernel"))
    assert (np.asarray(cp.outputs(got)) == (a + b).astype(np.uint32)).all()
    assert sess.cache.lowering_stats.lookups == 0  # nothing to lower for


# ------------------------------------------- the acceptance dispatch gate


def test_adder32_megakernel_single_dispatch():
    """The gate: a 32-bit ripple-carry add in ONE dispatch, bit-exact."""
    rng = np.random.default_rng(8)
    a, b = rand_u32(rng, 32), rand_u32(rng, 32)
    cp = compile_elementwise("add", a, b, tier=5, n_act=32)
    pal = get_backend("pallas", IDEAL)

    with pal.count_dispatches() as fused_scope:
        fused = np.asarray(pal.run_fused(cp.program, cp.state))
    with pal.count_dispatches() as mega_scope:
        mega = np.asarray(pal.run_fused(cp.program, cp.state,
                                        mode="megakernel"))
    assert mega_scope.count == 1
    assert mega_scope.count < fused_scope.count
    assert (mega == fused).all()
    assert (np.asarray(cp.outputs(mega)) == (a + b).astype(np.uint32)).all()


# --------------------------------------------------- VMEM planning / spill


def test_plan_vmem_properties():
    prog = Program()
    prog.emit("MAJ", x=5, n_act=8, srcs=(0, 1, 2, 3, 4), dsts=(5,))
    low = lower_schedule(build_schedule(prog))
    big = plan_vmem(low, rows=6, words=256, budget_bytes=8 * 2**20)
    assert big.resident and big.block_c % 128 == 0 and big.block_c >= 256
    tiny = plan_vmem(low, rows=6, words=100_000, budget_bytes=4096)
    assert not tiny.resident
    assert tiny.block_c % 128 == 0
    assert tiny.block_c < 100_000
    assert tiny.working_set_bytes > tiny.budget_bytes
    d = tiny.as_dict()
    assert set(d) == {"block_c", "resident", "working_set_bytes",
                      "budget_bytes"}


def test_forced_vmem_spill_is_still_one_exact_dispatch():
    """A starved budget splits the word axis into column blocks streamed
    through the grid — launch count and results must not change."""
    prog = Program()
    prog.emit("MAJ", x=3, n_act=4, srcs=(0, 1, 2), dsts=(3,))
    prog.emit("NOT", srcs=(3,), dsts=(4,))
    prog.emit("MRC", n_act=4, srcs=(4,), dsts=(5, 6, 7))
    state = jnp.asarray(rand_u32(np.random.default_rng(9), 8, 300))
    want = _oracle_want(prog, state)

    starved = get_backend("pallas", IDEAL.replace(vmem_budget_bytes=4096))
    low = lower_schedule(build_schedule(prog))
    plan = plan_vmem(low, 8, 300, starved.ctx.vmem_budget_bytes)
    assert not plan.resident and plan.block_c < 300  # really multi-block
    with starved.count_dispatches() as scope:
        got = np.asarray(starved.run_fused(prog, state, mode="megakernel"))
    assert scope.count == 1
    assert (got == want).all()
