"""Property tests for the packed bit-plane substrate."""

import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import rand_bits, rand_u32, sweep
from repro.core import bitplanes as bp


@sweep(10)
def test_pack_unpack_roundtrip(rng):
    n_bits = int(rng.integers(1, 200))
    bits = rand_bits(rng, 3, n_bits)
    assert (np.asarray(bp.unpack(bp.pack(bits), n_bits)) == bits).all()


@sweep(10)
def test_popcount_matches_numpy(rng):
    w = rand_u32(rng, 64)
    got = np.asarray(bp.popcount(jnp.asarray(w)))
    want = np.array([bin(x).count("1") for x in w])
    assert (got == want).all()


@sweep(10)
def test_majority_matches_bit_counting(rng):
    n = int(rng.choice([3, 5, 7, 9]))
    planes = rand_u32(rng, n, 16)
    got = np.asarray(bp.majority(jnp.asarray(planes)))
    bits = np.stack([[(planes[i, j] >> k) & 1 for k in range(32)]
                     for i in range(n) for j in range(16)])
    bits = bits.reshape(n, 16, 32)
    want_bits = (bits.sum(0) * 2 > n).astype(np.uint32)
    want = (want_bits << np.arange(32, dtype=np.uint64)).sum(-1).astype(np.uint32)
    assert (got == want).all()


@sweep(6)
def test_maj3_closed_form(rng):
    a, b, c = rand_u32(rng, 3, 32)
    got = bp.maj3_words(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    want = bp.majority(jnp.stack([jnp.asarray(a), jnp.asarray(b),
                                  jnp.asarray(c)]))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_majority_replication_invariance():
    """MAJ6(A,B,C,A,B,C) == MAJ3(A,B,C) — the paper's fn 3 identity."""
    rng = np.random.default_rng(0)
    a, b, c = (jnp.asarray(rand_u32(rng, 8)) for _ in range(3))
    maj3 = bp.majority(jnp.stack([a, b, c]))
    maj6 = bp.majority_with_ties(jnp.stack([a, b, c, a, b, c]), tie_value=0)
    maj9 = bp.majority(jnp.stack([a, b, c] * 3))
    assert (np.asarray(maj3) == np.asarray(maj6)).all()
    assert (np.asarray(maj3) == np.asarray(maj9)).all()


@sweep(6)
def test_weighted_majority_identity(rng):
    """MAJ3(x,y,z) == weighted majority (2,2,1) over (x,x,y,y,z)."""
    x, y, z = (jnp.asarray(rand_u32(rng, 16)) for _ in range(3))
    m3 = bp.maj3_words(x, y, z)
    wm = bp.weighted_majority(jnp.stack([x, y, z]), jnp.asarray([2, 2, 1]))
    assert (np.asarray(m3) == np.asarray(wm)).all()


@sweep(8)
def test_uint_element_transpose_roundtrip(rng):
    k = int(rng.integers(1, 100))
    x = rand_u32(rng, k)
    planes = bp.pack_uint_elements(jnp.asarray(x))
    back = bp.unpack_uint_elements(planes, k)
    assert (np.asarray(back) == x).all()


def test_bitcast_roundtrip_dtypes():
    rng = np.random.default_rng(1)
    for dtype in (jnp.float32, jnp.bfloat16, jnp.float16, jnp.int8,
                  jnp.uint8, jnp.int32):
        x = jnp.asarray(rng.standard_normal(37), jnp.float32).astype(dtype)
        w, sh, dt = bp.bitcast_to_planes(x)
        back = bp.bitcast_from_planes(w, sh, dt)
        assert back.dtype == x.dtype and back.shape == x.shape
        assert (np.asarray(back) == np.asarray(x)).all(), dtype


# ----------------------------------------------------- word boundaries
# The packing layout changes representation exactly at multiples of 32
# (one uint32 word per 32 logical bits); every edge below sits on, just
# under, or just over a boundary, where an off-by-one in the pad/crop
# arithmetic would silently truncate or alias bits.

WORD_EDGES = (1, 31, 32, 33, 1024)


@pytest.mark.parametrize("n_bits", WORD_EDGES)
def test_pack_unpack_word_boundary(n_bits):
    rng = np.random.default_rng(n_bits)
    bits = rand_bits(rng, 2, n_bits)
    words = bp.pack(bits)
    assert words.shape == (2, bp.n_words(n_bits))
    assert (np.asarray(bp.unpack(words, n_bits)) == bits).all()
    # Pad bits beyond n_bits must be zero, not residue of the input.
    tail = np.asarray(bp.unpack(words, bp.n_words(n_bits) * 32))
    assert not tail[:, n_bits:].any()


@pytest.mark.parametrize("k", WORD_EDGES)
def test_pack_uint_elements_word_boundary(k):
    rng = np.random.default_rng(k)
    x = rand_u32(rng, k)
    planes = bp.pack_uint_elements(jnp.asarray(x))
    assert planes.shape == (32, bp.n_words(k))
    assert (np.asarray(bp.unpack_uint_elements(planes, k)) == x).all()


@pytest.mark.parametrize("n_bits", WORD_EDGES)
def test_pack_uint_elements_narrow_width(n_bits):
    """Element widths at word edges: values must survive a pack at
    width min(n_bits, 32) when they fit in that many bits."""
    width = min(n_bits, 32)
    rng = np.random.default_rng(n_bits + 7)
    x = rand_u32(rng, 40) >> np.uint32(32 - width)
    planes = bp.pack_uint_elements(jnp.asarray(x), n_bits=width)
    assert planes.shape == (width, bp.n_words(40))
    assert (np.asarray(bp.unpack_uint_elements(planes, 40)) == x).all()


@pytest.mark.parametrize("n_elem", WORD_EDGES)
def test_bitcast_word_boundary_element_counts(n_elem):
    """Sub-word dtypes pad to whole uint32 words; every edge count must
    round-trip without truncation or stray tail bytes."""
    rng = np.random.default_rng(n_elem)
    for dtype in (jnp.uint8, jnp.float16, jnp.float32):
        x = jnp.asarray(
            rng.integers(0, 200, n_elem), jnp.uint32).astype(dtype)
        w, sh, dt = bp.bitcast_to_planes(x)
        assert w.dtype == jnp.uint32
        assert w.size == bp.n_words(n_elem * 8 * jnp.dtype(dtype).itemsize)
        back = bp.bitcast_from_planes(w, sh, dt)
        assert back.shape == (n_elem,)
        assert (np.asarray(back) == np.asarray(x)).all(), (n_elem, dtype)
