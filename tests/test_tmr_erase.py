"""TMR voting, secure erase, offload planner, power model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import sweep
from repro.core import calibration as cal
from repro.core.power import STANDARD_POWER_W, power_table, simra_power_w
from repro.core.subarray import Subarray
from repro.pud import tmr
from repro.pud.offload import plan_broadcast, plan_vote
from repro.pud.secure_erase import (destruction_time_ns, erase_subarray,
                                    speedup_over_rowclone)


@sweep(6)
def test_tmr_corrects_single_replica_fault(rng):
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31)))
    x = jax.random.normal(key, (400,), jnp.float32)
    reps = [x, tmr.corrupt(x, key, 0.05), x]  # one heavily corrupted replica
    assert (np.asarray(tmr.vote_array(reps)) == np.asarray(x)).all()


def test_tmr5_corrects_two_faults():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256,), jnp.float32)
    reps = [x, tmr.corrupt(x, jax.random.fold_in(key, 1), 0.5),
            tmr.corrupt(x, jax.random.fold_in(key, 2), 0.5), x, x]
    assert (np.asarray(tmr.vote_array(reps)) == np.asarray(x)).all()


def test_tmr_residual_rate_matches_theory():
    key = jax.random.PRNGKey(3)
    x = jnp.zeros((200_000,), jnp.uint32)
    p = 1e-2
    reps = [tmr.corrupt(x, jax.random.fold_in(key, i), p) for i in range(3)]
    voted = tmr.vote_array(reps)
    bad = float(jnp.mean((voted != x).astype(jnp.float32)))
    want = tmr.residual_word_error_rate(p, 3)
    assert bad == pytest.approx(want, rel=0.25)


def test_vote_pytree():
    key = jax.random.PRNGKey(4)
    tree = {"a": jax.random.normal(key, (64,)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32)}}
    reps = [tree,
            jax.tree.map(lambda t: tmr.corrupt(t, key, 0.03), tree),
            tree]
    voted = tmr.vote_pytree(reps)
    for l1, l2 in zip(jax.tree.leaves(voted), jax.tree.leaves(tree)):
        assert (np.asarray(l1) == np.asarray(l2)).all()


# -------------------------------------------------------------- cold boot


def test_fig17_speedups():
    """MRC-based destruction: up to ~20.87x vs RowClone, ~7.55x vs Frac."""
    s32 = speedup_over_rowclone("mrc", 32)
    assert s32 == pytest.approx(cal.COLDBOOT_MAX_SPEEDUP_VS_ROWCLONE, rel=0.02)
    vs_frac = (destruction_time_ns("frac") / destruction_time_ns("mrc", 32))
    assert vs_frac == pytest.approx(cal.COLDBOOT_MAX_SPEEDUP_VS_FRAC, rel=0.02)


def test_fig17_monotone_in_n_act():
    sp = [speedup_over_rowclone("mrc", n) for n in (4, 8, 16, 32)]
    assert sp == sorted(sp)
    assert all(s > 1 for s in sp)


def test_functional_erase():
    sa = Subarray(cols=256, ideal=True)
    sa.fill("0xAA")
    t = erase_subarray(sa, 0)
    assert (np.asarray(sa.planes) == 0).all()
    assert t > 0


# -------------------------------------------------------------- offload


def test_offload_vote_prefers_pud_for_bulk():
    d = plan_vote(1 << 26)
    assert d.winner == "pud"
    assert d.pud_ns < d.tpu_ns


def test_offload_decision_fields():
    d = plan_broadcast(8192, 31)
    assert d.speedup == pytest.approx(d.tpu_ns / d.pud_ns)
    assert "MRC" in d.detail


# -------------------------------------------------------------- power


def test_obs5_power_anchor():
    """32-row activation draws 21.19 % less power than REF."""
    assert simra_power_w(32) == pytest.approx(
        STANDARD_POWER_W["REF"] * (1 + cal.SIMRA32_POWER_VS_REF), rel=1e-6)


def test_power_monotone_in_n():
    vals = [simra_power_w(n) for n in (2, 4, 8, 16, 32)]
    assert vals == sorted(vals)
    table = power_table()
    assert table["SIMRA_32"] < table["REF"]
