"""Service-layer tests: typed requests, admission control, continuous
batching, SLO accounting, and the engine's thin-client integrity hooks.

The load-bearing claims: (1) coalesced execution is bit-exact with
per-request execution on every backend — batching is purely a
throughput/dispatch optimization; (2) N sessions sharing one compile
cache resolve a same-shape program concurrently as exactly 1 miss +
N-1 hits; (3) admission control actually bounds the two scarce
resources (queue depth, tenant arena rows) and load-shedding only
drops past-deadline work; (4) the SLO snapshot is structured,
JSON-serializable, and reuses the trainer's straggler detector per
pooled session.
"""

import asyncio
import json
import warnings
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from _proptest import rand_u32
from repro.backends import ExecutionContext, get_backend
from repro.ft.straggler import StragglerDetector
from repro.serve import (ArenaExhaustedError, DeadlineExceededError,
                         EraseRequest, HealRequest, IntegrityRequest,
                         Priority, QueueFullError, RequestQueue,
                         ServeError, SloMonitor)
from repro.session import CompileCache, DramSession
from test_session import valid_rand_program

IDEAL = ExecutionContext(ideal=True)
BACKENDS = ("oracle", "sim", "pallas")


def heal_req(rng, rows=2, words=8, flips=3, tenant="default", **kw):
    """A heal request whose replicas agree except for ``flips`` bits."""
    base = rand_u32(rng, rows, words)
    replicas = np.stack([base, base, base])
    flat = replicas[0].reshape(-1)
    for i in rng.choice(flat.size, size=flips, replace=False):
        flat[i] ^= np.uint32(1) << np.uint32(rng.integers(32))
    return HealRequest(replicas=replicas, tenant=tenant, **kw)


def mixed_requests(seed, n_heal=3, n_erase=2, rows=2, words=8):
    """Deterministic mixed workload; fresh objects every call (requests
    are stamped at admission, so they cannot be served twice)."""
    rng = np.random.default_rng(seed)
    reqs = [heal_req(rng, rows, words, tenant=f"t{i}")
            for i in range(n_heal)]
    reqs += [EraseRequest(rows=5, words=words, pattern=0xDEADBEEF,
                          fanout=4, tenant=f"t{i}") for i in range(n_erase)]
    return reqs


# ------------------------------------------- coalescing is bit-exact


@pytest.mark.parametrize("backend", BACKENDS)
def test_coalesced_bit_exact_with_per_request(make_pud_service, backend):
    """Same deterministic workload, coalescing on vs off, every backend:
    per-request results must be bit-identical (and match the oracle)."""
    ref = make_pud_service(backend="oracle", coalesce=True)
    want = ref.serve(mixed_requests(seed=42))
    for coalesce in (True, False):
        svc = make_pud_service(backend=backend, coalesce=coalesce)
        got = svc.serve(mixed_requests(seed=42))
        for w, g in zip(want, got):
            if hasattr(w, "healed"):
                assert (np.asarray(g.healed) == np.asarray(w.healed)).all()
                assert g.fixed_bits == w.fixed_bits == 3
            else:
                assert (np.asarray(g.wiped) == np.asarray(w.wiped)).all()
                assert (np.asarray(g.wiped) == 0xDEADBEEF).all()


def test_coalescing_cuts_dispatches_not_results(make_pud_service):
    """pallas, structural: batching the same tick's heals+erases into
    fused groups must strictly reduce kernel launches."""
    counts = {}
    for coalesce in (True, False):
        svc = make_pud_service(backend="pallas", coalesce=coalesce)
        svc.serve(mixed_requests(seed=7, n_heal=4, n_erase=4))
        snap = svc.snapshot()
        counts[coalesce] = snap.dispatches
        assert snap.completed == 8
    assert counts[True] < counts[False], counts


def test_heal_through_service_equals_backend_majx(make_pud_service):
    """A single heal is exactly the backend's majority vote."""
    rng = np.random.default_rng(3)
    replicas = rand_u32(rng, 3, 2, 8)
    svc = make_pud_service(backend="pallas")
    [res] = svc.serve([HealRequest(replicas=replicas)])
    want = np.asarray(get_backend("oracle", IDEAL).majx(replicas))
    assert (np.asarray(res.healed) == want).all()
    assert res.decision is not None  # offload verdict rides along


def test_verify_request_counts_bits(make_pud_service):
    rng = np.random.default_rng(4)
    live = rand_u32(rng, 2, 8)
    ref = live.copy()
    ref[0, 0] ^= 0b101  # 2 flipped bits
    svc = make_pud_service(backend="oracle")
    [res] = svc.serve([IntegrityRequest(live=live, reference=ref)])
    assert res.mismatch_bits == 2
    assert res.total_bits == live.size * 32
    assert 0.0 < res.success_rate < 1.0


# ------------------------------------------- shared-cache concurrency


@pytest.mark.parametrize("backend", BACKENDS)
def test_concurrent_sessions_one_miss_rest_hits(backend):
    """N sessions over ONE cache resolve the same program concurrently:
    exactly 1 miss + N-1 hits, results bit-exact with the oracle."""
    n = 4
    rng = np.random.default_rng(0)
    prog = valid_rand_program(rng, rows=8, n_ops=6)
    state = rand_u32(rng, 8, 8)
    want = np.asarray(get_backend("oracle", IDEAL).run(prog, state))
    cache = CompileCache()
    sessions = [DramSession(backend, IDEAL, cache=cache, name=f"s{i}")
                for i in range(n)]
    with ThreadPoolExecutor(max_workers=n) as pool:
        outs = list(pool.map(
            lambda s: np.asarray(s.run_fused(prog, state)), sessions))
    assert (cache.stats.hits, cache.stats.misses) == (n - 1, 1)
    for out in outs:
        assert (out == want).all()


def test_service_pool_shares_one_cache(make_pud_service):
    """Every pooled session holds the service's cache; a steady request
    shape is 1 miss + hits thereafter across the whole pool."""
    svc = make_pud_service(backend="pallas", pool_size=3)
    assert all(s.cache is svc.cache for s in svc.sessions)
    for r in range(3):
        svc.serve(mixed_requests(seed=r, n_heal=2, n_erase=0))
    # 2 lookups per heal batch (run_fused + the offload verdict's
    # schedule_for): 3 rounds = 6 lookups, only the first ever builds.
    assert svc.cache.stats.misses == 1
    assert svc.cache.stats.hits == 5
    assert svc.snapshot().cache["hit_rate"] == pytest.approx(5 / 6)


# ------------------------------------------- admission & backpressure


def test_queue_full_backpressure(make_pud_service):
    svc = make_pud_service(backend="oracle", queue_depth=2)
    rng = np.random.default_rng(1)
    with pytest.raises(QueueFullError):
        svc.serve([heal_req(rng) for _ in range(3)])
    assert svc.backlog == 2      # the two admitted requests still queue
    assert svc.snapshot().rejected == 1
    while svc.backlog:
        svc.tick()               # and remain servable after the rejection


def test_tenant_queue_depth_cap(make_pud_service):
    svc = make_pud_service(backend="oracle", tenant_queue_depth=1)
    rng = np.random.default_rng(2)
    with pytest.raises(QueueFullError, match="tenant 'a'"):
        svc.serve([heal_req(rng, tenant="a"), heal_req(rng, tenant="a")])


def test_arena_exhausted_and_released(make_pud_service):
    # a (3, 2, words) heal needs (3+1)*2 = 8 arena rows
    svc = make_pud_service(backend="oracle", tenant_rows=8)
    rng = np.random.default_rng(3)
    svc.serve([heal_req(rng, tenant="a")])
    arena = svc.admission.arena("a")
    assert arena.rows_in_use == 0          # reservation freed on completion
    with pytest.raises(ArenaExhaustedError, match="tenant 'a'"):
        svc.serve([heal_req(rng, tenant="a"), heal_req(rng, tenant="a")])
    snap = svc.snapshot().tenants["a"]
    assert snap["completed"] == 1 and snap["rejected"] == 1
    assert snap["row_budget"] == 8


def test_deadline_shedding(make_pud_service):
    """A past-deadline request is load-shed at its tick: its slot holds
    the DeadlineExceededError, its arena rows are released, live work
    in the same tick completes normally."""
    svc = make_pud_service(backend="oracle")
    rng = np.random.default_rng(4)
    late = heal_req(rng, tenant="late", deadline_s=-0.001)
    ok = heal_req(rng, tenant="ok")
    res_late, res_ok = svc.serve([late, ok])
    assert isinstance(res_late, DeadlineExceededError)
    assert res_ok.fixed_bits == 3
    snap = svc.snapshot()
    assert snap.shed == 1 and snap.completed == 1
    assert snap.tenants["late"]["shed"] == 1
    assert svc.admission.arena("late").rows_in_use == 0


def test_shedding_disabled_runs_late_work(make_pud_service):
    svc = make_pud_service(backend="oracle", shed_late=False)
    rng = np.random.default_rng(5)
    [res] = svc.serve([heal_req(rng, deadline_s=-0.001)])
    assert res.fixed_bits == 3


def test_priority_order_and_fifo():
    q = RequestQueue(max_depth=8)
    rng = np.random.default_rng(6)
    lo = heal_req(rng, tenant="lo", priority=Priority.LOW)
    n1 = heal_req(rng, tenant="n1")
    n2 = heal_req(rng, tenant="n2")
    hi = heal_req(rng, tenant="hi", priority=Priority.HIGH)
    for r in (lo, n1, n2, hi):
        q.push(r)
    assert [r.tenant for r in q.drain()] == ["hi", "n1", "n2", "lo"]
    assert len(q) == 0 and q.tenant_depth("lo") == 0


def test_request_validation():
    rng = np.random.default_rng(7)
    with pytest.raises(ServeError, match="odd replica count"):
        HealRequest(replicas=rand_u32(rng, 4, 2, 8))
    with pytest.raises(ServeError, match="required"):
        HealRequest()
    with pytest.raises(ServeError, match="rank-2"):
        IntegrityRequest(live=rand_u32(rng, 8), reference=rand_u32(rng, 8))
    with pytest.raises(ServeError, match="fanout"):
        EraseRequest(rows=4, words=8, fanout=32)
    with pytest.raises(ServeError, match="rows >= 1"):
        EraseRequest(rows=0, words=8)


# --------------------------------------------------- async client API


def test_async_submit_and_stop(make_pud_service):
    async def drive():
        svc = make_pud_service(backend="oracle")
        await svc.start()
        rng = np.random.default_rng(8)
        results = await asyncio.gather(
            *(svc.submit(heal_req(rng, tenant=f"t{i}")) for i in range(4)))
        await svc.stop()
        return svc, results

    svc, results = asyncio.run(drive())
    assert [r.fixed_bits for r in results] == [3, 3, 3, 3]
    assert svc.snapshot().completed == 4 and svc.backlog == 0


def test_async_submit_shed_raises(make_pud_service):
    async def drive():
        svc = make_pud_service(backend="oracle")
        await svc.start()
        rng = np.random.default_rng(9)
        try:
            with pytest.raises(DeadlineExceededError):
                await svc.submit(heal_req(rng, deadline_s=-0.001))
        finally:
            await svc.stop()

    asyncio.run(drive())


# ------------------------------------------------------- SLO snapshot


def test_slo_snapshot_structure(make_pud_service):
    svc = make_pud_service(backend="pallas", pool_size=2)
    for r in range(2):
        svc.serve(mixed_requests(seed=r, n_heal=4, n_erase=2))
    snap = svc.snapshot()
    assert snap.completed == 12
    assert snap.p50_latency_s is not None
    assert snap.p99_latency_s is not None
    assert snap.p99_latency_s >= snap.p50_latency_s
    assert snap.batch_occupancy > 1.0       # heals coalesced
    assert snap.batches >= 2 and snap.dispatches > 0
    assert snap.throughput_rps > 0
    assert len(snap.session_ema_s) == 2
    assert set(snap.tenants) == {"t0", "t1", "t2", "t3"}
    json.dumps(snap.to_dict())              # schema is JSON-serializable


def test_reset_slo_rebases_cache_window(make_pud_service):
    svc = make_pud_service(backend="oracle")
    svc.serve(mixed_requests(seed=0, n_heal=2, n_erase=0))  # the miss
    svc.reset_slo()
    assert svc.snapshot().completed == 0
    svc.serve(mixed_requests(seed=1, n_heal=2, n_erase=0))
    cache = svc.snapshot().cache
    assert cache == {"hits": 2, "misses": 0, "hit_rate": 1.0}


def test_slo_monitor_flags_straggler_session():
    mon = SloMonitor(n_sessions=2)
    for _ in range(6):
        mon.record_batch(1, 0.001, 1, session_idx=0)
        mon.record_batch(1, 0.100, 1, session_idx=1)
    snap = mon.snapshot(CompileCache().stats)
    assert snap.slow_sessions == [1]
    assert snap.session_ema_s[1] > snap.session_ema_s[0]


# ------------------------------------- straggler detector contract


def test_straggler_post_init_contract():
    """The ema field is never None after construction (the old
    ``ema: np.ndarray = None`` type-lie is gone)."""
    det = StragglerDetector(n_workers=3)
    assert isinstance(det.ema, np.ndarray) and det.ema.shape == (3,)
    seeded = StragglerDetector(n_workers=2, ema=[0.5, 1.0])
    assert seeded.ema.dtype == float and seeded.ema[1] == 1.0
    with pytest.raises(ValueError, match="n_workers"):
        StragglerDetector(n_workers=0)
    with pytest.raises(ValueError, match="alpha"):
        StragglerDetector(n_workers=2, alpha=0.0)
    with pytest.raises(ValueError, match="shape"):
        StragglerDetector(n_workers=2, ema=np.zeros(3))


# ------------------------------------- engine as a service client
# (the tiny 2-tensor engine factory lives in conftest.py, shared with
# the system suite)


def test_engine_heal_and_verify_through_service(make_tiny_pud_engine):
    eng, params = make_tiny_pud_engine(pud_backend="pallas")
    bad = {k: v.copy() for k, v in params.items()}
    bad["w"][0, 0] = np.float32(99.0)  # silent corruption in one replica
    fixed = eng.heal_params([bad, params, params])
    assert fixed > 0
    assert eng.verify_params(params) == 1.0
    assert (np.asarray(eng.params["w"]) == params["w"]).all()
    assert eng.pud_decisions[-1] is not None
    assert eng.service.snapshot().tenants["engine"]["completed"] == 2


def test_engine_warns_on_non_ideal_context(make_tiny_pud_engine):
    from repro.serve.engine import IntegrityContextWarning

    eng, params = make_tiny_pud_engine(pud_backend="oracle",
                               pud_ctx=ExecutionContext(ideal=False))
    with pytest.warns(IntegrityContextWarning, match="non-ideal"):
        eng.heal_params([params, params, params])


def test_engine_strict_integrity_raises(make_tiny_pud_engine):
    from repro.serve.engine import IntegrityContextError

    eng, params = make_tiny_pud_engine(pud_backend="oracle",
                               pud_ctx=ExecutionContext(ideal=False),
                               strict_integrity=True)
    with pytest.raises(IntegrityContextError, match="fidelity studies"):
        eng.heal_params([params, params, params])


def test_engine_ideal_context_is_silent(make_tiny_pud_engine):
    eng, params = make_tiny_pud_engine(pud_backend="oracle")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.heal_params([params, params, params])


def test_engines_can_share_one_service(make_pud_service, make_tiny_pud_engine):
    svc = make_pud_service(backend="pallas")
    a, params = make_tiny_pud_engine(pud_service=svc, tenant="engine-a")
    b, _ = make_tiny_pud_engine(pud_service=svc, tenant="engine-b")
    assert a.service is svc and b.service is svc
    a.heal_params([params, params, params])
    b.heal_params([params, params, params])
    tenants = svc.snapshot().tenants
    assert tenants["engine-a"]["completed"] == 1
    assert tenants["engine-b"]["completed"] == 1
    assert svc.cache.stats.hits >= 1       # second vote reused the schedule
