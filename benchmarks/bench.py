"""``repro.bench``: per-op vs fused Program execution harness.

Times the same addressed :class:`~repro.pud.isa.Program` through
``Backend.run`` (one kernel launch per MAJ/MRC op) and
``Backend.run_fused`` (one launch per schedule dispatch group, see
:mod:`repro.compile`) for the paper-motivated workloads — bit-serial
adder / multiplier (§8.1) and the Multi-RowCopy secure-erase wave
(§8.2) — and writes a machine-readable ``BENCH_fused.json`` so the perf
trajectory of the fusion layer is recorded run over run (schema in
``docs/BENCH.md``).

Usage::

    python -m benchmarks.bench --smoke            # CI-size, ~seconds
    python -m benchmarks.bench                    # full sizes
    python -m benchmarks.bench --backends oracle pallas sim

Every row carries both wall-clock timings and *structural* dispatch
counts; the CI gate asserts on the latter (fused < per-op for the
32-bit adder), which needs no timing stability.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCHEMA = "repro-bench/fused-v1"
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                           "BENCH_fused.json")


# --------------------------------------------------------------- workloads
def _adder(nbits: int, lanes: int):
    """Traced §8.1 ripple-carry adder over ``lanes`` bit-serial lanes."""
    import numpy as np

    from repro.compile import compile_elementwise

    rng = np.random.default_rng(7)
    a = rng.integers(0, 2 ** 32, lanes, dtype=np.uint32)
    b = rng.integers(0, 2 ** 32, lanes, dtype=np.uint32)
    if nbits < 32:
        mask = np.uint32((1 << nbits) - 1)
        a, b = a & mask, b & mask
    cp = compile_elementwise("add", a, b, tier=5, n_act=32)
    return cp.program, cp.state


def _multiplier(nbits: int, lanes: int):
    """Traced shift-and-add multiplier restricted to ``nbits`` planes."""
    import numpy as np

    from repro.compile import trace_planes
    from repro.core import bitplanes as bp

    rng = np.random.default_rng(11)
    bits_a = rng.integers(0, 2, (nbits, lanes)).astype(bool)
    bits_b = rng.integers(0, 2, (nbits, lanes)).astype(bool)
    A = bp.pack(bits_a)
    B = bp.pack(bits_b)
    cp = trace_planes(lambda bs: list(bs.mul(A, B)), tier=5, n_act=32)
    return cp.program, cp.state


def _erase(waves: int, fanout: int, words: int):
    """§8.2 Multi-RowCopy bank wipe: one WR'd pattern row fans out to
    ``waves`` disjoint ``fanout``-row groups (all independent — a
    single dependency level, so the fused path is one dispatch)."""
    import numpy as np

    from repro.pud.isa import Program

    prog = Program()
    prog.emit("WR", tag="erase/pattern")
    row = 1
    for w in range(waves):
        prog.emit("MRC", n_act=fanout + 1, tag=f"erase/wave[{w}]",
                  srcs=(0,), dsts=tuple(range(row, row + fanout)))
        row += fanout
    state = np.zeros((row, words), np.uint32)
    state[0] = 0xDEADBEEF  # the predetermined wipe pattern
    return prog, state


def _workloads(smoke: bool):
    if smoke:
        return {
            "add32": lambda: _adder(32, 64),
            "mul8": lambda: _multiplier(8, 64),
            "erase_mrc31": lambda: _erase(waves=8, fanout=31, words=64),
        }
    return {
        "add32": lambda: _adder(32, 4096),
        "mul16": lambda: _multiplier(16, 4096),
        "erase_mrc31": lambda: _erase(waves=64, fanout=31, words=2048),
    }


# ----------------------------------------------------------------- driver
def _timed(fn, reps: int):
    import jax

    out = fn()           # warm-up: jit/pallas compile paths
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def bench_program(name: str, prog, state, backend_names, reps: int):
    import numpy as np

    from repro.backends import ExecutionContext, get_backend
    from repro.compile import build_schedule

    sched = build_schedule(prog)
    ideal = ExecutionContext(ideal=True)
    want = np.asarray(get_backend("oracle", ideal).run(prog, state))
    rows = []
    for be_name in backend_names:
        be = get_backend(be_name, ideal)
        modes = {}
        for mode, runner in (("per_op", be.run), ("fused", be.run_fused)):
            be.reset_dispatches()
            wall, out = _timed(lambda r=runner: r(prog, state), reps)
            # counters accumulate over warm-up + reps: report per run
            dispatches = be.dispatch_count // (reps + 1)
            modes[mode] = {"wall_s": wall, "dispatches": dispatches}
            modes[mode]["parity"] = bool((np.asarray(out) == want).all())
        rows.append({
            "name": name,
            "backend": be_name,
            "n_ops": len(prog.ops),
            "n_levels": sched.n_levels,
            "per_op": modes["per_op"],
            "fused": modes["fused"],
            "speedup": modes["per_op"]["wall_s"]
            / max(modes["fused"]["wall_s"], 1e-12),
            "dispatch_reduction": modes["per_op"]["dispatches"]
            / max(modes["fused"]["dispatches"], 1),
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size workloads, 1 timing rep")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path (default results/BENCH_fused.json)")
    ap.add_argument("--backends", nargs="+", default=["oracle", "pallas"],
                    help="executors to time (sim is slow: opt in)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repetitions (default: 1 smoke, 3 full)")
    args = ap.parse_args(argv)
    reps = args.reps or (1 if args.smoke else 3)

    rows = []
    for name, build in _workloads(args.smoke).items():
        prog, state = build()
        print(f"[bench] {name}: {len(prog.ops)} ops ...", flush=True)
        rows.extend(bench_program(name, prog, state, args.backends, reps))

    doc = {
        "schema": SCHEMA,
        "smoke": args.smoke,
        "reps": reps,
        "interpret": True,
        "workloads": rows,
    }
    out_path = os.path.abspath(args.out)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[bench] wrote {out_path}")

    for r in rows:
        flag = "" if r["per_op"]["parity"] and r["fused"]["parity"] else \
            "  !! PARITY MISMATCH"
        print(f"  {r['name']:12s} [{r['backend']:7s}] "
              f"per-op {r['per_op']['wall_s']*1e3:8.1f} ms "
              f"/{r['per_op']['dispatches']:5d} disp | fused "
              f"{r['fused']['wall_s']*1e3:8.1f} ms "
              f"/{r['fused']['dispatches']:5d} disp | "
              f"{r['speedup']:5.2f}x wall, "
              f"{r['dispatch_reduction']:5.1f}x dispatch{flag}")
    bad = [r for r in rows
           if not (r["per_op"]["parity"] and r["fused"]["parity"])]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
