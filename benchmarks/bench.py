"""``benchmarks.bench``: per-op vs fused vs megakernel execution harness.

Times the same addressed :class:`~repro.pud.isa.Program` through all
three execution paths of a :class:`~repro.session.DramSession` — per-op
interpretation (``run``, one kernel launch per MAJ/MRC op),
compile-cached fused execution (``run_fused``, one launch per schedule
dispatch group, see :mod:`repro.compile`), and megakernel execution
(``run_fused(mode="megakernel")``, ONE launch for the whole schedule
via lowered level tables, see :mod:`repro.compile.megakernel`) — for
the paper-motivated workloads: bit-serial adder / multiplier (§8.1)
and the Multi-RowCopy secure-erase wave (§8.2).  Results land in a
machine-readable ``BENCH_fused.json`` so the perf trajectory of the
fusion layer is recorded run over run (schema ``repro-bench/fused-v4``
in ``docs/BENCH.md``).

Usage::

    python -m benchmarks.bench --smoke            # CI-size, ~seconds
    python -m benchmarks.bench                    # full sizes
    python -m benchmarks.bench --backends oracle pallas sim

Every row carries wall-clock timings, *structural* dispatch counts and
CostModel-priced energy (both measured in a scoped ``count_dispatches``
window per run, so workloads never leak counts into each other), the
modelled launch overhead (dispatches x
:data:`repro.core.costmodel.KERNEL_LAUNCH_NS` — the command-stream cost
the megakernel collapses), the session compile-cache hits/misses of the
fused paths, and an ``offload`` block pricing the same program on the
PUD side (time and joules for both, via
:func:`repro.pud.offload.plan_program`); the CI gate asserts on the
structural columns (megakernel <= 2 dispatches for add32/mul8, fused <
per-op, megakernel energy <= fused <= per-op, >= 1 cache hit), which
needs no timing stability.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from _bench_io import default_out, write_bench_json

SCHEMA = "repro-bench/fused-v4"
DEFAULT_OUT = default_out("BENCH_fused.json")


# --------------------------------------------------------------- workloads
def _adder(nbits: int, lanes: int):
    """Traced §8.1 ripple-carry adder over ``lanes`` bit-serial lanes."""
    import numpy as np

    from repro.compile import compile_elementwise

    rng = np.random.default_rng(7)
    a = rng.integers(0, 2 ** 32, lanes, dtype=np.uint32)
    b = rng.integers(0, 2 ** 32, lanes, dtype=np.uint32)
    if nbits < 32:
        mask = np.uint32((1 << nbits) - 1)
        a, b = a & mask, b & mask
    cp = compile_elementwise("add", a, b, tier=5, n_act=32)
    return cp.program, cp.state


def _multiplier(nbits: int, lanes: int):
    """Traced shift-and-add multiplier restricted to ``nbits`` planes."""
    import numpy as np

    from repro.compile import trace_planes
    from repro.core import bitplanes as bp

    rng = np.random.default_rng(11)
    bits_a = rng.integers(0, 2, (nbits, lanes)).astype(bool)
    bits_b = rng.integers(0, 2, (nbits, lanes)).astype(bool)
    A = bp.pack(bits_a)
    B = bp.pack(bits_b)
    cp = trace_planes(lambda bs: list(bs.mul(A, B)), tier=5, n_act=32)
    return cp.program, cp.state


def _erase(waves: int, fanout: int, words: int):
    """§8.2 Multi-RowCopy bank wipe: one WR'd pattern row fans out to
    ``waves`` disjoint ``fanout``-row groups (all independent — a
    single dependency level, so the fused path is one dispatch)."""
    import numpy as np

    from repro.pud.isa import Program

    prog = Program()
    prog.emit("WR", tag="erase/pattern")
    row = 1
    for w in range(waves):
        prog.emit("MRC", n_act=fanout + 1, tag=f"erase/wave[{w}]",
                  srcs=(0,), dsts=tuple(range(row, row + fanout)))
        row += fanout
    state = np.zeros((row, words), np.uint32)
    state[0] = 0xDEADBEEF  # the predetermined wipe pattern
    return prog, state


def _workloads(smoke: bool):
    if smoke:
        return {
            "add32": lambda: _adder(32, 64),
            "mul8": lambda: _multiplier(8, 64),
            "erase_mrc31": lambda: _erase(waves=8, fanout=31, words=64),
        }
    return {
        "add32": lambda: _adder(32, 4096),
        "mul16": lambda: _multiplier(16, 4096),
        "erase_mrc31": lambda: _erase(waves=64, fanout=31, words=2048),
    }


# ----------------------------------------------------------------- driver
def _timed(fn, session, reps: int):
    """(wall_s per rep, final output, frozen dispatch/energy scope).

    The warm-up run (jit/pallas compile paths) executes inside its own
    ``count_dispatches`` scope, so the launch count — and the
    CostModel-priced energy — is exact for one run: no dividing a
    shared counter across reps, no leakage from whatever ran before.
    """
    import jax

    with session.count_dispatches() as scope:
        out = fn()
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out, scope


def bench_program(name: str, prog, state, sessions, ref, reps: int):
    import numpy as np

    from repro.core.costmodel import KERNEL_LAUNCH_NS
    from repro.pud.offload import plan_program

    want = np.asarray(ref.run(prog, state))
    rows = []
    for be_name, sess in sessions.items():
        modes = {}
        runners = (
            ("per_op", lambda: sess.run(prog, state)),
            ("fused", lambda: sess.run_fused(prog, state)),
            ("megakernel",
             lambda: sess.run_fused(prog, state, mode="megakernel")),
        )
        for mode, runner in runners:
            if mode != "per_op":  # per-op never touches the caches
                cache0 = sess.cache.stats.snapshot()
                low0 = sess.cache.lowering_stats.snapshot()
            wall, out, scope = _timed(runner, sess, reps)
            modes[mode] = {
                "wall_s": wall,
                "dispatches": scope.count,
                "launch_overhead_ns": scope.count * KERNEL_LAUNCH_NS,
                "energy_nj": scope.energy_nj,
                "parity": bool((np.asarray(out) == want).all()),
            }
            if mode != "per_op":
                d = sess.cache.stats.delta(cache0)
                modes[mode]["cache"] = {"hits": d.hits, "misses": d.misses}
            if mode == "megakernel":
                dl = sess.cache.lowering_stats.delta(low0)
                modes[mode]["lowering_cache"] = {"hits": dl.hits,
                                                 "misses": dl.misses}
                modes[mode]["vmem"] = _vmem_plan(sess, prog, state)
        # The fused warm-up built (and cached) the schedule; reading the
        # level count back is a hit, never a second scheduling pass.
        # The offload decision reuses the same cached schedule: the row
        # records where this program would run, in ns AND nJ.
        decision = plan_program(prog, state.shape[1] * 4, ctx=sess.ctx,
                                sched=sess.schedule_for(prog))
        rows.append({
            "name": name,
            "backend": be_name,
            "n_ops": len(prog.ops),
            "n_levels": sess.schedule_for(prog).n_levels,
            "per_op": modes["per_op"],
            "fused": modes["fused"],
            "megakernel": modes["megakernel"],
            "speedup": modes["per_op"]["wall_s"]
            / max(modes["fused"]["wall_s"], 1e-12),
            "dispatch_reduction": modes["per_op"]["dispatches"]
            / max(modes["fused"]["dispatches"], 1),
            "megakernel_dispatch_reduction":
            modes["per_op"]["dispatches"]
            / max(modes["megakernel"]["dispatches"], 1),
            "energy_reduction": modes["per_op"]["energy_nj"]
            / max(modes["fused"]["energy_nj"], 1e-12),
            "megakernel_energy_reduction":
            modes["per_op"]["energy_nj"]
            / max(modes["megakernel"]["energy_nj"], 1e-12),
            "offload": {
                "tpu_ns": decision.tpu_ns,
                "pud_ns": decision.pud_ns,
                "tpu_energy_nj": decision.tpu_energy_nj,
                "pud_energy_nj": decision.pud_energy_nj,
                "winner": decision.winner,
                "winner_energy": decision.winner_energy,
            },
        })
    return rows


def _vmem_plan(sess, prog, state):
    """The megakernel column-blocking decision for this (program, image),
    or None on backends without the capability (their megakernel rows
    measure the exact fallback path)."""
    caps = sess.capabilities()
    if not caps.megakernel:
        return None
    from repro.compile import plan_vmem

    low = sess.cache.lowering_for(prog)
    rows, words = state.shape
    return plan_vmem(low, rows, words, caps.vmem_budget_bytes).as_dict()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size workloads, 1 timing rep")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path (default results/BENCH_fused.json)")
    ap.add_argument("--backends", nargs="+", default=["oracle", "pallas"],
                    help="executors to time (sim is slow: opt in)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repetitions (default: 1 smoke, 3 full)")
    args = ap.parse_args(argv)
    reps = args.reps or (1 if args.smoke else 3)

    from repro.backends import ExecutionContext
    from repro.session import DramSession

    # One session per backend for the whole run: repeated programs hit
    # the compile cache exactly as they would in a serving deployment.
    ideal = ExecutionContext(ideal=True)
    sessions = {n: DramSession(n, ideal, name=f"bench-{n}")
                for n in args.backends}
    ref = (sessions.get("oracle")
           or DramSession("oracle", ideal, name="bench-oracle-ref"))

    rows = []
    for name, build in _workloads(args.smoke).items():
        prog, state = build()
        print(f"[bench] {name}: {len(prog.ops)} ops ...", flush=True)
        rows.extend(bench_program(name, prog, state, sessions, ref, reps))

    hits = sum(s.cache.stats.hits for s in sessions.values())
    misses = sum(s.cache.stats.misses for s in sessions.values())
    lhits = sum(s.cache.lowering_stats.hits for s in sessions.values())
    lmisses = sum(s.cache.lowering_stats.misses for s in sessions.values())
    doc = {
        "schema": SCHEMA,
        "smoke": args.smoke,
        "reps": reps,
        "interpret": True,
        "compile_cache": {
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
        },
        "lowering_cache": {
            "hits": lhits,
            "misses": lmisses,
            "hit_rate": lhits / max(lhits + lmisses, 1),
        },
        "workloads": rows,
    }
    write_bench_json(args.out, doc)

    for r in rows:
        ok = (r["per_op"]["parity"] and r["fused"]["parity"]
              and r["megakernel"]["parity"])
        flag = "" if ok else "  !! PARITY MISMATCH"
        print(f"  {r['name']:12s} [{r['backend']:7s}] "
              f"per-op {r['per_op']['wall_s']*1e3:8.1f} ms "
              f"/{r['per_op']['dispatches']:5d} disp "
              f"/{r['per_op']['energy_nj']/1e3:9.1f} uJ | fused "
              f"{r['fused']['wall_s']*1e3:8.1f} ms "
              f"/{r['fused']['dispatches']:5d} disp | mega "
              f"{r['megakernel']['wall_s']*1e3:8.1f} ms "
              f"/{r['megakernel']['dispatches']:5d} disp "
              f"/{r['megakernel']['energy_nj']/1e3:9.1f} uJ | "
              f"{r['speedup']:5.2f}x wall, "
              f"{r['megakernel_dispatch_reduction']:5.1f}x mega "
              f"dispatch, {r['megakernel_energy_reduction']:5.1f}x mega "
              f"energy{flag}")
    cc, lc = doc["compile_cache"], doc["lowering_cache"]
    print(f"[bench] compile cache: {cc['hits']} hits / {cc['misses']} "
          f"misses ({cc['hit_rate']*100:.0f}% hit rate); lowering cache: "
          f"{lc['hits']} hits / {lc['misses']} misses")
    bad = [r for r in rows
           if not (r["per_op"]["parity"] and r["fused"]["parity"]
                   and r["megakernel"]["parity"])]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
