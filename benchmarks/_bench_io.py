"""Shared result-file hygiene for the bench harnesses.

Both benches (``benchmarks/bench.py``, ``benchmarks/serve_bench.py``)
write schema'd JSON documents.  The rules they share live here instead
of being duplicated:

* ``--out`` is always honored; the repo-relative ``results/`` path is
  only a *default* for interactive runs — CI must pass a temp-dir
  ``--out`` and never writes into ``results/`` (see ``scripts/ci.sh``);
* writes are atomic (tmp file + ``os.replace``), so a killed bench
  never leaves a half-written results document for a gate to parse.
"""

from __future__ import annotations

import json
import os

_RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def default_out(filename: str) -> str:
    """Default (non-CI) output path: ``results/<filename>``."""
    return os.path.join(_RESULTS_DIR, filename)


def write_bench_json(out_path: str, doc: dict) -> str:
    """Atomically write ``doc`` to ``out_path``; returns the abspath."""
    out_path = os.path.abspath(out_path)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2)
    os.replace(tmp, out_path)
    print(f"[bench] wrote {out_path}")
    return out_path
