"""Kernel micro-benchmarks through the session API: a ``pallas``
session (interpret) vs the ``oracle`` reference on identical inputs,
plus the analytic TPU-side traffic model for each kernel.  Swapping the
one-string backend name re-prices every row on a different executor."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionContext
from repro.session import DramSession

#: One-string config choice: which executor the benchmark rows measure.
BENCH_BACKEND = "pallas"
REF_BACKEND = "oracle"


def _timeit(fn, reps=3):
    r = fn()
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_benchmarks(backend: str = BENCH_BACKEND):
    ctx = ExecutionContext()
    be = DramSession(backend, ctx)
    ref = DramSession(REF_BACKEND, ctx)
    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.integers(0, 2**32, (9, 64, 2048), dtype=np.uint32))
    us_ref = _timeit(lambda: ref.majx(x))
    us_k = _timeit(lambda: be.majx(x))
    # HBM traffic model on TPU: read 9 planes + write 1
    traffic = x.nbytes * 10 / 9
    rows.append((f"kernel_majx9_64x2048[{backend}]", us_k,
                 f"ref_us={us_ref:.0f};tpu_bytes={traffic:.0f}"))

    a = jnp.asarray(rng.integers(0, 2**32, (32, 16, 512), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (32, 16, 512), dtype=np.uint32))
    us_ref = _timeit(lambda: ref.add_planes(a, b))
    us_k = _timeit(lambda: be.add_planes(a, b))
    # fused kernel: one round trip; naive plane-at-a-time: 32 round trips
    rows.append((f"kernel_bitserial_add_32x16x512[{backend}]", us_k,
                 f"ref_us={us_ref:.0f};traffic_reduction=32x"))

    src = jnp.asarray(rng.integers(0, 2**32, (8, 4096), dtype=np.uint32))
    us_k = _timeit(lambda: be.rowcopy(src, 31))
    rows.append((f"kernel_fanout31_8x4096[{backend}]", us_k,
                 f"hbm_read_bytes={src.nbytes};write={src.nbytes*31}"))

    g = jnp.asarray(rng.integers(0, 2**32, (1 << 18,), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (1 << 18,), dtype=np.uint32))
    us_ref = _timeit(lambda: ref.mismatch(g, w))
    us_k = _timeit(lambda: be.mismatch(g, w))
    rows.append((f"kernel_mismatch_1M_cells[{backend}]", us_k,
                 f"ref_us={us_ref:.0f}"))

    # vmapped batch dispatch (native on pallas, loop elsewhere)
    xb = jnp.asarray(rng.integers(0, 2**32, (4, 5, 16, 512), dtype=np.uint32))
    us_k = _timeit(lambda: be.majx_batch(xb))
    rows.append((f"kernel_majx5_batch4_16x512[{backend}]", us_k,
                 f"native_batch={be.capabilities().native_batch}"))
    return rows
