"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle timing,
plus the analytic TPU-side traffic model for each kernel."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitserial.ops import bitserial_add
from repro.kernels.bitserial.ref import bitserial_add_ref
from repro.kernels.majx.ops import majx
from repro.kernels.majx.ref import majx_ref
from repro.kernels.mismatch.ops import mismatch_count
from repro.kernels.mismatch.ref import mismatch_count_ref
from repro.kernels.rowcopy.ops import fanout


def _timeit(fn, reps=3):
    r = fn()
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_benchmarks():
    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.integers(0, 2**32, (9, 64, 2048), dtype=np.uint32))
    us_ref = _timeit(jax.jit(majx_ref), reps=3) if False else _timeit(
        lambda: majx_ref(x))
    us_k = _timeit(lambda: majx(x))
    # HBM traffic model on TPU: read 9 planes + write 1
    traffic = x.nbytes * 10 / 9
    rows.append(("kernel_majx9_64x2048", us_k,
                 f"ref_us={us_ref:.0f};tpu_bytes={traffic:.0f}"))

    a = jnp.asarray(rng.integers(0, 2**32, (32, 16, 512), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (32, 16, 512), dtype=np.uint32))
    us_ref = _timeit(lambda: bitserial_add_ref(a, b))
    us_k = _timeit(lambda: bitserial_add(a, b))
    # fused kernel: one round trip; naive plane-at-a-time: 32 round trips
    rows.append(("kernel_bitserial_add_32x16x512", us_k,
                 f"ref_us={us_ref:.0f};traffic_reduction=32x"))

    src = jnp.asarray(rng.integers(0, 2**32, (8, 4096), dtype=np.uint32))
    us_k = _timeit(lambda: fanout(src, 31))
    rows.append(("kernel_fanout31_8x4096", us_k,
                 f"hbm_read_bytes={src.nbytes};write={src.nbytes*31}"))

    g = jnp.asarray(rng.integers(0, 2**32, (1 << 18,), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (1 << 18,), dtype=np.uint32))
    us_ref = _timeit(lambda: mismatch_count_ref(g, w))
    us_k = _timeit(lambda: mismatch_count(g, w))
    rows.append(("kernel_mismatch_1M_cells", us_k, f"ref_us={us_ref:.0f}"))
    return rows
