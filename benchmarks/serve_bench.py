"""``benchmarks.serve_bench``: offered load vs SLO under batching.

Drives a :class:`~repro.serve.service.PudService` with a mixed
integrity workload (X-replica MAJX heals + Multi-RowCopy erases) at a
ladder of offered loads, in two modes over the *same* requests:

* ``sequential`` — coalescing off: every request is its own fused
  Program and its own dispatch set (the one-at-a-time baseline the old
  engine hook was);
* ``batched`` — continuous batching on: same-shape requests coalesce
  into one fused Program per tick, so N tenants' votes cost one
  schedule-cache lookup and one batched MAJX dispatch.

Each (load, mode) point records wall time, throughput, p50/p99 request
latency, executed batches, batch occupancy, *structural* dispatch
counts and CostModel-priced energy (per-batch ``DispatchScope`` windows
summed by the SLO monitor) and the schedule-cache window.  A nonzero
``--tick-window`` exercises ``ServiceConfig.tick_window_s`` — the
cross-tick coalescing wait — on the sync ``serve()`` path.  Results
land in ``BENCH_serve.json`` (schema ``repro-bench/serve-v2``,
documented in ``docs/BENCH.md``); ``scripts/ci.sh`` gates on the
structural columns — batched throughput >= sequential, batched
dispatches AND energy < sequential, cache hit rate > 0, p99 recorded.

Usage::

    python -m benchmarks.serve_bench --smoke       # CI-size, ~seconds
    python -m benchmarks.serve_bench               # full load ladder
    python -m benchmarks.serve_bench --backend oracle --loads 4 16
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from _bench_io import default_out, write_bench_json

SCHEMA = "repro-bench/serve-v2"
DEFAULT_OUT = default_out("BENCH_serve.json")


def _requests(offered: int, rows: int, words: int, seed: int):
    """A deterministic mixed workload: ``offered`` heals + erases.

    Every round rebuilds fresh request objects (requests are stamped at
    admission) from the same seed, so batched and sequential modes
    serve bit-identical work.
    """
    import numpy as np

    from repro.serve import EraseRequest, HealRequest

    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(offered):
        base = rng.integers(0, 2**32, (rows, words), dtype=np.uint32)
        replicas = np.stack([base, base, base])
        # one replica suffers a few flipped bits, as in a real heal
        replicas[i % 3, rng.integers(rows), rng.integers(words)] ^= 0b101
        reqs.append(HealRequest(replicas=replicas, tenant=f"tenant[{i}]"))
    for i in range(offered):
        reqs.append(EraseRequest(rows=31, words=words, pattern=0xDEADBEEF,
                                 tenant=f"tenant[{i}]"))
    return reqs


def bench_point(offered: int, mode: str, backend: str, rows: int,
                words: int, rounds: int,
                tick_window_s: float = 0.0) -> dict:
    import time

    from repro.serve import PudService, ServiceConfig

    svc = PudService(ServiceConfig(
        backend=backend, pool_size=2, coalesce=(mode == "batched"),
        max_batch=2 * offered, queue_depth=max(4 * offered, 64),
        tick_window_s=tick_window_s))
    svc.serve(_requests(offered, rows, words, seed=0))  # warm-up round
    svc.reset_slo()

    t0 = time.perf_counter()
    for r in range(rounds):
        results = svc.serve(_requests(offered, rows, words, seed=r))
        assert all(not isinstance(x, Exception) for x in results)
    wall = time.perf_counter() - t0

    snap = svc.snapshot()
    return {
        "offered": offered,
        "mode": mode,
        "rounds": rounds,
        "wall_s": wall,
        "completed": snap.completed,
        "throughput_rps": snap.completed / wall,
        "p50_ms": (None if snap.p50_latency_s is None
                   else snap.p50_latency_s * 1e3),
        "p99_ms": (None if snap.p99_latency_s is None
                   else snap.p99_latency_s * 1e3),
        "batches": snap.batches,
        "batch_occupancy": snap.batch_occupancy,
        "dispatches": snap.dispatches,
        "energy_nj": snap.energy_nj,
        "energy_per_req_nj": snap.energy_nj / max(snap.completed, 1),
        "tick_window_s": tick_window_s,
        "cache": snap.cache,
        "shed": snap.shed,
        "slo": snap.to_dict(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.serve_bench", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size loads, tiny tiles, 2 rounds")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path (default results/BENCH_serve.json)")
    ap.add_argument("--backend", default="pallas",
                    help="service backend (oracle | sim | pallas)")
    ap.add_argument("--loads", nargs="+", type=int, default=None,
                    help="offered concurrent requests per class per round")
    ap.add_argument("--rounds", type=int, default=None,
                    help="timed rounds per point (default: 2 smoke, 3 full)")
    ap.add_argument("--tick-window", type=float, default=None,
                    help="ServiceConfig.tick_window_s coalescing wait "
                         "(default: 1 ms smoke — exercising the sync-path "
                         "window — 0 full)")
    args = ap.parse_args(argv)

    loads = args.loads or ([2, 8] if args.smoke else [4, 16, 64])
    rounds = args.rounds or (2 if args.smoke else 3)
    rows, words = (4, 64) if args.smoke else (8, 256)
    tick_window = (args.tick_window if args.tick_window is not None
                   else (0.001 if args.smoke else 0.0))

    points = []
    for offered in loads:
        for mode in ("sequential", "batched"):
            print(f"[serve-bench] offered={offered} mode={mode} ...",
                  flush=True)
            points.append(bench_point(offered, mode, args.backend,
                                      rows, words, rounds,
                                      tick_window_s=tick_window))

    doc = {
        "schema": SCHEMA,
        "smoke": args.smoke,
        "backend": args.backend,
        "rounds": rounds,
        "tick_window_s": tick_window,
        "workload": {
            "classes": ["heal(x3)", "erase(mrc31)"],
            "heal_rows": rows,
            "erase_rows": 31,
            "words": words,
        },
        "points": points,
    }
    write_bench_json(args.out, doc)

    for p in points:
        occ = p["batch_occupancy"] or 0.0
        print(f"  load {p['offered']:4d} [{p['mode']:10s}] "
              f"{p['throughput_rps']:8.1f} req/s | p50 "
              f"{p['p50_ms']:7.1f} ms p99 {p['p99_ms']:7.1f} ms | "
              f"{p['dispatches']:4d} disp / {p['batches']:3d} batches "
              f"(occ {occ:4.1f}) | {p['energy_per_req_nj']/1e3:6.1f} "
              f"uJ/req | cache {p['cache']['hit_rate']*100:3.0f}%")

    # Structural self-check (the CI gate re-asserts this from the JSON).
    bad = []
    for offered in loads:
        seq = next(p for p in points
                   if p["offered"] == offered and p["mode"] == "sequential")
        bat = next(p for p in points
                   if p["offered"] == offered and p["mode"] == "batched")
        if bat["dispatches"] >= seq["dispatches"]:
            bad.append(f"load {offered}: batched dispatches "
                       f"{bat['dispatches']} >= sequential "
                       f"{seq['dispatches']}")
        if bat["energy_nj"] > seq["energy_nj"]:
            bad.append(f"load {offered}: batched energy "
                       f"{bat['energy_nj']:.0f} nJ > sequential "
                       f"{seq['energy_nj']:.0f} nJ")
    if bad:
        print("[serve-bench] STRUCTURAL REGRESSION:", *bad, sep="\n  ")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
