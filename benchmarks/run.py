"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV.  Roofline rows for the LM
architectures come from prior dry-run artifacts (results/dryrun*.json),
since the dry-run needs the 512-device environment.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import kernel_bench
    from benchmarks import paper_figures as pf

    rows = []
    for fn in (
        pf.fig3_simra_timing,
        pf.fig4_simra_temp_vpp,
        pf.fig5_power,
        pf.fig6_maj3_timing,
        pf.fig6_cliff_adaptive,
        pf.fig7_majx_patterns,
        pf.fig8_majx_temperature,
        pf.fig9_majx_voltage,
        pf.fig10_mrc_timing,
        pf.fig11_mrc_patterns,
        pf.fig12_mrc_temp_vpp,
        pf.fig15_spice_mc,
        pf.fig16_microbench_speedups,
        pf.fig17_cold_boot,
        pf.fig18_energy_modes,
        pf.table1_devices,
        kernel_bench.kernel_benchmarks,
    ):
        rows.extend(fn())

    for path in sorted(glob.glob(os.path.join(
            os.path.dirname(__file__), "..", "results", "dryrun*.json"))):
        try:
            for r in json.load(open(path)):
                if r.get("status") != "ok":
                    continue
                rl = r["roofline"]
                rows.append((
                    f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                    f"bound={rl['bottleneck']};frac={rl['roofline_fraction']:.4f};"
                    f"mem_gb={rl['mem_per_chip_gb']:.2f}"))
        except Exception:
            pass

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
