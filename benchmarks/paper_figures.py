"""Reproduction of every SiMRA-DRAM figure/table as benchmark functions.

Each function returns a list of CSV rows (name, us_per_call, derived)
where ``derived`` carries the figure's headline quantity.  benchmarks/run.py
prints them; EXPERIMENTS.md §Paper-validation quotes them.

The characterization figures (3, 4, 6-12) are formatted views over
`repro.sweep` records: each figure's grid is a preset
:class:`~repro.sweep.spec.SweepSpec` (``repro.sweep.presets``) whose
records are produced — or loaded from the resumable store under
``results/sweeps`` — by the sweep engine.  The remaining figures
(power, SPICE, §8 case studies) are analytic models, not
characterization grids, and stay direct.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.session import DramSession
from repro.core import calibration as cal
from repro.core import chargeshare as cs
from repro.core import power as pw
from repro.core.errormodel import ErrorModel
from repro.pud import latency as lat
from repro.pud.secure_erase import destruction_time_ns, speedup_over_rowclone
from repro.sweep import default_root, presets, records_for, run_adaptive

#: Sweep record stores for the figure grids (resumable across runs;
#: repo-relative default shared with the CLI and make_tables).
SWEEP_ROOT = default_root()


def _records(spec):
    return records_for(spec, root=SWEEP_ROOT, progress=False)


def _timeit(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# Fig 3: SiMRA success vs (t1, t2) x N -----------------------------------


def fig3_simra_timing():
    recs = sorted(_records(presets.fig3_spec()),
                  key=lambda r: (r["t1"], r["t2"], r["n_act"]))
    return [(f"fig3_simra_n{r['n_act']}_t1_{r['t1']}_t2_{r['t2']}", 0.0,
             f"success={r['success']:.4f}") for r in recs]


# Fig 4: SiMRA temperature / voltage -------------------------------------


def fig4_simra_temp_vpp():
    recs = _records(presets.fig4_spec())
    rows = [(f"fig4a_simra32_T{r['temp_c']:.0f}", 0.0,
             f"success={r['success']:.4f}")
            for r in recs if r["vpp_v"] == 2.5]
    rows += [(f"fig4b_simra32_V{r['vpp_v']:.1f}", 0.0,
              f"success={r['success']:.4f}")
             for r in recs if r["temp_c"] == 50.0]
    return rows


# Fig 5: power ------------------------------------------------------------


def fig5_power():
    rows = []
    for op, w in pw.power_table().items():
        rows.append((f"fig5_power_{op}", 0.0, f"watts={w:.3f}"))
    rows.append(("fig5_simra32_vs_ref", 0.0,
                 f"delta={pw.simra_power_w(32)/pw.STANDARD_POWER_W['REF']-1:+.4f}"))
    return rows


# Fig 6: MAJ3 vs timing x N (incl. the replication ladder) -----------------


def fig6_maj3_timing():
    spec = presets.fig6_spec()
    order = {t: i for i, t in enumerate(spec.timings)}
    recs = sorted(_records(spec),
                  key=lambda r: (order[(r["t1"], r["t2"])], r["n_act"]))
    return [(f"fig6_maj3_n{r['n_act']}_t1_{r['t1']}_t2_{r['t2']}", 0.0,
             f"success={r['success']:.4f}") for r in recs]


def fig6_cliff_adaptive():
    """Obs 7 cliff located by boundary search instead of a dense ladder.

    Runs the adaptive smoke campaign (20-step t1 ladder, MAJ3@32) and
    reports each threshold bracket plus the point economy — the
    fraction of the dense ladder the search actually executed.  The
    store is shared with dense runs of the same spec, so records here
    are byte-identical to a grid campaign's.
    """
    result = run_adaptive(presets.adaptive_smoke_spec(), root=SWEEP_ROOT)
    rows = []
    for c in result.crossings:
        if not c.crossed:
            continue
        rows.append((f"fig6_cliff_t1_at_{c.threshold:g}", 0.0,
                     f"bracket={c.lo_value[0]:g}..{c.hi_value[0]:g}ns"))
    rows.append(("fig6_cliff_economy", 0.0,
                 f"probed={result.n_probed}/{result.n_grid_points}"))
    return rows


# Fig 7: MAJX x data pattern ----------------------------------------------


def fig7_majx_patterns():
    return [(f"fig7_maj{r['x']}_{r['pattern'].replace('/', '_')}", 0.0,
             f"success={r['success']:.4f}")
            for r in _records(presets.fig7_spec())]


# Fig 8/9: MAJX temperature / voltage -------------------------------------


def fig8_majx_temperature():
    recs = _records(presets.fig8_spec())
    wanted = {(x, n) for x in (3, 5, 7, 9)
              for n in (cal.min_activation_for(x), 32)}
    recs = sorted((r for r in recs if (r["x"], r["n_act"]) in wanted),
                  key=lambda r: (r["x"], r["temp_c"], r["n_act"]))
    return [(f"fig8_maj{r['x']}_n{r['n_act']}_T{r['temp_c']:.0f}", 0.0,
             f"success={r['success']:.4f}") for r in recs]


def fig9_majx_voltage():
    return [(f"fig9_maj{r['x']}_V{r['vpp_v']:.1f}", 0.0,
             f"success={r['success']:.4f}")
            for r in _records(presets.fig9_spec())]


# Fig 10-12: Multi-RowCopy -------------------------------------------------


def fig10_mrc_timing():
    recs = sorted(_records(presets.fig10_spec()),
                  key=lambda r: (r["t1"], r["n_dest"]))
    return [(f"fig10_mrc{r['n_dest']}_t1_{r['t1']}", 0.0,
             f"success={r['success']:.5f}") for r in recs]


def fig11_mrc_patterns():
    order = {"0x00": 0, "0xFF": 1, "random": 2}
    recs = sorted(_records(presets.fig11_spec()),
                  key=lambda r: (order[r["pattern"]], r["n_dest"]))
    return [(f"fig11_mrc{r['n_dest']}_{r['pattern']}", 0.0,
             f"success={r['success']:.5f}") for r in recs]


def fig12_mrc_temp_vpp():
    recs = _records(presets.fig12_spec())
    rows = [(f"fig12a_mrc31_T{r['temp_c']:.0f}", 0.0,
             f"success={r['success']:.5f}")
            for r in recs if r["vpp_v"] == 2.5]
    rows += [(f"fig12b_mrc31_V{r['vpp_v']:.1f}", 0.0,
              f"success={r['success']:.5f}")
             for r in recs if r["temp_c"] == 50.0]
    return rows


# Fig 15: SPICE Monte-Carlo ------------------------------------------------


def fig15_spice_mc():
    key = jax.random.PRNGKey(0)
    out = cs.spice_study(key, iters=4000)
    rows = []
    for (n, pv), d in out.items():
        us = 0.0
        rows.append((f"fig15_n{n}_pv{int(pv*100)}", us,
                     f"dev={d['dev_mean']:.4f};success={d['success_rate']:.4f}"))
    gain = cs.deviation_mean(32) / cs.deviation_mean(4) - 1
    rows.append(("fig15_dev_gain_32_over_4", 0.0, f"gain={gain:+.4f}"))
    return rows


# Fig 16: the seven microbenchmarks ---------------------------------------

#: active subarrays pipelining MAJX issues (bank-level parallelism; the
#: paper schedules across 16 banks x 3 subarrays — 5 concurrently active
#: keeps the model within a DDR4 power budget, cf. Fig 5).
ACTIVE_SUBARRAYS = 5


def _microbench_time_ns(op: str, mfr: str, tier: int) -> float:
    """Analytical §8.1 model: time = max(critical path, op-issue time /
    active subarrays), with best-group success retries."""
    em = ErrorModel(mfr)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**32, 8, dtype=np.uint32)
    b = np.maximum(rng.integers(0, 2**32, 8, dtype=np.uint32), 1)
    n_act = 4 if tier == 3 else 32
    # Programs are backend-invariant; the oracle is the cheapest compiler.
    _, prog = DramSession("oracle").elementwise(op, a, b, tier=tier,
                                                n_act=n_act)
    bg = cal.MAJX_BEST_GROUP_SUCCESS[mfr]
    bg3_baseline = cal.MAJ3_4ROW_BEST_GROUP_SUCCESS[mfr]

    def op_time(x: int) -> float:
        s = bg.get(x, 0.005) if tier > 3 else bg3_baseline
        return lat.LAT.majx_apa / max(s, 1e-3)

    total = 0.0
    crit = 0.0
    n_maj = {3: 0, 5: 0, 7: 0, 9: 0}
    for o in prog.ops:
        if o.kind == "MAJ":
            total += op_time(o.x)
            n_maj[o.x] += 1
        elif o.kind in ("NOT", "COPY"):
            total += lat.LAT.rowclone
    # critical path: the serial carry chain (adds/sub/mul/div);
    # tier>=7 halves its depth via the MAJ7 two-position skip.
    if op in ("add", "sub"):
        chain = 32
    elif op == "mul":
        chain = 32 * 32
    elif op == "div":
        chain = 33 * 32
    else:
        chain = 3
    if tier >= 7:
        chain /= 2
    worst_x = max((x for x, c in n_maj.items() if c), default=3)
    crit = chain * op_time(worst_x if tier >= 7 else 3)
    return max(crit, total / ACTIVE_SUBARRAYS)


def fig16_microbench_speedups():
    rows = []
    for mfr in ("M", "H"):
        tiers = (5, 7) if mfr == "M" else (5, 7, 9)
        speedups = {t: [] for t in tiers}
        for op in cal.MICROBENCHMARKS:
            base = _microbench_time_ns(op, mfr, tier=3)
            for t in tiers:
                sp = base / _microbench_time_ns(op, mfr, tier=t)
                speedups[t].append(sp)
                rows.append((f"fig16_{mfr}_{op}_maj{t}", 0.0,
                             f"speedup={sp:.3f}"))
        for t in tiers:
            rows.append((f"fig16_{mfr}_avg_maj{t}", 0.0,
                         f"speedup={np.mean(speedups[t]):.3f}"))
    return rows


# Fig 17: cold-boot content destruction ------------------------------------


def fig17_cold_boot():
    rows = []
    for n in (2, 4, 8, 16, 32):
        rows.append((f"fig17_mrc{n}", destruction_time_ns("mrc", n) / 1e3,
                     f"speedup={speedup_over_rowclone('mrc', n):.2f}"))
    rows.append(("fig17_frac", destruction_time_ns("frac") / 1e3,
                 f"speedup={speedup_over_rowclone('frac'):.2f}"))
    rows.append(("fig17_rowclone", destruction_time_ns("rowclone") / 1e3,
                 "speedup=1.00"))
    return rows


# Fig 18 (extension): energy per execution mode ---------------------------


def fig18_energy_modes():
    """Kill-Llama-style energy-savings view over the execution paths.

    Runs the same §8.1 programs (32-bit adder, 8-bit multiplier)
    through the ``pallas`` session's three executors — per-op, fused,
    megakernel — and reports the CostModel-priced TPU-side energy each
    accrues (launch round-trips at board power + HBM traffic), next to
    the DRAM-side energy of executing the identical program in-situ
    under the Fig. 5 power model.  The headline ratios mirror the
    dispatch-reduction story in joules: fusion amortizes launch energy
    exactly as SiMRA amortizes activation energy (Obs 5 / PULSAR).
    """
    from repro.core.costmodel import COST

    session = DramSession("pallas", name="fig18")
    errors = ErrorModel("H")
    rng = np.random.default_rng(0)
    rows = []
    for wl, op, nbits, lanes in (("add32", "add", 32, 64),
                                 ("mul8", "mul", 8, 64)):
        a = rng.integers(0, 2**nbits, lanes, dtype=np.uint32)
        b = rng.integers(0, 2**nbits, lanes, dtype=np.uint32)
        if op == "mul":
            a, b = a & 0xFF, b & 0xFF
        _, prog = session.elementwise(op, a, b, tier=5, n_act=32)
        state = np.zeros((prog.n_rows(), (lanes + 31) // 32), np.uint32)
        energies = {}
        for mode, run in (
                ("per_op", lambda: session.run(prog, state)),
                ("fused", lambda: session.run_fused(prog, state)),
                ("megakernel", lambda: session.run_fused(
                    prog, state, mode="megakernel"))):
            with session.count_dispatches() as scope:
                run()
            energies[mode] = scope.energy_nj
            rows.append((f"fig18_{wl}_{mode}", 0.0,
                         f"energy_nj={scope.energy_nj:.1f};"
                         f"dispatches={scope.count}"))
        pud_nj = prog.energy_nj(errors)
        rows.append((f"fig18_{wl}_pud_dram", 0.0, f"energy_nj={pud_nj:.1f}"))
        rows.append((f"fig18_{wl}_savings", 0.0,
                     f"fused_vs_per_op="
                     f"{energies['per_op']/energies['fused']:.2f};"
                     f"mega_vs_per_op="
                     f"{energies['per_op']/energies['megakernel']:.2f};"
                     f"pud_vs_per_op={energies['per_op']/pud_nj:.2f}"))
    rows.append(("fig18_dispatch_energy_nj", 0.0,
                 f"per_launch={COST.dispatch_energy_nj(1):.1f}"))
    return rows


# Table 1/2: tested devices ------------------------------------------------


def table1_devices():
    rows = []
    for (mfr, rev), d in cal.TABLE1.items():
        rows.append((f"table1_{mfr}_{rev}", 0.0,
                     f"chips={d['chips']};density={d['density']};"
                     f"subarrays={d['subarray_sizes']}"))
    return rows
